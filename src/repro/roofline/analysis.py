"""Roofline analysis from the compiled dry-run artifact.

Three terms per (arch × shape × mesh):

    compute    = HLO_FLOPs   / (chips × 197e12  bf16 FLOP/s)   [TPU v5e]
    memory     = HLO_bytes   / (chips × 819e9   B/s HBM)
    collective = coll_bytes  / (chips × n_links × 50e9 B/s ICI)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``. Collective
bytes are parsed from ``compiled.as_text()``: we walk the HLO computation
graph, multiply instructions inside ``while`` bodies by their trip counts
(scan over layers / microbatches / attention blocks), and sum per-shard
operand bytes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops. An analytic per-layer collective model cross-checks
the parser (reported side by side in EXPERIMENTS.md).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

# ---- TPU v5e hardware constants (assignment-provided) ----
PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link
ICI_LINKS = 4              # links per chip participating (2D torus x2 dirs)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """'bf16[16,1024]{1,0}' -> bytes. Tuple shapes: sum of element shapes."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Computation:
    name: str
    coll_bytes: Dict[str, int]
    whiles: List[Tuple[str, str]]          # (body_name, cond_name)
    calls: List[str]                        # called computations (call/cond branches)


def _parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        s = line.strip()
        header = re.match(r"(?:ENTRY\s+)?%?([\w\.\-]+)\s*\([^)]*\)\s*->.*{", s)
        if header and not s.startswith("ROOT") and "=" not in s.split("(")[0]:
            cur = Computation(header.group(1), {}, [], [])
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if s.startswith("}"):
            cur = None
            continue
        # collective instruction?
        for op in _COLLECTIVES:
            # match ' = <shape> op-name(' including "-start" variants
            if re.search(rf"=\s*[^=]*\b{op}(-start)?\(", s):
                lhs_rhs = s.split("=", 1)
                if len(lhs_rhs) != 2:
                    continue
                # operand bytes: shapes of the operands inside the parens;
                # use the result shape (per-shard) as proxy for moved bytes
                bytes_ = _shape_bytes(lhs_rhs[1].split(f"{op}")[0])
                if bytes_ == 0:
                    bytes_ = _shape_bytes(lhs_rhs[1])
                cur.coll_bytes[op] = cur.coll_bytes.get(op, 0) + bytes_
                break
        m = re.search(r"while\(.*\),\s*condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)", s)
        if m:
            cur.whiles.append((m.group(2), m.group(1)))
        for cm in re.finditer(r"(?:to_apply|branch_computations|called_computations)="
                              r"[{]?%?([\w\.\-,% ]+)[}]?", s):
            for name in re.split(r"[,\s]+", cm.group(1)):
                name = name.strip().lstrip("%")
                if name:
                    cur.calls.append(name)
    return comps


def _trip_count(cond_name: str, hlo_comps: Dict[str, str]) -> int:
    """Best-effort scan trip count: the comparison constant in the while cond."""
    body = hlo_comps.get(cond_name, "")
    consts = [int(x) for x in re.findall(r"s32\[\]\s+constant\((\d+)\)", body)]
    return max(consts) if consts else 1


def _raw_computation_texts(hlo: str) -> Dict[str, str]:
    texts: Dict[str, str] = {}
    cur_name, buf = None, []
    for line in hlo.splitlines():
        s = line.strip()
        header = re.match(r"(?:ENTRY\s+)?%?([\w\.\-]+)\s*\([^)]*\)\s*->.*{", s)
        if header:
            cur_name = header.group(1)
            buf = []
            continue
        if cur_name is not None:
            if s.startswith("}"):
                texts[cur_name] = "\n".join(buf)
                cur_name = None
            else:
                buf.append(s)
    return texts


def collective_bytes_from_hlo(hlo: str, entry_hint: Optional[str] = None
                              ) -> Dict[str, int]:
    """Total per-chip collective bytes by op kind, trip-count aware."""
    comps = _parse_computations(hlo)
    texts = _raw_computation_texts(hlo)

    entry = None
    em = re.search(r"ENTRY\s+%?([\w\.\-]+)", hlo)
    if em:
        entry = em.group(1)
    if entry is None or entry not in comps:
        entry = entry_hint or (next(iter(comps)) if comps else None)
    if entry is None:
        return {}

    totals: Dict[str, int] = {}
    seen_stack: List[str] = []

    def walk(name: str, mult: int):
        if name not in comps or name in seen_stack:
            return
        seen_stack.append(name)
        c = comps[name]
        for op, b in c.coll_bytes.items():
            totals[op] = totals.get(op, 0) + b * mult
        for body, cond in c.whiles:
            trips = _trip_count(cond, texts)
            walk(body, mult * max(trips, 1))
        for callee in c.calls:
            walk(callee, mult)
        seen_stack.pop()

    walk(entry, 1)
    return totals


# ---------------------------------------------------------------------------
# Roofline report
# ---------------------------------------------------------------------------

def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE); decode: D = batch
    tokens per step. Train includes 3x (fwd+bwd)."""
    n_active = cfg.param_count(active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def roofline_terms(flops: float, hbm_bytes: float, coll_bytes: float,
                   chips: int) -> Dict[str, float]:
    compute_s = flops / (chips * PEAK_FLOPS)
    memory_s = hbm_bytes / (chips * HBM_BW)
    collective_s = coll_bytes / (chips * ICI_LINKS * ICI_BW)
    dominant = max(
        ("compute", compute_s), ("memory", memory_s),
        ("collective", collective_s), key=lambda kv: kv[1])[0]
    total = max(compute_s, memory_s, collective_s)
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "bound_s": total,
        "roofline_fraction": (compute_s / total) if total > 0 else 0.0,
    }
