"""MoE dispatch correctness vs dense reference; Mamba2 chunked-vs-recurrent."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import mamba2 as m2
from repro.models.moe import moe_apply, moe_params
from repro.models.params import materialize


def _dense_moe_ref(params, x, top_k):
    """Compute EVERY expert densely, combine with renormalized top-k gates."""
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    g = jnp.einsum("bsd,edf->bsef", x, params["w_gate"])
    u = jnp.einsum("bsd,edf->bsef", x, params["w_up"])
    h = jax.nn.silu(g) * u
    y = jnp.einsum("bsef,efd->bsed", h, params["w_down"])     # (B,S,E,d)
    E = probs.shape[-1]
    w = jnp.zeros(probs.shape)
    w = jnp.take_along_axis(
        jnp.zeros(probs.shape), gate_idx, -1) * 0  # placeholder
    onehot = jax.nn.one_hot(gate_idx, E) * gate_vals[..., None]
    weights = onehot.sum(axis=2)                              # (B,S,E)
    return jnp.einsum("bsed,bse->bsd", y, weights.astype(y.dtype))


@pytest.mark.parametrize("E,K", [(8, 2), (16, 4)])
def test_moe_matches_dense_when_no_drops(E, K):
    rng = jax.random.PRNGKey(0)
    B, S, d, f = 2, 32, 16, 24
    params = materialize(rng, moe_params(d, f, E), dtype_override=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d), jnp.float32) * .5
    out, aux = moe_apply(params, x, top_k=K, capacity_factor=float(E))
    assert float(aux["dropped_frac"]) == 0.0
    ref = _dense_moe_ref(params, x, K)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-4, rtol=2e-4)


def test_moe_capacity_drops_bounded():
    rng = jax.random.PRNGKey(0)
    B, S, d, f, E, K = 2, 64, 16, 24, 8, 2
    params = materialize(rng, moe_params(d, f, E), dtype_override=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d)) * .5
    out, aux = moe_apply(params, x, top_k=K, capacity_factor=1.0)
    assert 0.0 <= float(aux["dropped_frac"]) < 0.5
    assert np.all(np.isfinite(np.asarray(out, np.float32)))
    assert float(aux["lb_loss"]) > 0.9  # >= 1 at perfect balance


def test_moe_grads_flow():
    rng = jax.random.PRNGKey(0)
    B, S, d, f, E, K = 1, 16, 8, 12, 4, 2
    params = materialize(rng, moe_params(d, f, E), dtype_override=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d))

    def loss(p):
        out, aux = moe_apply(p, x, top_k=K)
        return jnp.sum(out ** 2) + 0.01 * aux["lb_loss"]

    grads = jax.grad(loss)(params)
    for k in ("router", "w_gate", "w_up", "w_down"):
        gn = float(jnp.linalg.norm(grads[k].astype(jnp.float32)))
        assert np.isfinite(gn) and gn > 0, k


def test_mamba2_decode_matches_chunked():
    """Stepwise O(1) decode == chunked scan on the same sequence."""
    import dataclasses
    from repro.configs import ARCHS, reduced_model
    cfg = dataclasses.replace(reduced_model(ARCHS["mamba2-1.3b"]),
                              dtype="float32")
    params = materialize(jax.random.PRNGKey(0), m2.mamba2_params(cfg),
                         dtype_override=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, cfg.d_model)) * .3
    y_full, st_full = m2.mamba2_forward(params, cfg, x)

    d_in, nh, conv_dim = m2.mamba2_dims(cfg)
    st = m2.SSMState(
        h=jnp.zeros((2, nh, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
        conv=jnp.zeros((2, cfg.ssm_conv_width - 1, conv_dim), jnp.float32))
    ys = []
    for i in range(12):
        y, st = m2.mamba2_decode(params, cfg, x[:, i:i + 1], st)
        ys.append(y)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_full),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(st.h), np.asarray(st_full.h),
                               atol=1e-4, rtol=1e-3)
