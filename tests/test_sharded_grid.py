"""Device-sharded grid sweeps == single-device, float-hex.

`--xla_force_host_platform_device_count` must be set before jax import,
so the multi-device half runs in a subprocess (the
tests/test_data_sharding_hlo.py idiom); this process stays on the real
single device. The subprocess runs the SAME sweep twice — single-device
and sharded over 4 host devices — and compares every stat float-hex,
solo baselines included. 7 rows per signature group over 4 devices also
exercises the row padding (7 -> 8, repeated rows sliced back off).
"""
import os
import subprocess
import sys

import pytest

_CODE = r"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=4")
import jax
import numpy as np
assert jax.device_count() == 4, jax.device_count()
from repro.sim import runner as R

designs = ["mask", "gpu-mmu"]
mixes = [("3DS", "BLK"), ("MUM", "RED"), ("3DS", "MUM")]
kw = dict(cycles=120, solo_baselines=True, grid=True)
single = R.sweep(designs, mixes, **kw)
sharded = R.sweep(designs, mixes, devices=4, **kw)
for name in single:
    ra, rb = single[name], sharded[name]
    assert len(ra) == len(rb)
    for xa, xb in zip(ra, rb):
        for k in xa.raw:
            ha = [float(v).hex() for v in np.atleast_1d(xa.raw[k]).ravel()]
            hb = [float(v).hex() for v in np.atleast_1d(xb.raw[k]).ravel()]
            assert ha == hb, (name, k, ha, hb)
    assert ra.solo_ipc == rb.solo_ipc, name

# asking for more devices than are visible must fail loudly
try:
    R.run_grid(designs, mixes, cycles=120, devices=64)
except ValueError as e:
    assert "devices=64" in str(e), e
else:
    raise AssertionError("run_grid(devices=64) should have raised")
print("SHARDED_PARITY_OK")
"""


@pytest.mark.slow
@pytest.mark.multi_device
def test_sharded_sweep_matches_single_device():
    env = dict(os.environ,
               PYTHONPATH="src",
               JAX_PLATFORMS="cpu")  # skip any TPU/GPU probe in the child
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _CODE], capture_output=True,
                         text=True, timeout=900, env=env)
    assert "SHARDED_PARITY_OK" in out.stdout, \
        (out.stdout[-2000:], out.stderr[-2000:])
