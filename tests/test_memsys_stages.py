"""Unit tests for the layered memsys pipeline + N-app runner entry points.

Each pipeline stage (warp_sched / translation probe+commit / datapath /
accumulate_stats) is exercised in isolation (the `_translation` /
`_datapath` helpers compose the split stages with an empty partner lane
group); the vmapped L1 TLB bank is checked for exact equivalence against
the previous hand-rolled per-core implementation; and the N-app runner
invariants (run_mix == run_pair bit-for-bit, idle-partner run_mix ==
run_solo) are pinned down.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import tlb as tlb_mod
from repro.core import tokens as tok_mod
from repro.core.design import design_params
from repro.core.mask import design, static_partition_index
from repro.sim import memsys
from repro.sim.config import SimConfig
from repro.sim.runner import run_mix, run_pair, run_solo
from repro.sim.workloads import (FIELD, IDLE_ROW, N_FIELDS, app_matrix,
                                 mix_workloads, pair_workloads)

SMALL = SimConfig(n_cores=4, warps_per_core=4, n_apps=2, sim_cycles=64,
                  design=design("gpu-mmu"))
CYCLES = 1_200


def _sched(cfg, vpn):
    """Hand-built SchedOut: warp 0 of each core, all cores active."""
    C = cfg.n_cores
    app = jnp.asarray(cfg.app_of_core, jnp.int32)
    return memsys.SchedOut(
        picked_warp=jnp.arange(C) * cfg.warps_per_core,
        slot=jnp.zeros(C, jnp.int32),
        active=jnp.ones(C, bool),
        app=app, asid=app,
        vpn=jnp.asarray(vpn, jnp.int32),
        pos=jnp.zeros(C, jnp.int32))


# ------------------------------------------------------------ warp_sched

def test_warp_sched_picks_oldest_ready():
    cfg = SMALL
    pm = jnp.asarray(app_matrix(["3DS", "BLK"]))
    stall = jnp.zeros(16, jnp.int32).at[jnp.arange(4)].set(
        jnp.asarray([9, 2, 8, 8], jnp.int32))     # core 0 waits: 1, 8, 2, 2
    stall = stall.at[jnp.arange(4, 8)].set(100)   # core 1 fully stalled
    pos = jnp.zeros(16, jnp.int32)
    out = memsys.warp_sched(cfg, pm, stall, pos, jnp.int32(10))
    assert int(out.picked_warp[0]) == 1           # oldest ready on core 0
    assert not bool(out.active[1])
    assert bool(out.active[0]) and bool(out.active[2]) and bool(out.active[3])
    # oracle core split: first half of cores -> app 0, second half -> app 1
    assert out.app.tolist() == [0, 0, 1, 1]
    assert out.asid.tolist() == out.app.tolist()


# ----------------------------------------------------------- translation

def _translation(cfg, trans, data, tokens, sched, t):
    """Translation in isolation: probe + walk-only shared memory round
    (empty data-lane group) + commit — the split stages `step` composes."""
    dp = design_params(cfg.design)
    C = cfg.n_cores
    trans, probe = memsys.translation_probe(cfg, dp, trans, tokens, sched, t)
    data, mem = memsys.shared_memory_access(
        cfg, dp, data, sched.app, probe.walk_lines, probe.walk_go,
        probe.walk_tags, jnp.zeros((0,), jnp.int32), jnp.zeros((C,), bool),
        t)
    trans, tout = memsys.translation_commit(cfg, trans, probe, mem, sched, t)
    return trans, data, tout


def _datapath(cfg, data, params_mat, sched, t):
    """Data path in isolation (empty walk-lane group; see `_translation`)."""
    dp = design_params(cfg.design)
    front = memsys.datapath_front(cfg, params_mat, sched, t)
    data, mem = memsys.shared_memory_access(
        cfg, dp, data, sched.app, jnp.zeros((0,), jnp.int32),
        jnp.zeros((0,), bool), jnp.zeros((0,), jnp.int32), front.lines,
        front.go_l2d, t)
    return data, memsys._data_out(cfg, front, mem)


def test_translation_stage_cold_then_hot():
    """A translation-only cycle: cold request walks, refetch hits the L1."""
    cfg = SMALL
    trans, data = memsys.init_trans(cfg), memsys.init_data(cfg)
    tokens = tok_mod.init(cfg.n_apps,
                          jnp.asarray(cfg.warps_per_app, jnp.int32), 0.25)
    sched = _sched(cfg, [7, 7, 9, 9])
    trans, data, out = _translation(cfg, trans, data, tokens, sched,
                                    jnp.int32(1))
    assert not bool(out.l1_hit.any())
    assert bool(out.need_walk.all())
    assert np.all(np.asarray(out.trans_lat) > cfg.lat_l2_tlb)
    # the miss filled the per-core L1 bank: same request now hits locally
    _, _, out2 = _translation(cfg, trans, data, tokens, sched,
                              jnp.int32(2))
    assert bool(out2.l1_hit.all())
    assert not bool(out2.need_walk.any())
    assert np.all(np.asarray(out2.trans_lat) == cfg.lat_l1_tlb)


def test_translation_asid_isolation_in_l1_bank():
    """Same VPN, different app -> no cross-address-space L1/L2 hits."""
    cfg = SMALL
    trans, data = memsys.init_trans(cfg), memsys.init_data(cfg)
    tokens = tok_mod.init(cfg.n_apps,
                          jnp.asarray(cfg.warps_per_app, jnp.int32), 0.25)
    # cores 0/1 (app 0) request VPN 5; cores 2/3 (app 1) request VPN 6
    # (distinct sets: the shared L2 TLB takes one fill per set per cycle)
    sched = _sched(cfg, [5, 5, 6, 6])
    trans, data, _ = _translation(cfg, trans, data, tokens, sched,
                                  jnp.int32(1))
    occ = tlb_mod.occupancy_by_asid(trans.l2tlb, cfg.n_apps)
    assert occ.tolist() == [1, 1]
    # (5, asid 0) is resident, (5, asid 1) must NOT hit across ASIDs
    _, hit = tlb_mod.probe(trans.l2tlb, jnp.asarray([5, 5], jnp.int32),
                           jnp.asarray([0, 1], jnp.int32),
                           jnp.ones(2, bool), jnp.int32(2))
    assert bool(hit[0]) and not bool(hit[1])


# -------------------------------------------------------------- datapath

def test_datapath_stage_miss_latency():
    cfg = SMALL
    pm = app_matrix(["3DS", "BLK"])
    pm[:, FIELD["l1d_hit_milli"]] = 0             # force L1D misses
    data = memsys.init_data(cfg)
    data, out = _datapath(cfg, data, jnp.asarray(pm),
                          _sched(cfg, [7, 8, 9, 10]), jnp.int32(1))
    assert not bool(np.asarray(out.l1d_hit).any())
    assert int(np.asarray(out.go_l2d).sum()) == cfg.n_cores
    assert np.all(np.asarray(out.data_lat)
                  >= cfg.lat_l1_data + cfg.lat_l2_cache)


def test_datapath_stage_hit_latency():
    cfg = SMALL
    pm = app_matrix(["3DS", "BLK"])
    pm[:, FIELD["l1d_hit_milli"]] = 1024          # force L1D hits
    data = memsys.init_data(cfg)
    _, out = _datapath(cfg, data, jnp.asarray(pm),
                       _sched(cfg, [7, 8, 9, 10]), jnp.int32(1))
    assert bool(np.asarray(out.l1d_hit).all())
    assert not bool(np.asarray(out.go_l2d).any())
    assert np.all(np.asarray(out.data_lat) == cfg.lat_l1_data)


# ------------------------------------------------------ accumulate_stats

def test_stats_stage_buckets_by_app():
    C, na = 4, 2
    z = jnp.zeros(C, jnp.int32)
    zb = jnp.zeros(C, bool)
    zf = jnp.zeros(C, jnp.float32)
    sched = memsys.SchedOut(
        picked_warp=jnp.arange(C), slot=z,
        active=jnp.asarray([True, True, True, False]),
        app=jnp.asarray([0, 0, 1, 1]), asid=jnp.asarray([0, 0, 1, 1]),
        vpn=z, pos=z)
    tout = memsys.TransOut(
        trans_lat=z, l1_hit=jnp.asarray([True, False, True, True]),
        l1_miss=jnp.asarray([False, True, False, False]),
        l2_hit=zb, byp_hit=zb, l2_hit_eff=zb,
        need_walk=jnp.asarray([False, True, False, False]),
        merged=zb, new_walk=jnp.asarray([False, True, False, False]),
        walk_done_new=jnp.full((C,), 90, jnp.int32),
        dram_tlb_lat=zf, dram_tlb_n=z,
        l2c_hit=jnp.int32(3), l2c_probe=jnp.int32(4))
    dout = memsys.DataOut(data_lat=z, l1d_hit=zb, go_l2d=zb, dlat=z,
                          l2d_hit=zb)
    st = memsys.accumulate_stats(memsys.init_stats(na), na, sched, tout,
                                 dout, jnp.int32(10))
    assert st.s_l1_hit.tolist() == [1, 1]         # inactive core 3 ignored
    assert st.s_l1_miss.tolist() == [1, 0]
    assert st.s_l2_miss.tolist() == [1, 0]
    assert st.s_walks.tolist() == [1, 0]
    assert st.s_walk_lat.tolist() == [80.0, 0.0]  # walk_done_new - t
    assert int(st.s_l2c_tlb_hit) == 3 and int(st.s_l2c_tlb_probe) == 4


# ----------------------------------------------- vmapped L1 bank vs. old

def _old_probe(tags, asids, lru, vpn, asid, t):
    """The pre-refactor hand-rolled per-core L1 probe (reference)."""
    match = (tags == vpn[:, None]) & (asids == asid[:, None])
    hit = match.any(axis=1)
    way = jnp.argmax(match, axis=1)
    cidx = jnp.arange(tags.shape[0])
    lru = lru.at[cidx, way].set(jnp.where(hit, t, lru[cidx, way]))
    return hit, lru


def _old_fill(tags, asids, lru, vpn, asid, do_fill, t):
    """The pre-refactor hand-rolled per-core L1 fill (reference)."""
    victim = jnp.argmin(lru, axis=1)
    cidx = jnp.arange(tags.shape[0])
    sel = lambda new, old: jnp.where(do_fill, new, old)  # noqa: E731
    tags = tags.at[cidx, victim].set(sel(vpn, tags[cidx, victim]))
    asids = asids.at[cidx, victim].set(sel(asid, asids[cidx, victim]))
    lru = lru.at[cidx, victim].set(sel(t, lru[cidx, victim]))
    return tags, asids, lru


def test_l1_bank_matches_handrolled():
    """probe_bank/fill_bank replicate the old per-core L1 exactly: same
    per-step hits and identical final tags/asids/lru."""
    C, E, T = 3, 8, 200
    rng = np.random.RandomState(0)
    tags = jnp.full((C, E), -1, jnp.int32)
    asids = jnp.full((C, E), -1, jnp.int32)
    lru = jnp.zeros((C, E), jnp.int32)
    bank = tlb_mod.init_bank(C, E, E)
    active = jnp.ones(C, bool)
    for t in range(1, T + 1):
        vpn = jnp.asarray(rng.randint(0, 12, C), jnp.int32)
        asid = jnp.asarray(rng.randint(0, 2, C), jnp.int32)
        hit_old, lru = _old_probe(tags, asids, lru, vpn, asid, t)
        tags, asids, lru = _old_fill(tags, asids, lru, vpn, asid,
                                     active & ~hit_old, t)
        bank, hit_new = tlb_mod.probe_bank(bank, vpn, asid, active, t)
        bank = tlb_mod.fill_bank(bank, vpn, asid, active & ~hit_new, t)
        np.testing.assert_array_equal(np.asarray(hit_old),
                                      np.asarray(hit_new), err_msg=f"t={t}")
    np.testing.assert_array_equal(np.asarray(tags),
                                  np.asarray(bank.tags[:, 0]))
    np.testing.assert_array_equal(np.asarray(asids),
                                  np.asarray(bank.asids[:, 0]))
    np.testing.assert_array_equal(np.asarray(lru),
                                  np.asarray(bank.lru[:, 0]))


# --------------------------------------------------- N-app config/helpers

def test_config_app_partitions():
    cfg = SimConfig(n_apps=4)
    assert sum(cfg.cores_per_app) == cfg.n_cores
    assert sum(cfg.warps_per_app) == cfg.total_warps
    assert sorted(set(cfg.app_of_core)) == [0, 1, 2, 3]
    with pytest.raises(ValueError):
        SimConfig(n_apps=0)
    with pytest.raises(ValueError):
        SimConfig(n_apps=31)


def test_static_partition_slices_disjoint():
    idx = jnp.arange(200)
    for na in (2, 3, 4):
        slices = [set(np.asarray(
            static_partition_index(idx, 64, na, jnp.int32(a))).tolist())
            for a in range(na)]
        for i in range(na):
            assert max(slices[i]) <= 63 and min(slices[i]) >= 0
            for j in range(i + 1, na):
                assert not (slices[i] & slices[j])


def test_mix_workloads_seed_stable_and_nary():
    # pinned draw sequence: cached sweeps depend on it
    assert pair_workloads()[:3] == [("BFS2", "CONS"), ("MM", "NW"),
                                    ("RAY", "BLK")]
    mixes = mix_workloads(n_mixes=8, n_apps=3)
    assert len(mixes) == 8
    assert all(len(set(m)) == 3 for m in mixes)
    assert len({frozenset(m) for m in mixes}) == 8


def test_idle_row_matches_n_fields():
    assert IDLE_ROW.shape == (N_FIELDS,)
    assert IDLE_ROW[FIELD["gap"]] == 4000
    assert IDLE_ROW[FIELD["l1d_hit_milli"]] == 1024


# ------------------------------------------------------- runner invariants

def _reference_run(design_name, rows, cycles):
    """Independently-assembled 2-app run: explicit config, explicit params
    matrix, direct compiled-scan call — bypasses run_mix's plumbing so the
    wrapper equivalence tests are not tautologies."""
    from repro.sim import runner
    cfg = SimConfig(n_apps=len(rows), sim_cycles=cycles,
                    design=design(design_name))
    pm = jnp.asarray(np.stack(rows))
    return runner._stats(cfg, runner._compiled_run(cfg)(pm))


def test_run_mix_matches_run_pair_bitforbit():
    from repro.sim.workloads import make_app
    p = run_pair("mask", "3DS", "BLK", cycles=CYCLES)
    m = run_mix("mask", ["3DS", "BLK"], cycles=CYCLES)
    ref = _reference_run("mask", [make_app("3DS").as_array(),
                                  make_app("BLK").as_array()], CYCLES)
    for k in p:
        np.testing.assert_array_equal(np.asarray(p[k]), np.asarray(m[k]),
                                      err_msg=k)
        np.testing.assert_array_equal(np.asarray(m[k]), np.asarray(ref[k]),
                                      err_msg=f"ref:{k}")


def test_run_mix_idle_partner_matches_run_solo():
    from repro.sim.workloads import make_app
    s = run_solo("gpu-mmu", "3DS", cycles=CYCLES)
    m = run_mix("gpu-mmu", ["3DS", None], cycles=CYCLES)
    ref = _reference_run("gpu-mmu", [make_app("3DS").as_array(), IDLE_ROW],
                         CYCLES)
    for k in s:
        np.testing.assert_array_equal(np.asarray(s[k]), np.asarray(m[k]),
                                      err_msg=k)
        np.testing.assert_array_equal(np.asarray(m[k]), np.asarray(ref[k]),
                                      err_msg=f"ref:{k}")


def test_run_mix_three_apps_under_jit():
    benches = ["3DS", "HISTO", "BLK"]
    s = run_mix("mask", benches, cycles=CYCLES)
    assert s["ipc"].shape == (3,)
    assert s["l1_hit_rate"].shape == (3,)
    assert s["tokens"].shape == (3,)
    assert np.all(s["ipc"] > 0)
    for k, v in s.items():
        assert np.all(np.isfinite(np.asarray(v, np.float64))), k


# ------------------------------------------- design(name) compat vs goldens

# Golden stats for the pinned mix 3DS+BLK under the lane-fused memory
# path (PR 4; the pre-fusion sequential-round goldens lived at commit
# d64ae0d), captured on this container's jax/XLA CPU build. float.hex()
# encoding keeps the comparison bit-for-bit, not approximate. The
# `mask@9000` entry crosses an epoch boundary (epoch_cycles=8000) so the
# token hill-climb, bypass latch, and DRAM pressure-update paths are all
# pinned too. Any intentional semantic change must re-capture these AND
# bump benchmarks/paper_repro.CACHE_VERSION (see README "Performance").
GOLDEN = {
    'ideal': {
        'ipc': ['0x1.490aaaaaaaaabp+7', '0x1.5b4e81b4e81b5p+5'],
        'l2_hit_rate': ['0x0.0p+0', '0x0.0p+0'],
        'walk_lat': ['0x0.0p+0', '0x0.0p+0'],
        'byp_hit_rate': ['0x0.0p+0', '0x0.0p+0'],
        'tokens': ['0x1.e000000000000p+6', '0x1.e000000000000p+6'],
        'l2c_tlb_hit_rate': ['0x0.0p+0'],
    },
    'pwc': {
        'ipc': ['0x1.4e80000000000p+6', '0x1.bbd0369d0369dp+3'],
        'l2_hit_rate': ['0x0.0p+0', '0x0.0p+0'],
        'walk_lat': ['0x1.5026f7e1b0fb2p+7', '0x1.5aaa0a82a0a83p+8'],
        'byp_hit_rate': ['0x0.0p+0', '0x0.0p+0'],
        'tokens': ['0x1.e000000000000p+6', '0x1.e000000000000p+6'],
        'l2c_tlb_hit_rate': ['0x1.cb5d4ef40991fp-7'],
    },
    'gpu-mmu': {
        'ipc': ['0x1.642aaaaaaaaabp+6', '0x1.0951eb851eb85p+4'],
        'l2_hit_rate': ['0x1.54629b7f0d463p-2', '0x1.ce36b4175b466p-3'],
        'walk_lat': ['0x1.9d6e4630d013fp+7', '0x1.52af50af50af5p+8'],
        'byp_hit_rate': ['0x0.0p+0', '0x0.0p+0'],
        'tokens': ['0x1.e000000000000p+6', '0x1.e000000000000p+6'],
        'l2c_tlb_hit_rate': ['0x1.c94f90a5867d4p-1'],
    },
    'static': {
        'ipc': ['0x1.64aaaaaaaaaabp+6', '0x1.0951eb851eb85p+4'],
        'l2_hit_rate': ['0x1.5555555555555p-2', '0x1.d86d35d69602cp-3'],
        'walk_lat': ['0x1.9b3ae2a572bf1p+7', '0x1.5253aa554440ep+8'],
        'byp_hit_rate': ['0x0.0p+0', '0x0.0p+0'],
        'tokens': ['0x1.e000000000000p+6', '0x1.e000000000000p+6'],
        'l2c_tlb_hit_rate': ['0x1.c90abcc0242afp-1'],
    },
    'mask': {
        'ipc': ['0x1.62c0000000000p+6', '0x1.08bbbbbbbbbbcp+4'],
        'l2_hit_rate': ['0x1.53bd02647c694p-2', '0x1.d0d68a67435a3p-3'],
        'walk_lat': ['0x1.a000000000000p+7', '0x1.53c5f46414040p+8'],
        'byp_hit_rate': ['0x0.0p+0', '0x0.0p+0'],
        'tokens': ['0x1.e000000000000p+6', '0x1.e000000000000p+6'],
        'l2c_tlb_hit_rate': ['0x1.c922d719c060fp-1'],
    },
    'mask-tlb': {
        'ipc': ['0x1.642aaaaaaaaabp+6', '0x1.0951eb851eb85p+4'],
        'l2_hit_rate': ['0x1.54629b7f0d463p-2', '0x1.ce36b4175b466p-3'],
        'walk_lat': ['0x1.9d6e4630d013fp+7', '0x1.52af50af50af5p+8'],
        'byp_hit_rate': ['0x0.0p+0', '0x0.0p+0'],
        'tokens': ['0x1.e000000000000p+6', '0x1.e000000000000p+6'],
        'l2c_tlb_hit_rate': ['0x1.c94f90a5867d4p-1'],
    },
    'mask-cache': {
        'ipc': ['0x1.642aaaaaaaaabp+6', '0x1.0951eb851eb85p+4'],
        'l2_hit_rate': ['0x1.54629b7f0d463p-2', '0x1.ce36b4175b466p-3'],
        'walk_lat': ['0x1.9d6e4630d013fp+7', '0x1.52af50af50af5p+8'],
        'byp_hit_rate': ['0x0.0p+0', '0x0.0p+0'],
        'tokens': ['0x1.e000000000000p+6', '0x1.e000000000000p+6'],
        'l2c_tlb_hit_rate': ['0x1.c94f90a5867d4p-1'],
    },
    'mask-dram': {
        'ipc': ['0x1.62c0000000000p+6', '0x1.08bbbbbbbbbbcp+4'],
        'l2_hit_rate': ['0x1.53bd02647c694p-2', '0x1.d0d68a67435a3p-3'],
        'walk_lat': ['0x1.a000000000000p+7', '0x1.53c5f46414040p+8'],
        'byp_hit_rate': ['0x0.0p+0', '0x0.0p+0'],
        'tokens': ['0x1.e000000000000p+6', '0x1.e000000000000p+6'],
        'l2c_tlb_hit_rate': ['0x1.c922d719c060fp-1'],
    },
    'mask@9000': {
        'ipc': ['0x1.712aaaaaaaaabp+6', '0x1.5575a56ed1ce6p+4'],
        'l2_hit_rate': ['0x1.3aab8f24fb8c7p-2', '0x1.06a395c6a395cp-2'],
        'walk_lat': ['0x1.36f44b13ee32bp+7', '0x1.76877d6dc735ep+7'],
        'byp_hit_rate': ['0x1.0d29dde11c5eep-6', '0x1.6067bb6ff2802p-8'],
        'tokens': ['0x1.e000000000000p+6', '0x1.e000000000000p+6'],
        'l2c_tlb_hit_rate': ['0x1.de0d0f208e060p-1'],
    },
}


@pytest.mark.parametrize("entry", sorted(GOLDEN))
def test_design_bitforbit_vs_goldens(entry):
    """Every registered design reproduces its pinned float-hex golden
    bit-for-bit (catches unintentional drift anywhere in the pipeline)."""
    name, _, cyc = entry.partition("@")
    s = run_mix(name, ["3DS", "BLK"], cycles=int(cyc) if cyc else 1200)
    for key, want in GOLDEN[entry].items():
        got = [x.hex() for x in
               np.asarray(s[key], np.float64).ravel().tolist()]
        assert got == want, f"{entry}:{key} drifted: {got} != {want}"


def test_design_shim_legacy_fields_pinned():
    """The registry-served designs expose exactly the legacy DesignPoint
    field values of the pre-redesign table (pinned here verbatim)."""
    from repro.core.mask import ALL_DESIGNS, MaskConfig, design
    base_off = MaskConfig(tlb_tokens=False, l2_bypass=False,
                          dram_sched=False)
    expect = {
        # name: (use_l2_tlb, use_pwc, ideal_tlb, static_partition, mask)
        "ideal": (True, False, True, False, base_off),
        "pwc": (False, True, False, False, base_off),
        "gpu-mmu": (True, False, False, False, base_off),
        "static": (True, False, False, True, base_off),
        "mask": (True, False, False, False, MaskConfig()),
        "mask-tlb": (True, False, False, False, MaskConfig(
            tlb_tokens=True, l2_bypass=False, dram_sched=False)),
        "mask-cache": (True, False, False, False, MaskConfig(
            tlb_tokens=False, l2_bypass=True, dram_sched=False)),
        "mask-dram": (True, False, False, False, MaskConfig(
            tlb_tokens=False, l2_bypass=False, dram_sched=True)),
    }
    assert set(ALL_DESIGNS) == set(expect)
    for name, (l2, pwc, ideal, static, mask_cfg) in expect.items():
        d = design(name)
        assert d.name == name
        assert (d.use_l2_tlb, d.use_pwc, d.ideal_tlb,
                d.static_partition) == (l2, pwc, ideal, static), name
        assert d.mask == mask_cfg, name
