"""Simulator invariants (short runs — the full sweep lives in benchmarks/)."""
import numpy as np
import pytest

from repro.sim.runner import run_batch
from repro.sim.workloads import (BENCHES, CATEGORY, app_matrix, hmr_class,
                                 pair_workloads)

CYCLES = 16_000


@pytest.fixture(scope="module")
def short_runs():
    pairs = [("3DS", None), ("3DS", "BLK")]
    out = {}
    for d in ("ideal", "gpu-mmu", "mask"):
        out[d] = run_batch(d, pairs, cycles=CYCLES)
    return out


def test_ideal_dominates(short_runs):
    """No-translation-overhead IPC is an upper bound per workload."""
    for i in range(2):
        ideal = short_runs["ideal"][i]["ipc"][0]
        for d in ("gpu-mmu", "mask"):
            assert short_runs[d][i]["ipc"][0] <= ideal * 1.02


def test_sharing_thrashes_shared_tlb(short_runs):
    """Fig. 7: co-running inflates the shared-TLB miss rate."""
    solo = short_runs["gpu-mmu"][0]["l2_hit_rate"][0]
    pair = short_runs["gpu-mmu"][1]["l2_hit_rate"][0]
    assert pair < solo


def test_stats_finite(short_runs):
    for d, runs in short_runs.items():
        for s in runs:
            for k, v in s.items():
                arr = np.asarray(v, np.float64)
                assert np.all(np.isfinite(arr)), (d, k)


def test_tokens_bounded(short_runs):
    toks = short_runs["mask"][1]["tokens"]
    assert np.all(toks >= 1) and np.all(toks <= 480)


def test_walks_happen_and_cost(short_runs):
    s = short_runs["gpu-mmu"][1]
    assert s["walks"][0] > 100
    assert s["walk_lat"][0] > 30


def test_pair_sampling():
    pairs = pair_workloads()
    assert len(pairs) == 35
    assert all(CATEGORY[a] != ("low", "low") and CATEGORY[b] != ("low", "low")
               for a, b in pairs)
    assert {hmr_class(p) for p in pairs} <= {0, 1, 2}


def test_app_matrix_shapes():
    m = app_matrix(BENCHES)
    assert m.shape == (27, 10)
    assert m.min() >= 0
