"""Segmented churn runner: bitwise equivalence + teardown semantics.

The acceptance gates for `runner.run_trace`:

  * a K-segment run with CONSTANT membership is float-hex identical to
    the monolithic `run_mix` of the same total cycle count — across all
    8 builtin designs and n_apps in {2, 3}, and across different segment
    splits of the same run;
  * a mid-trace departure performs a real ASID shootdown: no translation
    for the departed generation survives anywhere in the hierarchy, and
    the slot's successor runs on a FRESH address-space generation;
  * the whole schedule (membership, change masks, fault operands, K) is
    data — different schedules of the same shape share one compiled
    segment executable.
"""
import jax
import numpy as np
import pytest

from repro.core.design import BUILTIN_DESIGNS
from repro.sim import runner
from repro.sim.runner import run_mix, run_trace
from repro.sim.workloads import BENCHES, CATEGORY, churn_schedule

MIX2 = ("3DS", "BLK")
MIX3 = ("3DS", "BLK", "MUM")


def _hex(stats) -> dict:
    """Bit-exact representation of a stats dict (float-hex-equivalent)."""
    return {k: np.asarray(v).tobytes() for k, v in sorted(stats.items())}


@pytest.mark.parametrize("mix", [MIX2, MIX3], ids=["2app", "3app"])
@pytest.mark.parametrize("design", [d.name for d in BUILTIN_DESIGNS])
def test_constant_membership_segments_bitwise(design, mix):
    K, seg = 3, 150
    mono = run_mix(design, list(mix), cycles=K * seg)
    tr = run_trace(design, [mix] * K, seg_cycles=seg)
    assert _hex(mono) == _hex(tr.stats)


def test_segment_split_invariance():
    """Different K-splits of the same total are all bitwise equal."""
    total = 360
    mono = run_mix("mask", list(MIX2), cycles=total)
    for k in (2, 4):
        tr = run_trace("mask", [MIX2] * k, seg_cycles=total // k)
        assert _hex(mono) == _hex(tr.stats), f"K={k}"


def test_per_segment_snapshots():
    tr = run_trace("mask", [MIX2] * 3, seg_cycles=150)
    assert len(tr.segments) == 3
    assert [s["cycles"] for s in tr.segments] == [150.0, 300.0, 450.0]
    assert _hex(tr.segments[-1]) == _hex(tr.stats)
    lean = run_trace("mask", [MIX2] * 3, seg_cycles=150,
                     collect_segments=False)
    assert lean.segments == () and _hex(lean.stats) == _hex(tr.stats)


def test_departure_triggers_asid_shootdown():
    """After a slot departs, NO translation of the departed generation
    survives in the L1 bank, shared L2 TLB, bypass cache, or the walk
    table — and the successor occupies a fresh generation."""
    tr = run_trace("mask",
                   [("3DS", "BLK"), ("3DS", None), ("3DS", "MUM")],
                   seg_cycles=300, return_state=True)
    st = jax.device_get(tr.final_state)
    # slot 1: gen 0 (BLK, asid 1) -> gen 1 (idle, asid 3) -> gen 2 (MUM,
    # asid 5); slot 0 never changed (asid 0)
    assert st.asid_of_app.tolist() == [0, 5]
    dead = (1, 3)
    for name in ("l1", "l2tlb", "bypass_tlb"):
        tlb = getattr(st.trans, name)
        stale = np.isin(np.asarray(tlb.asids), dead) & \
            (np.asarray(tlb.tags) >= 0)
        assert not stale.any(), f"stale {name} translations for dead ASIDs"
    assert not np.isin(np.asarray(st.trans.walk)[:, 1], dead).any(), \
        "walk table still references a departed ASID"
    # the survivor and the arrival both made progress
    assert tr.stats["ipc"][0] > 0 and np.isfinite(tr.stats["ipc"]).all()


def test_arrival_into_idle_slot_runs_cold():
    """None -> bench arrival: the slot starts cold (fresh generation)
    but executes; bench -> same bench across a boundary is NOT a change
    (bitwise-identical to no boundary at all)."""
    tr = run_trace("gpu-mmu", [("3DS", None), ("3DS", "BLK")],
                   seg_cycles=300, return_state=True)
    st = jax.device_get(tr.final_state)
    assert st.asid_of_app.tolist() == [0, 3]
    assert tr.stats["ipc"][1] > 0


def test_schedules_share_one_compiled_executable():
    # unique seg_cycles so this test owns its compile-cache entry
    seg = 170
    t0 = runner.TRACE_COUNT
    run_trace("mask", [MIX2, MIX2, ("3DS", None)], seg_cycles=seg)
    after_first = runner.TRACE_COUNT
    # different membership timeline, different K: same executable
    run_trace("mask", [("MUM", "RED")] * 5, seg_cycles=seg)
    run_trace("mask-tlb", [MIX2, ("BLK", "3DS")], seg_cycles=seg)
    assert after_first - t0 == 1
    assert runner.TRACE_COUNT == after_first, \
        "a schedule/design in the same signature group retraced"


def test_schedule_validation():
    with pytest.raises(ValueError, match="at least one segment"):
        run_trace("mask", [])
    with pytest.raises(ValueError, match="same slot count"):
        run_trace("mask", [("3DS", "BLK"), ("3DS",)])
    with pytest.raises(ValueError, match="seg_cycles"):
        run_trace("mask", [MIX2], seg_cycles=0)


def test_churn_schedule_generator():
    a = churn_schedule(seed=9, n_segments=6, n_slots=3)
    b = churn_schedule(seed=9, n_segments=6, n_slots=3)
    assert a == b, "churn_schedule must be deterministic in seed"
    assert len(a) == 6 and all(len(s) == 3 for s in a)
    assert any(x is not None for x in a[0]), "segment 0 never fully idle"
    pool = {x for x in BENCHES if CATEGORY[x] != ("low", "low")}
    assert {x for s in a for x in s if x is not None} <= pool
    assert a != churn_schedule(seed=10, n_segments=6, n_slots=3)
