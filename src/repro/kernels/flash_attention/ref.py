"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal=True, window: Optional[int] = None):
    """q: (B, H, Sq, dh); k, v: (B, KV, Sk, dh). fp32 softmax."""
    B, H, Sq, dh = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, Sq, dh)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / (dh ** 0.5)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p.astype(v.dtype), v)
    return o.reshape(B, H, Sq, dh)
