"""Oracle: the core TLB module's probe+fill pair."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import tlb as tlb_mod


def tlb_probe_fill_ref(tags, asids, lru, vpn, asid, active, time):
    st = tlb_mod.TLBState(tags=tags, asids=asids, lru=lru,
                          hits=jnp.zeros((), jnp.int32),
                          misses=jnp.zeros((), jnp.int32))
    st, hit = tlb_mod.probe(st, vpn, asid, active, time)
    st = tlb_mod.fill(st, vpn, asid, active & ~hit, time)
    return st.tags, st.asids, st.lru, hit.astype(jnp.int32)
