"""MASK policy bundle: configuration + composed state for the three
mechanisms (TLB-Fill Tokens, TLB-Request-Aware L2 Bypass, Address-Space-
Aware DRAM scheduler). Used by both the simulator (repro.sim) and the
serving memory manager (repro.memmgr)."""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax.numpy as jnp

from repro.core import bypass as bypass_mod
from repro.core import dram_sched
from repro.core import tlb as tlb_mod
from repro.core import tokens as tokens_mod


@dataclasses.dataclass(frozen=True)
class MaskConfig:
    """Feature switches + sizing (defaults = paper Table 1 / §5)."""

    # components (ablations: MASK-TLB / MASK-Cache / MASK-DRAM)
    tlb_tokens: bool = True
    l2_bypass: bool = True
    dram_sched: bool = True
    # translation caches
    l1_tlb_entries: int = 64        # fully associative, per core
    l2_tlb_entries: int = 512       # 16-way, ASID-tagged, shared
    l2_tlb_ways: int = 16
    bypass_cache_entries: int = 32  # fully associative
    # policies
    epoch_cycles: int = 8_000       # paper: 100K; scaled to sim length
    # paper initializes at 0.8 and reports <1% sensitivity — with 100K-cycle
    # epochs the climb converges from anywhere. Our runs see ~7 epochs, so
    # we start near the converged region (the scaled equivalent).
    initial_token_frac: float = 0.25
    token_step_frac: float = 0.5    # geometric hill-climb step
    thres_max: int = 500
    # page walk
    walk_levels: int = 4
    max_concurrent_walks: int = 64


@dataclasses.dataclass(frozen=True)
class DesignPoint:
    """Named baseline/design selections used across benchmarks."""

    name: str
    use_l2_tlb: bool = True          # shared L2 TLB (Fig. 2b) vs PWC (Fig. 2a)
    use_pwc: bool = False            # page-walk cache design
    mask: MaskConfig = MaskConfig(tlb_tokens=False, l2_bypass=False,
                                  dram_sched=False)
    ideal_tlb: bool = False          # every TLB access hits
    static_partition: bool = False   # L2$/DRAM statically split per app


def static_partition_index(index, n_resources: int, n_apps: int, app):
    """Static resource partitioning (the `Static` design, §6): app `a` owns
    a contiguous ~1/n_apps slice of an index space (L2 sets, DRAM channels).
    Slice bounds are proportional ((a*n)//n_apps .. ((a+1)*n)//n_apps) so no
    trailing resources are stranded when n_apps does not divide n_resources;
    if there are fewer resources than apps the slice floor is one unit and
    the result clips into range.

    index/app may be traced arrays; n_resources/n_apps are static ints.
    """
    na = max(n_apps, 1)
    start = (app * n_resources) // na
    span = jnp.maximum((app + 1) * n_resources // na - start, 1)
    return jnp.minimum(start + index % span, n_resources - 1)


def design(name: str) -> DesignPoint:
    base_off = MaskConfig(tlb_tokens=False, l2_bypass=False, dram_sched=False)
    table = {
        "ideal": DesignPoint("ideal", ideal_tlb=True, mask=base_off),
        "pwc": DesignPoint("pwc", use_l2_tlb=False, use_pwc=True,
                           mask=base_off),
        "gpu-mmu": DesignPoint("gpu-mmu", mask=base_off),
        "static": DesignPoint("static", static_partition=True, mask=base_off),
        "mask": DesignPoint("mask", mask=MaskConfig()),
        "mask-tlb": DesignPoint("mask-tlb", mask=MaskConfig(
            tlb_tokens=True, l2_bypass=False, dram_sched=False)),
        "mask-cache": DesignPoint("mask-cache", mask=MaskConfig(
            tlb_tokens=False, l2_bypass=True, dram_sched=False)),
        "mask-dram": DesignPoint("mask-dram", mask=MaskConfig(
            tlb_tokens=False, l2_bypass=False, dram_sched=True)),
    }
    return table[name]


ALL_DESIGNS = ("ideal", "pwc", "gpu-mmu", "static", "mask",
               "mask-tlb", "mask-cache", "mask-dram")
