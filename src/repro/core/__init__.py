"""MASK core: the paper's contribution as composable pure-JAX policy modules.

  asid        — address spaces / protection domains (§5.1)
  page_table  — multi-level radix walks, PTE line addressing (§3)
  tlb         — set-associative ASID-tagged TLB state (L1/L2/bypass cache)
  tokens      — TLB-Fill Tokens epoch controller (§5.2)
  bypass      — TLB-request-aware L2 data-cache bypass (§5.3)
  dram_sched  — golden/silver/normal scheduler with Eq. (1) quotas (§5.4)
  mask        — MaskConfig + named design points (ablation grid)
"""
from repro.core.mask import ALL_DESIGNS, DesignPoint, MaskConfig, design  # noqa: F401
