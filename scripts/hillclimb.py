"""§Perf hillclimb driver: lower variants of the three chosen cells and log
(hypothesis, change, before, after) rows into reports/perf/.

Usage: PYTHONPATH=src python scripts/hillclimb.py <exp> [<exp> ...]
"""
import os

# the dryrun lowering wants many host devices, but a user's pre-set
# XLA_FLAGS (e.g. compiler tuning from CI or a sweep wrapper) must
# survive: append rather than clobber, and leave an existing
# force-host-device-count choice alone
_FORCE = "--xla_force_host_platform_device_count"
_flags = os.environ.get("XLA_FLAGS", "")
if _FORCE not in _flags:
    os.environ["XLA_FLAGS"] = (_flags + " " if _flags else "") + \
        f"{_FORCE}=512"

import dataclasses
import json
import sys
from pathlib import Path

from repro.configs import get_run_config
from repro.launch.dryrun import lower_cell

PERF_DIR = Path(__file__).resolve().parent.parent / "reports" / "perf"


def record(cell, tag, rep, hypothesis):
    PERF_DIR.mkdir(parents=True, exist_ok=True)
    rep["hypothesis"] = hypothesis
    (PERF_DIR / f"{cell}__{tag}.json").write_text(
        json.dumps(rep, indent=2, default=str))
    rf = rep.get("roofline", {})
    print(f"[{cell} :: {tag}]")
    print(f"  compute={rf.get('compute_s', 0):.4e} memory={rf.get('memory_s', 0):.4e} "
          f"coll={rf.get('collective_s', 0):.4e} useful={rf.get('useful_flops_ratio', 0):.3f} "
          f"hbm={rep.get('hbm_per_device_bytes', 0)/1e9:.2f}GB", flush=True)


def olmoe_decode_group_merge():
    """A1: decode capacity padding. Per-seq groups at S=1 round capacity to
    8 slots/expert/seq => 64x padded expert compute (useful=0.024). Merging
    the whole decode batch into ONE routing group gives cap ~ B*topk/E*1.25
    => predicted useful ~0.6 and the MoE buffer ops shrink ~25x."""
    rep = lower_cell("olmoe-1b-7b", "decode_32k", False, tag="A1_group_merge")
    record("olmoe-1b-7b__decode_32k", "A1_group_merge", rep,
           "merge decode batch into one MoE routing group")


def mistral_decode_relax_batch():
    """C1: decode is collective-bound by FSDP weight all-gathers (30 GB/dev
    per token step) because activations are PINNED batch->data at every
    layer, forcing XLA to move weights instead of the (tiny) activations.
    Relaxing the batch constraint on non-cache activations lets SPMD
    all-gather x (~3 MB) and psum partials instead. Predicted: all-gather
    bytes drop ~50x; memory term becomes dominant."""
    run = get_run_config("mistral-large-123b", "decode_32k")
    run = dataclasses.replace(run, decode_relax_batch=True)
    rep = lower_cell("mistral-large-123b", "decode_32k", False,
                     run_override=run, tag="C1_relax_batch")
    record("mistral-large-123b__decode_32k", "C1_relax_batch", rep,
           "unpin batch->data on decode activations (keep cache sharded)")


def mistral_decode_int8():
    """C2: int8 weight-only decode. Baseline is collective-bound by per-token
    FSDP weight gathers (15.5 GB f32 / 7.75 GB bf16 per step) because bf16
    TP-only params (15.4 GB) + KV (5.9 GB) exceed 16 GB/chip. int8 weights
    (7.7 GB TP-only) fit residently: predicted collective term -> ~0,
    memory term -> KV 5.9 GB + weights 7.7 GB ≈ 17 ms/step."""
    run = get_run_config("mistral-large-123b", "decode_32k")
    run = dataclasses.replace(run, quantize_weights=True, fsdp=False)
    rep = lower_cell("mistral-large-123b", "decode_32k", False,
                     run_override=run, tag="C2_int8")
    record("mistral-large-123b__decode_32k", "C2_int8", rep,
           "int8 weight-only decode; drop FSDP (weights fit TP-only)")


def mixtral_train_bf16_grads():
    """B1: mixtral train all-reduce volume is 2.24 TB/dev — mostly f32 MoE
    cotangent psums + fp32 paths around the dispatch gathers. Forcing the
    dispatch gather operands shard-aligned (constraints added in moe.py) and
    verifying the 'Involuntary full rematerialization' warning disappears
    should cut all-gather traffic."""
    rep = lower_cell("mixtral-8x22b", "train_4k", False, tag="B1_recheck")
    record("mixtral-8x22b__train_4k", "B1_recheck", rep,
           "re-measure after slot-table dispatch (gathers shard-aligned)")


EXPS = {
    "A1": olmoe_decode_group_merge,
    "C1": mistral_decode_relax_batch,
    "C2": mistral_decode_int8,
    "B1": mixtral_train_bf16_grads,
}

if __name__ == "__main__":
    for name in (sys.argv[1:] or list(EXPS)):
        EXPS[name]()
