"""Simulation runner: N-app mixes, solo/pair wrappers, typed experiments.

Two API levels share one compiled core:

* Raw: `run_mix(design, benches)` co-runs len(benches) applications (None
  entries are idle partners) and returns a per-app stats dict.
  `run_pair` / `run_solo` are thin 2-app wrappers kept for the paper's
  pair-based experiments; `run_batch` vmaps many same-size mixes through
  one compile. `design` is a registered name, a `repro.core.design.Design`
  (including user-registered or ad-hoc compositions), or a legacy
  `DesignPoint`.

* Typed: `Experiment(design, mixes, cycles).run()` returns an
  `ExperimentResult` of `MixResult`/`AppStats` objects with the derived
  metrics (weighted speedup, unfairness, per-app hit rates) as
  methods/properties; `sweep(designs, mixes)` drives many designs.

Compilation is keyed on the design's STATIC SIGNATURE, not the design:
a design's dynamic knobs travel as a traced `DesignParams` plane (see
`repro.core.design`), so every design in a signature group shares one
executable, and `run_grid` / `sweep` stack (DesignParams, workload)
rows along a vmapped grid axis — one compile and ONE device execution
per (signature, n_apps) for a whole design x mix grid. The grid path
is bit-for-bit identical to running the designs one by one (pinned by
tests against the float-hex goldens).
"""
from __future__ import annotations

import dataclasses
import functools
import os
from typing import Dict, List, Mapping, NamedTuple, Optional, Sequence, \
    Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.design import (Design, as_design, canonical_design,
                               design_params, static_signature)
from repro.sim import faults as faults_mod
from repro.sim.config import SimConfig
from repro.sim.memsys import (SimState, apply_membership_change, init_state,
                              step)
from repro.sim.workloads import app_matrix

jax.config.update("jax_enable_x64", False)

DesignLike = Union[str, Design]  # legacy DesignPoint also accepted

# incremented every time a simulator program is traced for compilation
# (once per jit/vmap wrapper; re-executions hit the cache and do not
# bump it) — tests assert "one trace per signature group" against this
TRACE_COUNT = 0


def _canonical(cfg: SimConfig) -> SimConfig:
    """Replace the embedded design by its signature group's canonical
    representative: the compile-cache key for everything below. The
    fault plan is stripped too — fault operands are shape-stable data
    (`sim.faults`), so every chaos plan (and no plan) shares the one
    compiled trace of its signature group."""
    return dataclasses.replace(
        cfg, design=canonical_design(static_signature(cfg.design)),
        fault_plan=None)


def _run_fn(cfg: SimConfig):
    """The raw (DesignParams, params_mat) -> final-state scan.

    `cfg` must be canonical — the stages read only static-signature
    fields from it; every dynamic knob comes from the traced `dp`."""
    def run(dp, params_mat):
        global TRACE_COUNT
        TRACE_COUNT += 1              # runs at trace time only
        st = init_state(cfg, dp)

        def body(s, _):
            return step(cfg, dp, params_mat, s), None

        final, _ = jax.lax.scan(body, st, None, length=cfg.sim_cycles)
        return final

    return run


@functools.lru_cache(maxsize=64)
def _compiled_sig_run(ccfg: SimConfig):
    """One compiled (dp, pm) executable per (signature, SimConfig)."""
    return jax.jit(_run_fn(ccfg))


@functools.lru_cache(maxsize=64)
def _compiled_sig_batch_run(ccfg: SimConfig):
    """One design, many mixes: vmap over the workload axis only."""
    return jax.jit(jax.vmap(_run_fn(ccfg), in_axes=(None, 0)))


@functools.lru_cache(maxsize=64)
def _compiled_grid_run(ccfg: SimConfig):
    """Design x mix grid: vmap over stacked (DesignParams, params_mat)
    rows — one execution services every design of a signature group."""
    return jax.jit(jax.vmap(_run_fn(ccfg), in_axes=(0, 0)))


@functools.lru_cache(maxsize=64)
def _compiled_seg_run(ccfg: SimConfig):
    """One compiled SEGMENT executable per (signature, n_apps,
    seg_cycles): membership-change teardown + boundary faults + a
    seg_cycles scan, carrying `SimState` in and out.

    Everything that varies across a trace — the segment's workload rows,
    the change mask, the fault operands, K itself — is data, so a whole
    churn schedule (and every schedule of the same shape) replays through
    this one trace. With an all-False change mask and empty fault
    operands the boundary ops are bitwise identity, which is what makes
    constant-membership segmented runs float-hex equal to the monolithic
    scan."""
    def seg(dp, params_mat, state, change, fops: faults_mod.FaultOps):
        global TRACE_COUNT
        TRACE_COUNT += 1              # runs at trace time only
        st = apply_membership_change(ccfg, dp, state, change | fops.kill)
        st = faults_mod.apply_state_faults(ccfg, st, fops)

        def body(s, _):
            return step(ccfg, dp, params_mat, s), None

        final, _ = jax.lax.scan(body, st, None, length=ccfg.sim_cycles)
        return final

    return jax.jit(seg)


@functools.lru_cache(maxsize=128)
def _compiled_run(cfg: SimConfig):
    """Back-compat pm-only callable for one design; shares the signature
    group's executable (distinct designs, one compile)."""
    return functools.partial(_compiled_sig_run(_canonical(cfg)),
                             design_params(cfg.design))


@functools.lru_cache(maxsize=128)
def _compiled_batch_run(cfg: SimConfig):
    """vmapped over a leading batch of workload parameter matrices — one
    executable serves every mix/solo under the design's signature."""
    return functools.partial(_compiled_sig_batch_run(_canonical(cfg)),
                             design_params(cfg.design))


class ZeroCycleError(RuntimeError):
    """A stats request for a run that simulated no cycles (IPC undefined)."""


class NonFiniteStatsError(RuntimeError):
    """Per-app counters came back NaN/inf — corrupt state, not a metric."""


def _audit_enabled(audit: Optional[bool]) -> bool:
    """None defers to env REPRO_AUDIT; True/False force it on/off."""
    if audit is not None:
        return audit
    return os.environ.get("REPRO_AUDIT", "") in ("1", "true", "yes")


def _stats(cfg: SimConfig, st: SimState,
           audit: Optional[bool] = None) -> Dict[str, np.ndarray]:
    # one bulk transfer for the whole state tree (no-op on numpy trees,
    # e.g. the per-mix slices run_batch hands over)
    st = jax.device_get(st)
    if _audit_enabled(audit):
        from repro.sim.audit import check_state
        check_state(cfg, st)
    na = cfg.n_apps
    warp_app = np.repeat(np.asarray(cfg.app_of_core), cfg.warps_per_core)
    t = float(st.t)
    if not t > 0:
        raise ZeroCycleError(
            f"cannot derive per-app IPC from a {t:.0f}-cycle run "
            f"(design={cfg.design.name!r}): IPC = instructions / cycles "
            "would be NaN/inf and silently poison weighted_speedup / "
            "unfairness downstream — run with cycles >= 1")
    ipc = np.bincount(warp_app, weights=st.instr, minlength=na) / t
    if not np.all(np.isfinite(ipc)):
        raise NonFiniteStatsError(
            f"non-finite per-app IPC {ipc} after {t:.0f} cycles "
            f"(design={cfg.design.name!r}): the retired-instruction "
            "counters are corrupt (overflow or injected fault); refusing "
            "to propagate NaN into weighted_speedup / unfairness")
    s = st.stats
    g = lambda x: np.asarray(x, np.float64)  # noqa: E731
    l1p = g(s.s_l1_hit) + g(s.s_l1_miss)
    l2p = g(s.s_l2_hit) + g(s.s_l2_miss)
    return {
        "ipc": ipc,
        "l1_hit_rate": g(s.s_l1_hit) / np.maximum(l1p, 1),
        "l1_miss_rate": g(s.s_l1_miss) / np.maximum(l1p, 1),
        "l2_hit_rate": g(s.s_l2_hit) / np.maximum(l2p, 1),
        "l2_miss_rate": g(s.s_l2_miss) / np.maximum(l2p, 1),
        "byp_hit_rate": g(s.s_byp_hit) / np.maximum(g(s.s_byp_probe), 1),
        "walk_lat": g(s.s_walk_lat) / np.maximum(g(s.s_walks), 1),
        "walks": g(s.s_walks),
        "stalls_per_miss": g(s.s_stall_per_miss) / np.maximum(g(s.s_walks), 1),
        "dram_tlb_lat": g(s.s_dram_tlb_lat) / np.maximum(g(s.s_dram_tlb_n), 1),
        "dram_data_lat": g(s.s_dram_data_lat)
        / np.maximum(g(s.s_dram_data_n), 1),
        "dram_tlb_n": g(s.s_dram_tlb_n),
        "dram_data_n": g(s.s_dram_data_n),
        # L2 data-cache hit rate for TLB requests (Table 5). np.maximum
        # (not builtin max) so these survive the counters going per-app.
        "l2c_tlb_hit_rate": (g(s.s_l2c_tlb_hit)
                             / np.maximum(g(s.s_l2c_tlb_probe), 1)),
        "l2c_data_hit_rate": (g(s.s_l2c_data_hit)
                              / np.maximum(g(s.s_l2c_data_probe), 1)),
        "tokens": np.asarray(st.tokens.tokens),
        "cycles": float(st.t),
    }


def _mix_matrix(benches: Sequence[Optional[str]]) -> np.ndarray:
    """(n_apps, N_FIELDS) parameter matrix; None entries are idle apps."""
    return app_matrix(list(benches))


def _row_sharding(devices: int):
    """NamedSharding splitting a leading "rows" axis over `devices`.

    Grid rows are fully independent under vmap (no cross-row ops, so no
    collectives): placing the stacked (DesignParams, pm) rows on a 1-D
    device mesh makes XLA partition the whole scanned program row-wise —
    same math per row, so results stay bit-for-bit equal to the
    single-device path. More devices than are visible is an error; spawn
    a subprocess with `XLA_FLAGS=--xla_force_host_platform_device_count=N`
    to split a CPU host (see tests/test_sharded_grid.py).
    """
    devs = jax.devices()
    if devices > len(devs):
        raise ValueError(
            f"devices={devices} but only {len(devs)} JAX devices visible; "
            "on CPU, relaunch with XLA_FLAGS="
            f"--xla_force_host_platform_device_count={devices}")
    mesh = jax.sharding.Mesh(np.asarray(devs[:devices]), ("rows",))
    return jax.sharding.NamedSharding(mesh,
                                      jax.sharding.PartitionSpec("rows"))


def _pad_rows(tree, multiple: int):
    """Pad every leaf's leading axis up to a multiple of `multiple` by
    repeating the first rows; returns (padded_tree, real_row_count).

    Repeated leading rows keep every row a valid simulation (no NaN/zero
    design surprises); callers slice results back to the real count.
    """
    rows = jax.tree_util.tree_leaves(tree)[0].shape[0]
    pad = (-rows) % multiple
    if pad:
        tree = jax.tree_util.tree_map(
            lambda x: jnp.concatenate([x, x[:pad]], axis=0), tree)
    return tree, rows


def run_mix(design: DesignLike, benches: Sequence[Optional[str]],
            cycles: int = 60_000) -> Dict:
    """Co-run N apps under a design; returns per-app stats.

    `benches` may contain None for idle partners (the §6 `IPC_alone`
    emulation keeps the core split of the shared run but removes memory
    contention from the partner slots).
    """
    cfg = SimConfig(n_apps=len(benches), sim_cycles=cycles,
                    design=as_design(design))
    pm = jnp.asarray(_mix_matrix(benches))
    st = _compiled_run(cfg)(pm)
    return _stats(cfg, st)


@dataclasses.dataclass(frozen=True, eq=False)
class TraceResult:
    """A segmented churn run: final stats + per-boundary snapshots.

    `stats` is the run_mix-shaped dict of the FINAL state — for a
    constant-membership schedule it is float-hex identical to
    `run_mix(design, schedule[0], cycles=K * seg_cycles)`. `segments[k]`
    is the cumulative stats snapshot after segment k. Counters of a slot
    reset when its membership changes (the arriving app starts cold), so
    a churned slot's numbers read "since its last arrival"; `ipc` always
    divides by the TOTAL elapsed cycles.
    """
    design: Design
    schedule: Tuple[Tuple[Optional[str], ...], ...]
    seg_cycles: int
    stats: Mapping[str, np.ndarray]
    segments: Tuple[Mapping[str, np.ndarray], ...]
    final_state: Optional[SimState] = None

    def __getitem__(self, key: str):
        return self.stats[key]


def run_trace(design: DesignLike,
              schedule: Sequence[Tuple[Optional[str], ...]],
              seg_cycles: int = 2_000,
              fault_plan: Optional[faults_mod.FaultPlan] = None,
              audit: Optional[bool] = None,
              collect_segments: bool = True,
              return_state: bool = False) -> TraceResult:
    """Run a time-varying mix: one membership tuple per segment.

    `schedule[k]` is the bench tuple live during segment k (None entries
    are idle slots); all tuples must share one length (the slot count is
    an array shape). Between segments, every slot whose entry CHANGED
    gets full teardown + cold-start semantics — ASID shootdown across
    the TLB hierarchy, walk cancellation, token/DRAM-pressure release,
    fresh ASID generation, cold warps and counters
    (`memsys.apply_membership_change`) — and the boundary's faults from
    `fault_plan` (plus the fault plan's kills) are applied
    (`sim.faults`). Membership, `AppParams` rows, change masks, and
    fault operands are all DATA: the whole trace replays through one
    compiled segment executable per (signature, n_apps, seg_cycles) —
    K, the schedule, and the plan never retrace.

    `audit`: None defers to env `REPRO_AUDIT` (the state auditor runs on
    every collected snapshot, `sim.audit`); True/False force it.
    `collect_segments=False` skips intermediate snapshots (one
    device->host transfer instead of K). `return_state` attaches the
    final device `SimState` for state-level inspection in tests.
    """
    schedule = [tuple(s) for s in schedule]
    if not schedule:
        raise ValueError("schedule needs at least one segment")
    sizes = {len(s) for s in schedule}
    if len(sizes) != 1:
        raise ValueError(
            f"all schedule segments must have the same slot count "
            f"(it is an array shape), got {sizes}")
    if seg_cycles < 1:
        raise ValueError(f"seg_cycles must be >= 1, got {seg_cycles}")
    n = sizes.pop()
    K = len(schedule)
    cfg = SimConfig(n_apps=n, sim_cycles=seg_cycles,
                    design=as_design(design), fault_plan=fault_plan)
    ccfg = _canonical(cfg)
    dp = design_params(cfg.design)
    ops = (faults_mod.plan_operands(fault_plan, cfg, K) if fault_plan
           else faults_mod.empty_operands(cfg, K))
    seg_run = _compiled_seg_run(ccfg)

    state = init_state(ccfg, dp)
    snaps: List[Dict] = []
    prev: Optional[Tuple[Optional[str], ...]] = None
    for k, benches in enumerate(schedule):
        pm = jnp.asarray(_mix_matrix(benches))
        # segment 0's membership is the cold init itself: no teardown
        change = np.zeros(n, bool) if prev is None else np.array(
            [a != b for a, b in zip(prev, benches)])
        fops = jax.tree_util.tree_map(lambda x, k=k: x[k], ops)
        state = seg_run(dp, pm, state, jnp.asarray(change), fops)
        if collect_segments or k == K - 1:
            snaps.append(_stats(cfg, state, audit=audit))
        prev = benches
    return TraceResult(
        design=cfg.design, schedule=tuple(schedule), seg_cycles=seg_cycles,
        stats=snaps[-1], segments=tuple(snaps) if collect_segments else (),
        final_state=state if return_state else None)


def run_batch(design: DesignLike,
              bench_mixes: Sequence[Tuple[Optional[str], ...]],
              cycles: int = 60_000) -> List[Dict]:
    """Run many same-size workload mixes at once (vmap). An entry may
    contain None for a solo run (idle partner)."""
    sizes = {len(m) for m in bench_mixes}
    if len(sizes) != 1:
        raise ValueError(f"all mixes must have the same size, got {sizes}")
    cfg = SimConfig(n_apps=sizes.pop(), sim_cycles=cycles,
                    design=as_design(design))
    pm = jnp.asarray(np.stack([_mix_matrix(m) for m in bench_mixes]))
    # one bulk device->host transfer of the whole batched final state,
    # then cheap numpy views per mix (was B per-mix tree transfers)
    final = jax.device_get(_compiled_batch_run(cfg)(pm))
    out = []
    for i in range(len(bench_mixes)):
        sub = jax.tree_util.tree_map(lambda x: x[i], final)
        out.append(_stats(cfg, sub))
    return out


@dataclasses.dataclass(frozen=True)
class FailureRecord:
    """A sweep cell (or whole signature-group chunk) that failed.

    Fail-soft sweeps return these IN PLACE of stats/results instead of
    aborting the remaining groups: one poisoned design point costs its
    own group, not the grid. The record carries everything needed to
    reproduce the failure standalone."""
    designs: Tuple[str, ...]      # design names sharing the failed call
    n_apps: int
    cycles: int
    error_type: str               # exception class name
    message: str
    stage: str                    # e.g. "grid-chunk", "experiment-batch"

    def __bool__(self) -> bool:   # a failed cell is falsy; stats are truthy
        return False

    def reraise(self) -> None:
        raise RuntimeError(
            f"[{self.stage}] designs={self.designs} n_apps={self.n_apps} "
            f"cycles={self.cycles}: {self.error_type}: {self.message}")


def run_grid(designs: Sequence[DesignLike],
             bench_mixes: Sequence[Tuple[Optional[str], ...]],
             cycles: int = 60_000,
             max_rows: int = 64,
             devices: Optional[int] = None,
             fail_soft: bool = False
             ) -> List[List[Union[Dict, "FailureRecord"]]]:
    """Run the full designs x mixes cross product, one compile per
    static-signature group and as few device executions as `max_rows`
    allows.

    Designs are grouped by `static_signature`; each group's
    `DesignParams` are stacked design-major against a tiled copy of the
    mix matrices and vmapped through the group's shared executable.
    Groups whose full grid exceeds `max_rows` simulation rows are
    executed in whole-design chunks of EQUAL width — the largest
    divisor of the group size within the cap — so every chunk reuses
    the group's one compiled program (per-row results are independent
    under vmap, so chunking cannot change them). This bounds peak state
    memory; per-sim throughput is flat in the batch width anyway, so
    narrower chunks cost nothing but per-call dispatch.

    `devices=N` (> 1) shards each chunk's rows over the first N visible
    JAX devices on a 1-D mesh (`_row_sharding`), padding the row count
    up to a multiple of N with repeated rows (`_pad_rows`, sliced back
    off). Rows are independent, so sharded results are bit-for-bit
    identical to the single-device path (pinned by
    tests/test_sharded_grid.py); the per-call row cap scales to
    `max_rows * devices` so each device still sees at most `max_rows`.
    Returns `stats[d][m]` aligned with the inputs — bit-for-bit equal to
    `run_mix(designs[d], bench_mixes[m], cycles)`.

    `fail_soft=True` catches a failing chunk (trace/compile error,
    execution error, or corrupt stats) into a `FailureRecord` placed in
    every cell the chunk covered, and CONTINUES with the remaining
    chunks and signature groups — one poisoned design cannot abort the
    sweep. Default False preserves raise-on-first-error semantics.
    """
    ds = [as_design(d) for d in designs]
    sizes = {len(m) for m in bench_mixes}
    if len(sizes) != 1:
        raise ValueError(f"all mixes must have the same size, got {sizes}")
    if not ds:
        return []
    n = sizes.pop()
    M = len(bench_mixes)
    pms = np.stack([_mix_matrix(m) for m in bench_mixes])
    sharding = _row_sharding(devices) if devices and devices > 1 else None
    row_cap = max_rows * (devices if sharding is not None else 1)
    designs_per_call = max(row_cap // M, 1)

    out: List[List[Optional[Dict]]] = [[None] * M for _ in ds]
    groups: Dict[object, List[int]] = {}
    for i, d in enumerate(ds):
        groups.setdefault(static_signature(d), []).append(i)
    for sig, g_idxs in groups.items():
        ccfg = SimConfig(n_apps=n, sim_cycles=cycles,
                         design=canonical_design(sig))
        G = len(g_idxs)
        # equal-width chunks only: a ragged tail would be a second
        # compiled program for the group
        width = G if G <= designs_per_call else max(
            w for w in range(1, designs_per_call + 1) if G % w == 0)
        for lo in range(0, G, width):
            idxs = g_idxs[lo:lo + width]
            try:
                dps = [design_params(ds[i]) for i in idxs]
                # rows are design-major: row g*M + m = (design idxs[g],
                # mix m)
                dp_stack = jax.tree_util.tree_map(
                    lambda *leaves: jnp.repeat(jnp.stack(leaves), M, axis=0),
                    *dps)
                pm_stack = jnp.asarray(np.tile(pms, (len(idxs), 1, 1)))
                if sharding is not None:
                    (dp_stack, pm_stack), _ = _pad_rows(
                        (dp_stack, pm_stack), devices)
                    dp_stack, pm_stack = jax.device_put(
                        (dp_stack, pm_stack), sharding)
                # one bulk device->host transfer of the chunk's final
                # state (padding rows ride along; the loop below never
                # reads them)
                final = jax.device_get(
                    _compiled_grid_run(ccfg)(dp_stack, pm_stack))
                for g, di in enumerate(idxs):
                    for m in range(M):
                        sub = jax.tree_util.tree_map(
                            lambda x, r=g * M + m: x[r], final)
                        out[di][m] = _stats(ccfg, sub)
            except Exception as e:  # noqa: BLE001 — fail-soft boundary
                if not fail_soft:
                    raise
                rec = FailureRecord(
                    designs=tuple(ds[i].name for i in idxs), n_apps=n,
                    cycles=cycles, error_type=type(e).__name__,
                    message=str(e), stage="grid-chunk")
                for di in idxs:
                    for m in range(M):
                        out[di][m] = rec
    return out


@dataclasses.dataclass(frozen=True)
class MixPrediction:
    """One candidate co-placement's predicted contention metrics.

    Produced by `predict_mixes` (the serving oracle's entry point into
    the simulator): per-app slowdown/speedup are §6 semantics — the
    solo baseline keeps the app's core share (idle partners) and
    removes memory contention, so `slowdown[i]` isolates what SHARING
    the memory system costs app i in this mix."""

    benches: Tuple[str, ...]
    weighted_speedup: float
    max_slowdown: float
    slowdown: Tuple[float, ...]   # aligned with benches
    ipc: Tuple[float, ...]
    solo_ipc: Tuple[float, ...]


def predict_mixes(design: DesignLike,
                  mixes: Sequence[Sequence[str]],
                  cycles: int = 2_000,
                  slots: Optional[int] = None,
                  pad_rows: int = 0,
                  fail_soft: bool = False,
                  solo_cache: Optional[Dict[str, float]] = None
                  ) -> List[Union[MixPrediction, FailureRecord]]:
    """Predict contention for candidate co-placement mixes in ONE
    `run_grid` call (the oracle-facing helper).

    Every mix (a tuple of bench names, no Nones) is padded with idle
    partners to a common `slots` count, so candidates of different
    co-run degrees batch into one (signature, n_apps) grid execution
    together with the IPC_alone solo-baseline rows their benches need.
    Slowdowns are therefore comparable across candidate sizes: each app
    holds the same 1/slots core share in its mix AND in its baseline,
    and the prediction isolates memory-system contention (§6).

    `pad_rows > 0` pads the ROW COUNT up to the next multiple by
    repeating the last row, keeping the vmapped grid shape stable
    across calls: a serving loop that predicts every decision epoch
    compiles exactly one program for the oracle's lifetime
    (`runner.TRACE_COUNT` pins this in tests/test_serving_oracle.py).

    `solo_cache` (mutated in place when given) carries solo IPCs across
    calls so previously-seen benches don't re-simulate their baselines.
    With `fail_soft=True` a failing chunk yields `FailureRecord`s in
    place of predictions (and poisons only the mixes that needed it).
    """
    mixes = [tuple(b for b in m if b is not None) for m in mixes]
    if not mixes:
        return []
    if any(not m for m in mixes):
        raise ValueError("every candidate mix needs at least one bench")
    n = max(len(m) for m in mixes)
    slots = n if slots is None else slots
    if n > slots:
        raise ValueError(f"a candidate mix has {n} apps > slots={slots}")
    solo_cache = {} if solo_cache is None else solo_cache
    need_solo = sorted({b for m in mixes for b in m} - set(solo_cache))
    rows = [m + (None,) * (slots - len(m)) for m in mixes]
    rows += [(b,) + (None,) * (slots - 1) for b in need_solo]
    if pad_rows > 0:
        target = -(-len(rows) // pad_rows) * pad_rows
        rows += [rows[-1]] * (target - len(rows))
    grid = run_grid([design], rows, cycles, fail_soft=fail_soft)[0]

    solo_fail: Dict[str, FailureRecord] = {}
    for b, s in zip(need_solo, grid[len(mixes):len(mixes) + len(need_solo)]):
        if isinstance(s, FailureRecord):
            solo_fail[b] = s
        else:
            solo_cache[b] = float(s["ipc"][0])
    out: List[Union[MixPrediction, FailureRecord]] = []
    for m, s in zip(mixes, grid[:len(mixes)]):
        if isinstance(s, FailureRecord):
            out.append(s)
            continue
        bad = next((solo_fail[b] for b in m if b in solo_fail), None)
        if bad is not None:
            out.append(bad)
            continue
        solo = tuple(solo_cache[b] for b in m)
        ipc = tuple(float(s["ipc"][i]) for i in range(len(m)))
        slow = tuple(a / max(i, 1e-9) for a, i in zip(solo, ipc))
        out.append(MixPrediction(
            benches=m,
            weighted_speedup=float(sum(i / max(a, 1e-9)
                                       for i, a in zip(ipc, solo))),
            max_slowdown=float(max(slow)),
            slowdown=slow, ipc=ipc, solo_ipc=solo))
    return out


def run_pair(design: DesignLike, bench_a: str, bench_b: str,
             cycles: int = 60_000) -> Dict:
    """Co-run two apps under a design; returns per-app stats."""
    return run_mix(design, [bench_a, bench_b], cycles)


def run_solo(design: DesignLike, bench: str, cycles: int = 60_000) -> Dict:
    """IPC_alone: same core count as in the shared run (paper §6),
    exclusive memory system — emulated by pairing with an idle app."""
    return run_mix(design, [bench, None], cycles)


def weighted_speedup(mix_stats, *solos) -> float:
    """Sum of per-app IPC / IPC_alone over the mix (any N)."""
    return float(sum(mix_stats["ipc"][i] / max(s["ipc"][0], 1e-9)
                     for i, s in enumerate(solos)))


def max_slowdown(mix_stats, *solos) -> float:
    """Unfairness: worst per-app IPC_alone / IPC over the mix (any N)."""
    return float(max(s["ipc"][0] / max(mix_stats["ipc"][i], 1e-9)
                     for i, s in enumerate(solos)))


# ---------------------------------------------------------------------------
# typed results layer
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AppStats:
    """One application's slice of a mix run. `ipc_alone` is the §6
    IPC_alone baseline (same core share, idle partners) when the
    experiment computed solo baselines, else None."""

    bench: Optional[str]          # None = idle partner slot
    index: int                    # position in the mix
    ipc: float
    ipc_alone: Optional[float]
    l1_tlb_hit_rate: float
    l2_tlb_hit_rate: float        # shared L2 TLB (Table 3)
    bypass_hit_rate: float        # token bypass cache (Table 4)
    walk_lat: float               # mean page-walk latency (cycles)
    walks: float
    stalls_per_miss: float
    dram_tlb_lat: float           # mean DRAM latency, walk requests
    dram_data_lat: float          # mean DRAM latency, data requests
    tokens: int                   # final TLB-fill token count

    @property
    def speedup(self) -> float:
        """IPC / IPC_alone (this app's weighted-speedup contribution)."""
        if self.ipc_alone is None:
            raise ValueError("run the experiment with solo baselines")
        return self.ipc / max(self.ipc_alone, 1e-9)

    @property
    def slowdown(self) -> float:
        """IPC_alone / IPC (this app's unfairness contribution)."""
        if self.ipc_alone is None:
            raise ValueError("run the experiment with solo baselines")
        return self.ipc_alone / max(self.ipc, 1e-9)


@dataclasses.dataclass(frozen=True, eq=False)
class MixResult:
    """One mix under one design: per-app `AppStats` + mix-level metrics.
    The raw stats dict stays reachable via `.raw` / `res[key]`."""

    design: Design
    benches: Tuple[Optional[str], ...]
    cycles: int
    apps: Tuple[AppStats, ...]
    raw: Mapping[str, np.ndarray]

    def __getitem__(self, key: str):
        return self.raw[key]

    def app(self, bench: str) -> AppStats:
        """First AppStats running `bench` (mixes may repeat a bench)."""
        for a in self.apps:
            if a.bench == bench:
                return a
        raise KeyError(f"{bench!r} not in mix {self.benches}")

    @property
    def real_apps(self) -> Tuple[AppStats, ...]:
        """Apps excluding idle-partner (None) slots."""
        return tuple(a for a in self.apps if a.bench is not None)

    @property
    def l2c_tlb_hit_rate(self) -> float:
        """L2 data-cache hit rate for TLB (walk) requests (Table 5)."""
        return float(self.raw["l2c_tlb_hit_rate"])

    @property
    def l2c_data_hit_rate(self) -> float:
        return float(self.raw["l2c_data_hit_rate"])

    def weighted_speedup(self) -> float:
        """Sum of IPC / IPC_alone over the real apps (paper Eq. WS)."""
        return float(sum(a.speedup for a in self.real_apps))

    def unfairness(self) -> float:
        """Max per-app slowdown over the real apps (paper max slowdown)."""
        return float(max(a.slowdown for a in self.real_apps))

    max_slowdown = unfairness


@dataclasses.dataclass(frozen=True, eq=False)
class ExperimentResult:
    """All mixes of one `Experiment`, aligned with its mix list."""

    design: Design
    cycles: int
    results: Tuple[MixResult, ...]
    solo_ipc: Mapping[Tuple[str, int], float]  # (bench, n_apps) -> IPC_alone

    def __iter__(self):
        return iter(self.results)

    def __len__(self) -> int:
        return len(self.results)

    def __getitem__(self, i) -> MixResult:
        return self.results[i]

    def mean_weighted_speedup(self) -> float:
        return float(np.mean([r.weighted_speedup() for r in self.results]))

    def mean_unfairness(self) -> float:
        return float(np.mean([r.unfairness() for r in self.results]))


def _normalize_mixes(mixes) -> Tuple[Tuple[Optional[str], ...], ...]:
    """Normalize a mix list: bare bench strings become 1-app mixes."""
    if isinstance(mixes, str):
        raise TypeError(
            f"mixes must be a sequence of mixes, got the bare string "
            f"{mixes!r} — did you mean [({mixes!r},)]?")
    norm = tuple((m,) if isinstance(m, str) else tuple(m) for m in mixes)
    if not norm:
        raise ValueError("need at least one mix")
    return norm


class _NPlan(NamedTuple):
    """Per-n_apps slice of an experiment: which simulation rows to run
    (user mixes + IPC_alone solo mixes) and how to map them back."""
    items: Tuple[Tuple[int, Tuple[Optional[str], ...]], ...]  # (orig idx, mix)
    rows: Tuple[Tuple[Optional[str], ...], ...]   # mixes + solo_mixes
    n_mixes: int
    solo_shaped: frozenset                        # user mixes that ARE solos
    solo_mixes: Tuple[Tuple[Optional[str], ...], ...]


def _mix_plan(mixes, solo_baselines: bool) -> Dict[int, _NPlan]:
    """Group normalized mixes by n_apps and plan each group's simulation
    rows, deduplicating solo baselines against solo-shaped user mixes."""
    by_n: Dict[int, List[Tuple[int, Tuple[Optional[str], ...]]]] = {}
    for i, m in enumerate(mixes):
        by_n.setdefault(len(m), []).append((i, m))
    plans: Dict[int, _NPlan] = {}
    for n, items in sorted(by_n.items()):
        ms = [m for _, m in items]
        benches = sorted({b for m in ms for b in m
                          if b is not None}) if solo_baselines else []
        # a user mix that IS the canonical solo shape (bench + idle
        # partners) doubles as its own baseline — don't simulate twice
        solo_shaped = {m for m in ms if m[0] is not None and not any(m[1:])}
        solo_mixes = [(b,) + (None,) * (n - 1) for b in benches]
        solo_mixes = [sm for sm in solo_mixes if sm not in solo_shaped]
        plans[n] = _NPlan(items=tuple(items),
                          rows=tuple(ms) + tuple(solo_mixes),
                          n_mixes=len(ms),
                          solo_shaped=frozenset(solo_shaped),
                          solo_mixes=tuple(solo_mixes))
    return plans


def _mk_mix_result(design: Design, cycles: int, benches, s, solo_ipc,
                   n: int) -> MixResult:
    apps = tuple(
        AppStats(
            bench=b, index=i,
            ipc=float(s["ipc"][i]),
            ipc_alone=solo_ipc.get((b, n)),
            l1_tlb_hit_rate=float(s["l1_hit_rate"][i]),
            l2_tlb_hit_rate=float(s["l2_hit_rate"][i]),
            bypass_hit_rate=float(s["byp_hit_rate"][i]),
            walk_lat=float(s["walk_lat"][i]),
            walks=float(s["walks"][i]),
            stalls_per_miss=float(s["stalls_per_miss"][i]),
            dram_tlb_lat=float(s["dram_tlb_lat"][i]),
            dram_data_lat=float(s["dram_data_lat"][i]),
            tokens=int(s["tokens"][i]),
        ) for i, b in enumerate(benches))
    return MixResult(design=design, benches=tuple(benches),
                     cycles=cycles, apps=apps, raw=s)


def _assemble_result(design: Design, cycles: int, n_results: int,
                     plans: Dict[int, _NPlan],
                     stats_by_n: Dict[int, List[Dict]]) -> ExperimentResult:
    """Fold per-row stats back into an ExperimentResult (shared by the
    per-design `Experiment.run` and the grid-path `sweep`)."""
    results: List[Optional[MixResult]] = [None] * n_results
    solo_ipc: Dict[Tuple[str, int], float] = {}
    for n, plan in sorted(plans.items()):
        stats = stats_by_n[n]
        for m, s in zip(plan.rows[:plan.n_mixes], stats):
            if m in plan.solo_shaped:
                solo_ipc[(m[0], n)] = float(s["ipc"][0])
        for sm, s in zip(plan.solo_mixes, stats[plan.n_mixes:]):
            solo_ipc[(sm[0], n)] = float(s["ipc"][0])
        for (i, m), s in zip(plan.items, stats[:plan.n_mixes]):
            results[i] = _mk_mix_result(design, cycles, m, s, solo_ipc, n)
    return ExperimentResult(design=design, cycles=cycles,
                            results=tuple(results), solo_ipc=solo_ipc)


@dataclasses.dataclass(frozen=True)
class Experiment:
    """Typed façade over `run_batch`: a design × a list of mixes.

    `design` may be a registered name, a `Design`, or a legacy
    `DesignPoint`; `mixes` entries are bench tuples (a bare bench name
    means a 1-app run; None entries are idle partners). Mixes of
    different sizes are allowed — each (design, n_apps) group is one
    vmapped compile, with the solo baselines batched into the same call.

        exp = Experiment("mask", [("3DS", "BLK"), ("MUM", "RED")])
        res = exp.run()
        res.mean_weighted_speedup()
        res[0].app("3DS").l2_tlb_hit_rate
    """

    design: DesignLike
    mixes: Tuple[Tuple[Optional[str], ...], ...]
    cycles: int = 60_000

    def __post_init__(self):
        object.__setattr__(self, "design", as_design(self.design))
        object.__setattr__(self, "mixes", _normalize_mixes(self.mixes))

    def run(self, solo_baselines: bool = True, fail_soft: bool = False
            ) -> Union[ExperimentResult, FailureRecord]:
        """`fail_soft=True` converts a failure (compile, execution, or
        corrupt stats) into this experiment's `FailureRecord` instead of
        raising, so sweep loops over many experiments keep going."""
        plans = _mix_plan(self.mixes, solo_baselines)
        # one executable per (signature, n_apps): mixes + solos per batch
        stats_by_n = {}
        for n, plan in plans.items():
            try:
                stats_by_n[n] = run_batch(self.design, plan.rows,
                                          self.cycles)
            except Exception as e:  # noqa: BLE001 — fail-soft boundary
                if not fail_soft:
                    raise
                return FailureRecord(
                    designs=(self.design.name,), n_apps=n,
                    cycles=self.cycles, error_type=type(e).__name__,
                    message=str(e), stage="experiment-batch")
        return _assemble_result(self.design, self.cycles, len(self.mixes),
                                plans, stats_by_n)


def sweep(designs: Sequence[DesignLike],
          mixes: Sequence, cycles: int = 60_000,
          solo_baselines: bool = True,
          grid: bool = True,
          devices: Optional[int] = None,
          fail_soft: bool = False
          ) -> Dict[str, Union[ExperimentResult, FailureRecord]]:
    """Run several designs over the same mixes, keyed by design name.

    With `grid=True` (default) the designs are grouped by static
    signature and each (signature, n_apps) slice — every design of the
    group x every mix of that size, solo baselines included — runs as
    ONE compiled, vmapped grid execution (`run_grid`). The paper's
    8-design ablation grid compiles two programs instead of eight and
    executes two device calls per n_apps. `grid=False` keeps the
    per-design `Experiment` loop; results are bit-for-bit identical
    either way (pinned by tests).

    `devices=N` shards the grid rows over N devices (see `run_grid`);
    it requires the grid path.

    `fail_soft=True`: a failing signature group (or per-design
    experiment with `grid=False`) becomes a `FailureRecord` VALUE for
    each affected design name, and every other design's
    `ExperimentResult` is still computed and returned — one poisoned
    design point costs its group, not the sweep."""
    ds: List[Design] = []
    for d in designs:
        dd = as_design(d)
        if any(x.name == dd.name for x in ds):
            raise ValueError(f"duplicate design name in sweep: {dd.name!r}")
        ds.append(dd)
    if not grid:
        if devices and devices > 1:
            raise ValueError("devices > 1 requires the grid path "
                             "(sweep(grid=True))")
        return {d.name: Experiment(d, tuple(mixes), cycles).run(
            solo_baselines=solo_baselines, fail_soft=fail_soft)
            for d in ds}
    norm = _normalize_mixes(mixes)
    plans = _mix_plan(norm, solo_baselines)
    stats = {n: run_grid(ds, plan.rows, cycles, devices=devices,
                         fail_soft=fail_soft)
             for n, plan in plans.items()}        # stats[n][design][row]
    out: Dict[str, Union[ExperimentResult, FailureRecord]] = {}
    for i, d in enumerate(ds):
        rows_by_n = {n: stats[n][i] for n in plans}
        failed = [s for rows in rows_by_n.values() for s in rows
                  if isinstance(s, FailureRecord)]
        out[d.name] = failed[0] if failed else _assemble_result(
            d, cycles, len(norm), plans, rows_by_n)
    return out
