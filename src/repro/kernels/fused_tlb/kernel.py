"""Pallas kernel for the fused cross-wave TLB round (`tlb.access_fused`).

One call services ALL of a simulator cycle's sub-accesses to a shared
cache structure (the L2$ line cache, the PWC) with the cross-wave
semantics of `repro.core.tlb.access_fused` (PR 4's fused contract, which
obsoleted the seed's single-round `tlb_probe` kernel):

  * probe against the start-of-cycle tags (per-lane gather, no sort);
  * per-(set, wave) fill ports — the first fill candidate of a set
    within a wave wins, resolved by a scratch-table scatter-min;
  * duplicate suppression — a flat position (core) whose line was
    already a fill candidate in an earlier wave forwards instead of
    filling again;
  * k-th-LRU victim chains — the k-th winning wave of a set takes the
    k-th least-recently-used way (stable (lru, way) pairwise rank);
  * forwarding — the final hit resolution re-probes the post-fill tags,
    so a lane whose line was filled this cycle by anyone observes it.

State planes are aliased in/out (`input_output_aliases`) — the kernel
mutates the cache in place, as the hardware structure does. The whole
problem is a few hundred int32 lanes over a (sets, ways) table, so
grid=() and the kernel is a single fused VMEM pass.

The arithmetic mirrors `repro.core.tlb.access_fused` op for op (integer
gathers/scatters only), so interpret mode is bit-for-bit identical to
the XLA path — the float-hex parity tests pin that. Iotas are built
with 2-D `broadcasted_iota` (TPU requires >=2-D iota); the dynamic
gathers/scatters follow the repo's established TLB-kernel idiom.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _iota_1d(n: int) -> jax.Array:
    """(n,) int32 iota via a 2-D broadcasted_iota (TPU-safe)."""
    return jax.lax.broadcasted_iota(jnp.int32, (n, 1), 0).reshape(n)


def _kernel(n_waves: int, track_asids: bool,
            tags_ref, asids_ref, lru_ref, vpn_ref, asid_ref, act_ref,
            mayf_ref, time_ref,
            tags_out, asids_out, lru_out, hit_out, filled_out):
    tags = tags_ref[...]                         # (sets, ways) int32
    asids = asids_ref[...]
    lru = lru_ref[...]
    vpn = vpn_ref[...]                           # (N,) int32
    asid = asid_ref[...]
    active = act_ref[...] != 0
    may_fill = mayf_ref[...] != 0
    t = time_ref[0]

    n_sets, n_ways = tags.shape
    N = vpn.shape[0]
    W = n_waves
    C = N // W
    set_ix = (vpn % n_sets if n_sets > 1
              else jnp.zeros_like(vpn)).astype(jnp.int32)

    rows_t = tags[set_ix]                        # (N, ways)
    match = rows_t == vpn[:, None]
    if track_asids:
        match = match & (asids[set_ix] == asid[:, None])
    pre_hit = match.any(axis=1) & active
    way = jnp.argmax(match, axis=1).astype(jnp.int32)

    # ---- fill candidates --------------------------------------------------
    cand = active & ~pre_hit & may_fill
    if W > 1:
        # duplicate suppression per flat position (core): an earlier-wave
        # candidate with the same line makes later waves forward, not fill
        lines_wc = vpn.reshape(W, C)
        cand_wc = cand.reshape(W, C)
        tri_w = (jax.lax.broadcasted_iota(jnp.int32, (W, W, 1), 0)
                 < jax.lax.broadcasted_iota(jnp.int32, (W, W, 1), 1))
        dup = ((lines_wc[:, None, :] == lines_wc[None, :, :])
               & tri_w & cand_wc[:, None, :]).any(0).reshape(N)
        cand = cand & ~dup

    # ---- per-(set, wave) fill port via a scratch table --------------------
    wave = jax.lax.broadcasted_iota(jnp.int32, (W, C), 0).reshape(N)
    order = _iota_1d(N)
    key = set_ix * W + wave
    scratch = jnp.full((n_sets * W,), jnp.int32(N), jnp.int32)
    scratch = scratch.at[jnp.where(cand, key, n_sets * W)].min(
        order, mode="drop")
    winner = cand & (scratch[key] == order)
    filled_sw = (scratch.reshape(n_sets, W) < N)[set_ix]        # (N, W)
    earlier_w = _iota_1d(W)[None, :] < wave[:, None]            # (N, W)
    rank = (filled_sw & earlier_w).sum(1)
    # a set accepts at most n_ways fills per cycle (n_waves > n_ways only)
    winner = winner & (rank < n_ways)

    # ---- victim = rank-th least-recently-used way -------------------------
    lru_rows = lru[set_ix]                       # (N, ways)
    widx_col = jax.lax.broadcasted_iota(jnp.int32, (1, n_ways, n_ways), 2)
    widx_row = jax.lax.broadcasted_iota(jnp.int32, (1, n_ways, n_ways), 1)
    lru_less = (lru_rows[:, None, :] < lru_rows[:, :, None]) | \
        ((lru_rows[:, None, :] == lru_rows[:, :, None])
         & (widx_col < widx_row))
    way_rank = lru_less.sum(-1)                  # (N, ways)
    victim = jnp.argmax(way_rank == jnp.minimum(rank, n_ways - 1)[:, None],
                        axis=1).astype(jnp.int32)

    # ---- one merged update pass per plane ---------------------------------
    flat = jnp.where(pre_hit, set_ix * n_ways + way,
                     jnp.where(winner, set_ix * n_ways + victim,
                               n_sets * n_ways))
    tags = tags.reshape(-1).at[flat].set(vpn, mode="drop") \
        .reshape(n_sets, n_ways)
    lru = lru.reshape(-1).at[flat].set(t, mode="drop") \
        .reshape(n_sets, n_ways)
    if track_asids:
        asids = asids.reshape(-1).at[flat].set(asid, mode="drop") \
            .reshape(n_sets, n_ways)

    # ---- final hit resolution (forwarding falls out of the fills) ---------
    post = tags[set_ix] == vpn[:, None]
    if track_asids:
        post = post & (asids[set_ix] == asid[:, None])
    hit = pre_hit | (active & ~winner & post.any(axis=1))

    tags_out[...] = tags
    asids_out[...] = asids
    lru_out[...] = lru
    hit_out[...] = hit.astype(jnp.int32)
    filled_out[...] = winner.astype(jnp.int32)


def fused_tlb_round(tags, asids, lru, vpn, asid, active, may_fill, time, *,
                    n_waves: int = 1, track_asids: bool = True,
                    interpret: bool = False):
    """One fused cross-wave probe+fill round over a (sets, ways) cache.

    Returns (tags', asids', lru', hit (N,) int32, filled (N,) int32);
    the hit/miss counter arithmetic stays with the caller
    (`repro.core.tlb.access_fused` keeps it identical across backends).
    """
    n_sets, n_ways = tags.shape
    N = vpn.shape[0]
    if N % n_waves:
        raise ValueError(f"lane count {N} not divisible by n_waves={n_waves}")
    t_arr = jnp.full((1,), time, jnp.int32)
    return pl.pallas_call(
        functools.partial(_kernel, n_waves, track_asids),
        out_shape=[
            jax.ShapeDtypeStruct((n_sets, n_ways), jnp.int32),
            jax.ShapeDtypeStruct((n_sets, n_ways), jnp.int32),
            jax.ShapeDtypeStruct((n_sets, n_ways), jnp.int32),
            jax.ShapeDtypeStruct((N,), jnp.int32),
            jax.ShapeDtypeStruct((N,), jnp.int32),
        ],
        input_output_aliases={0: 0, 1: 1, 2: 2},
        interpret=interpret,
    )(tags, asids, lru, vpn, asid.astype(jnp.int32),
      active.astype(jnp.int32), may_fill.astype(jnp.int32), t_arr)
