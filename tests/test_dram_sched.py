"""Address-Space-Aware DRAM scheduler unit tests (§5.4)."""
import jax.numpy as jnp
import numpy as np

from repro.core import dram_sched as ds


def _state(n_apps=2):
    return ds.init(n_channels=2, n_banks=2, n_apps=n_apps)


def test_golden_beats_normal():
    st = _state()
    # two requests, same channel+bank+row, one TLB one data: golden first
    channel = jnp.asarray([0, 0])
    bank = jnp.asarray([0, 0])
    row = jnp.asarray([7, 7])
    app = jnp.asarray([0, 1])
    active = jnp.ones(2, bool)
    # order puts the data request FIRST — priority must still win
    is_tlb = jnp.asarray([False, True])
    _, lat = ds.access(st, channel, bank, row, app, is_tlb, active,
                       mask_enabled=True)
    assert int(lat[1]) < int(lat[0])


def test_frfcfs_row_hit_priority():
    st = _state()
    st = st._replace(open_row=st.open_row.at[0, 0].set(5))
    channel = jnp.asarray([0, 0])
    bank = jnp.asarray([0, 0])
    row = jnp.asarray([9, 5])          # second one hits the open row
    app = jnp.asarray([0, 0])
    is_tlb = jnp.zeros(2, bool)
    _, lat = ds.access(st, channel, bank, row, app, is_tlb,
                       jnp.ones(2, bool), mask_enabled=False)
    assert int(lat[1]) < int(lat[0])


def test_eq1_quota_proportional():
    st = _state()
    st = ds.update_pressure(st, jnp.asarray([30, 10]), jnp.asarray([20, 10]))
    q = np.asarray(ds.silver_quota(st, thres_max=500))
    # 30*20 : 10*10 = 6 : 1
    assert q[0] > 4 * q[1]
    assert q.sum() <= 510


def test_silver_rotation():
    st = _state()
    st = st._replace(silver_left=jnp.asarray(1, jnp.int32))
    channel = jnp.asarray([0])
    bank = jnp.asarray([0])
    row = jnp.asarray([1])
    app = jnp.asarray([0])              # app 0 is silver initially
    st2, _ = ds.access(st, channel, bank, row, app, jnp.asarray([False]),
                       jnp.asarray([True]), mask_enabled=True)
    assert int(st2.silver_app) == 1     # quota consumed -> rotate


def test_disabled_mask_is_single_queue():
    st = _state()
    cls = ds.classify(st, jnp.asarray([0, 1]), jnp.asarray([True, False]),
                      mask_enabled=False)
    assert tuple(np.asarray(cls)) == (2, 2)
