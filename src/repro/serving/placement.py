"""Admission/placement policies gating the serving engine's `_admit`.

Once per *decision epoch* (every `epoch_steps` engine steps) the active
policy looks at a host-side `EngineView` snapshot — queue depths,
running counts, KV-pool pressure (`repro.memmgr.kv_cache.pool_pressure`)
— and produces a `PlacementDecision`: which tenants may co-run this
epoch (`allowed`) and each tenant's admission cap (`caps`, max running
requests). The engine consults the current decision on every admission;
running requests always finish out (admission gating only, so decisions
are work-conserving for work already placed).

Policies, least to most informed:

  none    — admit everything (the engine's legacy behavior).
  static  — fixed equal partition of the batch over the DECLARED tenant
            universe, never adapted (the paper's Static baseline
            transplanted: isolating but wasteful when tenants idle).
  greedy  — equal share over the tenants with work right now, backing
            off when the KV pool nears exhaustion. Adaptive but
            contention-blind.
  oracle  — consults the `ContentionOracle`: enumerates candidate
            co-run sets, gets predicted weighted-speedup/unfairness
            from the simulator, picks the best candidate whose
            predicted max slowdown clears the unfairness cap, and
            reserves admission slots for predicted victims so an
            aggressor tenant cannot crowd them out of the batch.

Every decision (with its predictions, for the oracle) is recorded on
the engine's `decisions` log — the serving benchmark reports
predicted-vs-achieved fairness from exactly these records.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from repro.serving.oracle import ContentionOracle, PlacementPrediction


@dataclasses.dataclass(frozen=True)
class EngineView:
    """Host-side engine snapshot a policy decides from."""

    step: int
    max_batch: int
    queued: Mapping[int, int]          # tenant -> queued request count
    running: Mapping[int, int]         # tenant -> running request count
    waiting_since: Mapping[int, int]   # tenant -> oldest queued submit step
    pool_used_frac: float              # KV pool page pressure [0, 1]
    pool_free_seqs: int
    profiles: Mapping[int, str]        # declared tenant profiles

    @property
    def tenants(self) -> Tuple[int, ...]:
        """Tenants with any work (queued or running), sorted."""
        live = {t for t, n in self.queued.items() if n > 0}
        live |= {t for t, n in self.running.items() if n > 0}
        return tuple(sorted(live))


@dataclasses.dataclass(frozen=True)
class PlacementDecision:
    """One epoch's admission plan (+ the evidence, for the oracle)."""

    step: int
    policy: str
    allowed: Tuple[int, ...]           # tenants that may admit this epoch
    caps: Mapping[int, int]            # tenant -> max running requests
    predictions: Tuple[PlacementPrediction, ...] = ()
    chosen: Optional[PlacementPrediction] = None
    note: str = ""
    default_cap: int = 0               # cap for tenants NOT in `allowed`

    def cap(self, tenant: int) -> int:
        """Admission cap. Tenants outside `allowed` get `default_cap`:
        0 freezes them out for the epoch (static partitions), 1 lets a
        tenant that was idle at the decision boundary trickle in
        instead of stalling a full epoch (greedy/oracle)."""
        if tenant not in self.allowed:
            return self.default_cap
        return self.caps.get(tenant, 0)


class PlacementPolicy:
    """Base: admit-all ("none"). Subclasses override `_decide`."""

    name = "none"

    def __init__(self, epoch_steps: int = 16):
        if epoch_steps < 1:
            raise ValueError(f"epoch_steps must be >= 1, got {epoch_steps}")
        self.epoch_steps = epoch_steps
        self.decision: Optional[PlacementDecision] = None
        self._last_step: Optional[int] = None
        self._last_active: Tuple[int, ...] = ()

    def due(self, step: int) -> bool:
        return (self._last_step is None
                or step - self._last_step >= self.epoch_steps)

    def stale(self, active: Sequence[int]) -> bool:
        """Decision invalidation on churn: a tenant that was NOT active
        when the epoch's decision was made has work now, so the
        placement no longer covers the live tenant set — re-decide
        early rather than stall the newcomer a whole epoch. (Tenants
        the decision deliberately excluded were seen at decision time
        and do NOT retrigger; oracle memoization keeps early
        re-decides cheap.)"""
        if self.name == "none" or self.decision is None:
            return False
        return bool(set(active) - set(self._last_active))

    def refresh(self, view: EngineView) -> PlacementDecision:
        self.decision = self._decide(view)
        self._last_step = view.step
        self._last_active = view.tenants
        return self.decision

    def may_admit(self, tenant: int, running_count: int) -> bool:
        """Admission gate consulted per admitted request. The base
        policy is truly admit-all — never gated on the (stale) epoch
        snapshot, so "none" is the engine's legacy behavior exactly."""
        if self.name == "none" or self.decision is None:
            return True
        return running_count < self.decision.cap(tenant)

    def _decide(self, view: EngineView) -> PlacementDecision:
        ts = view.tenants
        return PlacementDecision(
            step=view.step, policy=self.name, allowed=ts,
            caps={t: view.max_batch for t in ts},
            default_cap=view.max_batch)


class StaticPartition(PlacementPolicy):
    """Fixed 1/N admission slice per DECLARED tenant — isolating but
    non-adaptive: an idle tenant's slice is never reused."""

    name = "static"

    def __init__(self, tenants: Sequence[int], epoch_steps: int = 16):
        super().__init__(epoch_steps)
        self._universe = tuple(sorted(set(tenants)))
        if not self._universe:
            raise ValueError("static partition needs >= 1 declared tenant")

    def stale(self, active: Sequence[int]) -> bool:
        return False        # the partition is fixed; churn changes nothing

    def _decide(self, view: EngineView) -> PlacementDecision:
        share = max(view.max_batch // len(self._universe), 1)
        return PlacementDecision(
            step=view.step, policy=self.name, allowed=self._universe,
            caps={t: share for t in self._universe})


class GreedyShare(PlacementPolicy):
    """Equal share over currently-active tenants + pool backpressure.
    Adaptive (idle tenants' slots are redistributed) but blind to WHICH
    tenants contend on the memory system."""

    name = "greedy"

    def __init__(self, epoch_steps: int = 16,
                 pool_high_water: float = 0.9):
        super().__init__(epoch_steps)
        self.pool_high_water = pool_high_water

    def _decide(self, view: EngineView) -> PlacementDecision:
        ts = view.tenants
        if not ts:
            return PlacementDecision(step=view.step, policy=self.name,
                                     allowed=(), caps={}, default_cap=1)
        budget = view.max_batch
        note = ""
        if view.pool_used_frac > self.pool_high_water:
            budget = max(budget // 2, len(ts))
            note = f"pool pressure {view.pool_used_frac:.2f}: halved budget"
        share = max(-(-budget // len(ts)), 1)       # ceil
        return PlacementDecision(
            step=view.step, policy=self.name, allowed=ts,
            caps={t: share for t in ts}, note=note, default_cap=1)


class OraclePlacement(PlacementPolicy):
    """Simulator-driven placement (see module docstring).

    Per epoch: enumerate co-run candidates over the (up to `slots`)
    longest-waiting active tenants, predict each through the oracle,
    keep candidates whose predicted max slowdown clears
    `unfairness_cap`, and pick the one serving the most tenants at the
    highest predicted weighted speedup. Admission caps then reserve
    batch slots for predicted victims: every allowed tenant's cap is
    the batch minus the other tenants' reservations (the predicted
    worst victim reserves 2 slots, others 1), so the aggressor can
    never occupy the whole batch while a victim queues.
    """

    name = "oracle"

    def __init__(self, oracle: ContentionOracle, epoch_steps: int = 16,
                 unfairness_cap: float = 1.15,
                 pool_high_water: float = 0.9):
        super().__init__(epoch_steps)
        self.oracle = oracle
        self.unfairness_cap = unfairness_cap
        self.pool_high_water = pool_high_water

    # ---------------------------------------------------------- decide
    def _candidates(self, tenants: Tuple[int, ...]
                    ) -> List[Tuple[int, ...]]:
        """All non-empty subsets, smallest-last so ties in scoring
        resolve toward serving more tenants; deterministic order."""
        out: List[Tuple[int, ...]] = []
        n = len(tenants)
        for bits in range(1, 2 ** n):
            out.append(tuple(t for i, t in enumerate(tenants)
                             if bits >> i & 1))
        return sorted(out, key=lambda c: (len(c), c))

    def _decide(self, view: EngineView) -> PlacementDecision:
        active = view.tenants
        if not active:
            return PlacementDecision(step=view.step, policy=self.name,
                                     allowed=(), caps={}, default_cap=1)
        # consider the longest-waiting tenants first when over-wide
        consider = sorted(
            active,
            key=lambda t: (view.waiting_since.get(t, view.step), t)
        )[: self.oracle.slots]
        consider = tuple(sorted(consider))
        cands = self._candidates(consider)
        preds = [p for p in self.oracle.predict(cands, view.profiles)
                 if p is not None]
        note = ""
        if not preds:
            # every candidate's simulation failed: fail soft to greedy
            share = max(-(-view.max_batch // len(active)), 1)
            return PlacementDecision(
                step=view.step, policy=self.name, allowed=active,
                caps={t: share for t in active}, default_cap=1,
                note="oracle predictions unavailable; equal share")
        feasible = [p for p in preds
                    if p.max_slowdown <= self.unfairness_cap]
        if feasible:
            # serve the most tenants at the best predicted speedup;
            # deterministic tie-break on the tenant tuple
            chosen = max(feasible, key=lambda p: (
                len(p.tenants), p.weighted_speedup, p.tenants))
        else:
            chosen = min(preds, key=lambda p: (
                p.max_slowdown, -len(p.tenants), p.tenants))
            note = (f"no candidate under unfairness cap "
                    f"{self.unfairness_cap}: min-slowdown fallback")
        allowed = chosen.tenants
        # Latent-tenant headroom: declared tenants (profiles) that are
        # idle right now WILL come back; holding a slot for them means
        # their first request admits instantly instead of waiting out a
        # full batch of long decodes (admission caps can't evict).
        latent = min(len([t for t in view.profiles if t not in allowed]), 2)
        caps: Dict[int, int] = {}
        if len(allowed) == 1:
            caps[allowed[0]] = max(view.max_batch - latent, 1)
        else:
            # one reserved admission slot per co-tenant: enough for the
            # predicted victim's first request to admit instantly, and
            # cheap enough (1/max_batch capacity) that a backlogged
            # aggressor is not pushed into queue divergence
            for t in allowed:
                others = len(allowed) - 1
                caps[t] = max(view.max_batch - others - latent, 1)
        if view.pool_used_frac > self.pool_high_water:
            caps = {t: max(c // 2, 1) for t, c in caps.items()}
            note = (note + "; " if note else "") + (
                f"pool pressure {view.pool_used_frac:.2f}: halved caps")
        return PlacementDecision(
            step=view.step, policy=self.name, allowed=allowed, caps=caps,
            predictions=tuple(preds), chosen=chosen, note=note,
            default_cap=1)


POLICIES = ("none", "static", "greedy", "oracle")


def make_policy(name: str,
                profiles: Optional[Mapping[int, str]] = None,
                oracle: Optional[ContentionOracle] = None,
                epoch_steps: int = 16,
                **kw) -> PlacementPolicy:
    """Factory used by the benchmark/CLI: policy name -> instance.

    `profiles` (tenant -> declared app profile) is required for
    "static" (it declares the tenant universe); "oracle" builds a
    default `ContentionOracle` when none is passed (kw: design, cycles,
    slots, unfairness_cap, ...).
    """
    if name == "none":
        return PlacementPolicy(epoch_steps=epoch_steps)
    if name == "static":
        if not profiles:
            raise ValueError("static placement needs declared profiles "
                             "(the tenant universe)")
        return StaticPartition(tuple(profiles), epoch_steps=epoch_steps)
    if name == "greedy":
        return GreedyShare(epoch_steps=epoch_steps, **kw)
    if name == "oracle":
        cap = kw.pop("unfairness_cap", 1.15)
        if oracle is None:
            oracle = ContentionOracle(**kw)
        return OraclePlacement(oracle, epoch_steps=epoch_steps,
                               unfairness_cap=cap)
    raise KeyError(f"unknown placement policy {name!r}: {POLICIES}")
