"""Multi-tenant serving with the MASK-style 3-class scheduler + paged KV.

Two tenants share one reduced model; the engine's golden/silver/normal
admission keeps throughput fair while the paged KV pool (with ASID
protection) holds every sequence's cache.

Run:  PYTHONPATH=src python examples/multi_tenant_serving.py
"""
import numpy as np

from repro.launch.serve import build_engine
from repro.serving import metrics as smet
from repro.serving.engine import Request

eng = build_engine("qwen3-4b")
rng = np.random.RandomState(0)

# tenant 0 floods; tenant 1 sends a trickle — fairness should hold
reqs = [Request(rid=i, tenant=0,
                prompt=rng.randint(0, eng.cfg.vocab_size, 12), max_new=6)
        for i in range(6)]
reqs += [Request(rid=100 + i, tenant=1,
                 prompt=rng.randint(0, eng.cfg.vocab_size, 12), max_new=6)
         for i in range(2)]
for r in reqs:
    eng.submit(r)

finished = eng.run_until_drained(max_steps=400)
tput = smet.tenant_throughput(finished, eng.step_count)
print(f"{len(finished)} requests drained in {eng.step_count} engine steps")
for t in sorted(tput):
    n = sum(1 for r in finished if r.tenant == t)
    lat = np.mean([r.finish_step - r.submit_step
                   for r in finished if r.tenant == t])
    print(f"  tenant {t}: {n} reqs, {tput[t]:.2f} tok/step, "
          f"mean latency {lat:.1f} steps")
print("\n(the 'silver' rotation guarantees the light tenant is not starved "
      "by the flood — the paper's Eq. 1 discipline)")
