"""Per-tenant serving metrics: throughput, latency distributions, TTFT,
SLO attainment, and the paper's fairness metrics (weighted speedup, max
slowdown) applied to the serving engine — plus the oracle's
predicted-vs-achieved fairness error.

Latency accounting is in ENGINE STEPS (submit -> finish), the serving
analogue of the simulator's cycles: a tenant's *slowdown* is its shared
mean latency over its solo mean latency (same seeded arrivals, engine
to itself — `stream.TraceSpec.only`), and *unfairness* is the max
slowdown over tenants, mirroring §6's IPC_alone construction.
"""
from __future__ import annotations

from collections import defaultdict
from typing import Dict, Iterable, Mapping, Optional

import numpy as np


def _decoded(r) -> int:
    """Decode-produced tokens of a finished request (the prefill-emitted
    token in `out` is not a decode token)."""
    d = getattr(r, "decoded", None)
    return d if d is not None else max(len(r.out) - 1, 0)


def tenant_throughput(finished, total_steps: int) -> Dict[int, float]:
    """Decoded tokens per engine step, per tenant."""
    toks = defaultdict(int)
    for r in finished:
        toks[r.tenant] += _decoded(r)
    return {t: n / max(total_steps, 1) for t, n in toks.items()}


def weighted_speedup(shared: Dict[int, float],
                     alone: Dict[int, float]) -> float:
    return sum(shared[t] / max(alone.get(t, 1e-9), 1e-9) for t in shared)


def max_slowdown(shared: Dict[int, float], alone: Dict[int, float]) -> float:
    return max(max(alone.get(t, 0.0), 1e-9) / max(v, 1e-9)
               for t, v in shared.items())


def mean_latency(finished) -> float:
    if not finished:
        return 0.0
    return sum(r.finish_step - r.submit_step for r in finished) / len(finished)


def tenant_mean_latency(finished) -> Dict[int, float]:
    lat = defaultdict(list)
    for r in finished:
        lat[r.tenant].append(r.finish_step - r.submit_step)
    return {t: float(np.mean(v)) for t, v in lat.items()}


def tenant_ttft(finished) -> Dict[int, float]:
    """Mean time-to-first-token (submit -> prefill emission), per
    tenant; requests that never prefilled are excluded."""
    lat = defaultdict(list)
    for r in finished:
        if r.first_token_step >= 0:
            lat[r.tenant].append(r.first_token_step - r.submit_step)
    return {t: float(np.mean(v)) for t, v in lat.items()}


def latency_percentiles(finished, ps: Iterable[int] = (50, 95, 99)
                        ) -> Dict[str, float]:
    """Overall completion-latency percentiles, `{"p50": ..., ...}`."""
    if not finished:
        return {f"p{p}": 0.0 for p in ps}
    lat = np.asarray([r.finish_step - r.submit_step for r in finished])
    return {f"p{p}": float(np.percentile(lat, p)) for p in ps}


def tenant_latency_percentiles(finished, ps: Iterable[int] = (50, 95, 99)
                               ) -> Dict[int, Dict[str, float]]:
    by = defaultdict(list)
    for r in finished:
        by[r.tenant].append(r)
    return {t: latency_percentiles(v, ps) for t, v in by.items()}


def slo_attainment(finished, slo_steps: float) -> Dict[int, float]:
    """Fraction of each tenant's finished requests completing within
    `slo_steps` engine steps of submission."""
    tot, ok = defaultdict(int), defaultdict(int)
    for r in finished:
        tot[r.tenant] += 1
        if r.finish_step - r.submit_step <= slo_steps:
            ok[r.tenant] += 1
    return {t: ok[t] / tot[t] for t in tot}


def tenant_slowdown(shared_lat: Mapping[int, float],
                    solo_lat: Mapping[int, float]) -> Dict[int, float]:
    """Per-tenant achieved slowdown: shared mean latency / solo mean
    latency (>= ~1 when sharing hurts). Tenants missing a side are
    skipped; a tenant starved in the shared run (no finished requests)
    simply has no entry — report starvation separately."""
    out = {}
    for t, shared in shared_lat.items():
        solo = solo_lat.get(t)
        if solo is not None:
            out[t] = shared / max(solo, 1e-9)
    return out


def unfairness(slowdowns: Mapping[int, float]) -> float:
    """Max per-tenant slowdown (the paper's unfairness metric)."""
    if not slowdowns:
        return 0.0
    return float(max(slowdowns.values()))


def prediction_error(predicted: Optional[float],
                     achieved: Optional[float]) -> Optional[float]:
    """Relative predicted-vs-achieved fairness error
    |pred - achieved| / achieved. None when either side is missing
    (e.g. the `none` policy makes no predictions)."""
    if predicted is None or achieved is None or achieved <= 0:
        return None
    return abs(predicted - achieved) / achieved


def decision_summary(decisions) -> Dict[str, object]:
    """Fold an engine's placement `decisions` log into benchmark-ready
    scalars: epochs, mean/last predicted max-slowdown of the CHOSEN
    placements, and per-policy bookkeeping."""
    chosen = [d.chosen for d in decisions if d.chosen is not None]
    pred = [c.max_slowdown for c in chosen]
    allowed_sizes = [len(d.allowed) for d in decisions]
    return {
        "epochs": len(decisions),
        "predicted_max_slowdown_mean": (float(np.mean(pred))
                                        if pred else None),
        "predicted_max_slowdown_last": (float(pred[-1]) if pred else None),
        "predicted_weighted_speedup_mean": (
            float(np.mean([c.weighted_speedup for c in chosen]))
            if chosen else None),
        "mean_allowed_tenants": (float(np.mean(allowed_sizes))
                                 if allowed_sizes else 0.0),
        "rungs": rung_counts(decisions),
        "notes": sorted({d.note for d in decisions if d.note}),
    }


def rung_counts(decisions) -> Dict[str, int]:
    """Degradation-ladder attribution: how many decision epochs landed
    on each rung (`placement.RUNGS`) — the benchmark's WHY record."""
    counts: Dict[str, int] = {}
    for d in decisions:
        rung = getattr(d, "rung", "normal")
        counts[rung] = counts.get(rung, 0) + 1
    return counts


def conservation_report(eng) -> Dict[str, object]:
    """Request-conservation audit across admit/evict/re-queue cycles:
    every submitted rid must be in exactly one of {queued, running,
    parked, finished}, exactly once. `lost`/`duplicated` are the
    violation counts (both must be 0 — the preemption invariant)."""
    seen: Dict[int, int] = {}
    for q in eng.queues.values():
        for r in q:
            seen[r.rid] = seen.get(r.rid, 0) + 1
    for pool in (eng.running, eng.parked, eng.finished):
        for r in pool:
            seen[r.rid] = seen.get(r.rid, 0) + 1
    duplicated = sum(n - 1 for n in seen.values() if n > 1)
    lost = eng.submitted - len(seen)
    return {
        "submitted": eng.submitted,
        "finished": len(eng.finished),
        "pending": eng.pending(),
        "lost": lost,
        "duplicated": duplicated,
        "ok": lost == 0 and duplicated == 0,
    }


def overload_summary(eng) -> Dict[str, object]:
    """Overload/robustness attribution for one engine run: preemption
    counts, wasted (re-accounted) tokens, injected faults by kind,
    safe-mode transitions, and the recalibrator's movement — next to
    `rung_counts` this answers WHY a protective policy won or lost."""
    pol = eng.placement
    recal = getattr(pol, "recalibrator", None)
    faults: Dict[str, int] = {}
    for _, kind, _ in eng.fault_log:
        faults[kind] = faults.get(kind, 0) + 1
    return {
        "preemptions": eng.preemptions,
        "preempted_tenants": sorted({t for _, t, _ in eng.preempt_log}),
        "wasted_tokens": int(sum(r.wasted_tokens
                                 for r in (eng.finished + eng.running
                                           + eng.parked))),
        "faults_injected": faults,
        "safe_mode_log": [tuple(e) for e in getattr(pol, "mode_log", [])],
        "safe_level_final": getattr(pol, "safe_level", 0),
        "recalibration": None if recal is None else {
            "updates": recal.updates,
            "rejected": recal.rejected,
            "last_delta": recal.last_delta,
            "corrections": {int(t): float(c)
                            for t, c in sorted(recal.corrections().items())},
        },
    }


def fairness_report(shared_finished, solo_lat: Mapping[int, float],
                    decisions=()) -> Dict[str, object]:
    """One-call fairness rollup for a shared run: achieved per-tenant
    slowdown + unfairness, and (when placement decisions carry oracle
    predictions) the predicted-vs-achieved error."""
    shared_lat = tenant_mean_latency(shared_finished)
    slow = tenant_slowdown(shared_lat, solo_lat)
    ach = unfairness(slow)
    summ = decision_summary(decisions)
    pred = summ["predicted_max_slowdown_mean"]
    return {
        "tenant_slowdown": {int(t): v for t, v in sorted(slow.items())},
        "unfairness": ach,
        "predicted_max_slowdown": pred,
        "fairness_error": prediction_error(pred, ach),
        "starved_tenants": sorted(set(solo_lat) - set(shared_lat)),
    }
