"""Dev sanity: one reduced forward (train+prefill+decode) per arch on CPU."""
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, reduced_model
from repro.configs.base import RunConfig, ShapeConfig
from repro.models import model as M
from repro.models.losses import cross_entropy

def run_one(name):
    cfg = reduced_model(ARCHS[name])
    shape = ShapeConfig("t", seq_len=32, global_batch=2, kind="train")
    run = RunConfig(model=cfg, shape=shape, remat=False,
                    attn_block_q=16, attn_block_k=16)
    rng = jax.random.PRNGKey(0)
    params = M.init_params(rng, cfg)

    batch = {"tokens": jax.random.randint(rng, (2, 32 - (cfg.n_patches or 0)),
                                          0, cfg.vocab_size),
             "labels": jax.random.randint(rng, (2, 32), 0, cfg.vocab_size)}
    if cfg.n_patches:
        batch["patch_embeds"] = jnp.ones((2, cfg.n_patches, cfg.d_model),
                                         jnp.bfloat16)
    if cfg.is_enc_dec:
        batch["frames"] = jnp.ones((2, cfg.enc_len, cfg.d_model), jnp.bfloat16)

    logits, aux = M.forward_train(cfg, run, params, batch)
    assert logits.shape == (2, 32, cfg.vocab_size), logits.shape
    loss, _ = cross_entropy(logits, batch["labels"])
    assert np.isfinite(float(loss)), (name, float(loss))

    # prefill + 2 decode steps
    pb = {k: v for k, v in batch.items() if k != "labels"}
    lg, caches = M.forward_prefill(cfg, run, params, pb, max_len=64)
    assert lg.shape == (2, 1, cfg.vocab_size)
    enc_out = None
    tok = jnp.argmax(lg[:, -1], -1)[:, None]
    for _ in range(2):
        lg, caches = M.forward_decode(cfg, run, params, {"tokens": tok}, caches)
        assert lg.shape == (2, 1, cfg.vocab_size)
        assert np.all(np.isfinite(np.asarray(lg, np.float32))), name
        tok = jnp.argmax(lg[:, -1], -1)[:, None]
    print(f"  OK {name}: loss={float(loss):.3f}")

if __name__ == "__main__":
    names = sys.argv[1:] or list(ARCHS)
    for n in names:
        run_one(n)
    print("all ok")
