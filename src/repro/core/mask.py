"""Legacy MASK policy bundle + compat shims over the design registry.

The canonical design-point API lives in `repro.core.design`: frozen
per-layer policy specs composed into a registered `Design`. This module
keeps the original flag-bag dataclasses (`MaskConfig`, `DesignPoint`) and
the `design(name)` / `ALL_DESIGNS` entry points as bit-for-bit compatible
shims — `design(name)` now resolves through the registry and returns a
`Design`, whose legacy properties (`.mask`, `.use_l2_tlb`, `.ideal_tlb`,
`.static_partition`, ...) mirror the old `DesignPoint` fields exactly.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core.design import Design, get_design  # noqa: F401


@dataclasses.dataclass(frozen=True)
class MaskConfig:
    """Feature switches + sizing (defaults = paper Table 1 / §5)."""

    # components (ablations: MASK-TLB / MASK-Cache / MASK-DRAM)
    tlb_tokens: bool = True
    l2_bypass: bool = True
    dram_sched: bool = True
    # translation caches
    l1_tlb_entries: int = 64        # fully associative, per core
    l2_tlb_entries: int = 512       # 16-way, ASID-tagged, shared
    l2_tlb_ways: int = 16
    bypass_cache_entries: int = 32  # fully associative
    # policies
    epoch_cycles: int = 8_000       # paper: 100K; scaled to sim length
    # paper initializes at 0.8 and reports <1% sensitivity — with 100K-cycle
    # epochs the climb converges from anywhere. Our runs see ~7 epochs, so
    # we start near the converged region (the scaled equivalent).
    initial_token_frac: float = 0.25
    token_step_frac: float = 0.5    # geometric hill-climb step
    thres_max: int = 500
    # page walk
    walk_levels: int = 4
    max_concurrent_walks: int = 64


@dataclasses.dataclass(frozen=True)
class DesignPoint:
    """Legacy flag-bag design point (pre-registry API).

    Still accepted everywhere a design is taken (`SimConfig`, `run_mix`,
    `Experiment`) — it is converted to a `repro.core.design.Design` via
    `design.from_legacy`. New code should compose a `Design` instead."""

    name: str
    use_l2_tlb: bool = True          # shared L2 TLB (Fig. 2b) vs PWC (Fig. 2a)
    use_pwc: bool = False            # page-walk cache design
    mask: MaskConfig = MaskConfig(tlb_tokens=False, l2_bypass=False,
                                  dram_sched=False)
    ideal_tlb: bool = False          # every TLB access hits
    static_partition: bool = False   # L2$/DRAM statically split per app


def static_partition_index(index, n_resources: int, n_apps: int, app):
    """Static resource partitioning (the `Static` design, §6): app `a` owns
    a contiguous ~1/n_apps slice of an index space (L2 sets, DRAM channels).
    Slice bounds are proportional ((a*n)//n_apps .. ((a+1)*n)//n_apps) so no
    trailing resources are stranded when n_apps does not divide n_resources;
    if there are fewer resources than apps the slice floor is one unit and
    the result clips into range.

    index/app may be traced arrays; n_resources/n_apps are static ints.
    """
    na = max(n_apps, 1)
    start = (app * n_resources) // na
    span = jnp.maximum((app + 1) * n_resources // na - start, 1)
    return jnp.minimum(start + index % span, n_resources - 1)


def design(name: str) -> Design:
    """Compat shim: the named design points, now served by the registry.

    Returns the registered `Design`; its legacy view properties reproduce
    the old `DesignPoint` fields, and simulation results are bit-for-bit
    identical to the pre-registry table (pinned by tests)."""
    return get_design(name)


# the paper's named designs (the registry may hold user designs beyond these)
ALL_DESIGNS = ("ideal", "pwc", "gpu-mmu", "static", "mask",
               "mask-tlb", "mask-cache", "mask-dram")
