"""Batched set-associative TLB probe+fill Pallas TPU kernel.

The simulator's innermost operation: N concurrent requests probe an
ASID-tagged set-associative array, update LRU on hits, and fill LRU victims
on misses (first-fill-per-set port model). This is `repro.core.tlb.probe` +
`fill` fused into one pass so the tag array is read once per step.

State tensors are aliased in/out (input_output_aliases) — the kernel
mutates the TLB in place, which is exactly what the hardware structure
does. Request count N is small (≤ a few hundred); the whole problem fits
one VMEM block, so grid=() and the kernel is a single fused pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(tags_ref, asids_ref, lru_ref, vpn_ref, asid_ref, act_ref,
            time_ref, tags_out, asids_out, lru_out, hit_out):
    tags = tags_ref[...]          # (sets, ways)
    asids = asids_ref[...]
    lru = lru_ref[...]
    vpn = vpn_ref[...]            # (N,)
    asid = asid_ref[...]
    active = act_ref[...] != 0
    t = time_ref[0]

    n_sets, n_ways = tags.shape
    N = vpn.shape[0]
    set_ix = jax.lax.rem(vpn, jnp.int32(n_sets))
    set_ix = jnp.where(n_sets > 1, set_ix, 0)

    row_tags = tags[set_ix]       # (N, ways)
    row_asids = asids[set_ix]
    match = (row_tags == vpn[:, None]) & (row_asids == asid[:, None])
    hit = match.any(axis=1) & active
    way = jnp.argmax(match, axis=1).astype(jnp.int32)

    # LRU touch on hit; non-hit lanes are routed out of bounds and dropped
    # so they cannot scatter a stale value over a hit's touch (matches
    # repro.core.tlb.probe)
    touch_set = jnp.where(hit, set_ix, jnp.int32(n_sets))
    lru = lru.at[touch_set, way].set(t, mode="drop")

    # fills: misses only; first active miss per set wins (fill-port model)
    want = active & ~hit
    order = jax.lax.broadcasted_iota(jnp.int32, (N, N), 1)
    mine = jax.lax.broadcasted_iota(jnp.int32, (N, N), 0)
    same_earlier = (set_ix[None, :] == set_ix[:, None]) & (order < mine) \
        & want[None, :]
    do_fill = want & ~same_earlier.any(axis=1)

    victim = jnp.argmin(lru[set_ix], axis=1).astype(jnp.int32)
    # masked lanes dropped via out-of-bounds routing (matches core.tlb.fill)
    fill_set = jnp.where(do_fill, set_ix, jnp.int32(n_sets))
    tags = tags.at[fill_set, victim].set(vpn, mode="drop")
    asids = asids.at[fill_set, victim].set(asid, mode="drop")
    lru = lru.at[fill_set, victim].set(t, mode="drop")

    tags_out[...] = tags
    asids_out[...] = asids
    lru_out[...] = lru
    hit_out[...] = hit.astype(jnp.int32)


def tlb_probe_fill(tags, asids, lru, vpn, asid, active, time, *,
                   interpret: bool = False):
    """Fused probe+LRU-touch+fill. Returns (tags', asids', lru', hit)."""
    n_sets, n_ways = tags.shape
    N = vpn.shape[0]
    t_arr = jnp.full((1,), time, jnp.int32)
    return pl.pallas_call(
        _kernel,
        out_shape=[
            jax.ShapeDtypeStruct((n_sets, n_ways), jnp.int32),
            jax.ShapeDtypeStruct((n_sets, n_ways), jnp.int32),
            jax.ShapeDtypeStruct((n_sets, n_ways), jnp.int32),
            jax.ShapeDtypeStruct((N,), jnp.int32),
        ],
        input_output_aliases={0: 0, 1: 1, 2: 2},
        interpret=interpret,
    )(tags, asids, lru, vpn, asid.astype(jnp.int32),
      active.astype(jnp.int32), t_arr)
