"""MASK core: the paper's contribution as composable pure-JAX policy modules.

  asid        — address spaces / protection domains (§5.1)
  page_table  — multi-level radix walks, PTE line addressing (§3)
  tlb         — set-associative ASID-tagged TLB state (L1/L2/bypass cache)
  tokens      — TLB-Fill Tokens epoch controller (§5.2)
  bypass      — TLB-request-aware L2 data-cache bypass (§5.3)
  dram_sched  — golden/silver/normal scheduler with Eq. (1) quotas (§5.4)
  design      — composable design points: per-layer policy specs +
                registry (register_design / get_design / list_designs)
  mask        — legacy MaskConfig/DesignPoint + design(name) compat shims
"""
from repro.core.design import (BypassSpec, Design, DramSpec,  # noqa: F401
                               PartitionSpec, TokenSpec, TranslationSpec,
                               get_design, list_designs, register_design)
from repro.core.mask import (ALL_DESIGNS, DesignPoint,  # noqa: F401
                             MaskConfig, design)
