"""Hypothesis properties for the churn runner and fault layer.

Skips itself when `hypothesis` is absent (same policy as
test_core_tlb_properties.py). All draws reuse one compiled segment
executable — shapes are fixed — so examples are cheap after the first.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
import hypothesis.strategies as st  # noqa: E402
from hypothesis import given, settings  # noqa: E402

from repro.sim.faults import random_plan  # noqa: E402
from repro.sim.runner import run_mix, run_trace  # noqa: E402
from repro.sim.workloads import churn_schedule  # noqa: E402

SEG = 160          # fixed shapes: every example shares the compile
K = 4


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_property_chaos_runs_always_finish_finite(seed):
    """Any seeded churn schedule + any seeded fault plan completes with
    finite stats and an audit-clean state at every boundary."""
    sched = churn_schedule(seed=seed, n_segments=K, n_slots=2)
    plan = random_plan(seed, K, 2, rate=0.8)
    tr = run_trace("mask", sched, seg_cycles=SEG, fault_plan=plan,
                   audit=True)
    for snap in tr.segments:
        assert np.isfinite(snap["ipc"]).all()
        assert float(snap["cycles"]) > 0


@settings(max_examples=4, deadline=None)
@given(st.sampled_from(["mask", "gpu-mmu", "static", "ideal"]),
       st.sampled_from([("3DS", "BLK"), ("MUM", "RED")]))
def test_property_constant_membership_is_bitwise(design, mix):
    """Segmenting never changes the answer when membership is constant."""
    mono = run_mix(design, list(mix), cycles=K * SEG)
    tr = run_trace(design, [mix] * K, seg_cycles=SEG)
    for k in mono:
        assert np.asarray(mono[k]).tobytes() == \
            np.asarray(tr.stats[k]).tobytes(), k


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 10), st.integers(1, 4))
def test_property_churn_schedule_wellformed(seed, n_segments, n_slots):
    sched = churn_schedule(seed=seed, n_segments=n_segments,
                           n_slots=n_slots)
    assert len(sched) == n_segments
    assert all(len(s) == n_slots for s in sched)
    assert sched == churn_schedule(seed=seed, n_segments=n_segments,
                                   n_slots=n_slots)
    assert any(b is not None for b in sched[0])
