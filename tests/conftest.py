import os

import pytest


@pytest.fixture(autouse=True)
def _single_device_guard(request):
    """Smoke tests and benches must see the real (single) device — the
    512-device override belongs ONLY to the dry-run (see launch/dryrun.py).

    Tests marked `multi_device` are exempt: they spawn their own
    subprocesses with `--xla_force_host_platform_device_count=N` (the
    flag must be set before jax import, hence the subprocess — this
    process stays single-device either way).
    """
    if request.node.get_closest_marker("multi_device") is None:
        assert "xla_force_host_platform_device_count" not in \
            os.environ.get("XLA_FLAGS", "")
    yield


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
    config.addinivalue_line(
        "markers", "multi_device: spawns multi-device subprocesses "
        "(exempt from the single-device XLA_FLAGS guard)")
