"""Contention oracle: the memory-system simulator as an admission/
placement advisor for the serving engine.

Tenants declare an app *profile* ("interactive", "heavy", a Table 2
bench name, ...); the oracle maps profiles to calibrated simulator
benches (`repro.sim.profiles`) and asks the simulator how candidate
co-placements would contend: for every candidate set of tenants it
returns the predicted weighted speedup, max slowdown (unfairness), and
per-tenant slowdown of co-running their benches on the shared memory
system under the oracle's design point.

Cost discipline — the oracle must be cheap enough to consult every
decision epoch of a serving loop:

* ONE `run_grid` call per epoch: all uncached candidate mixes plus the
  solo-baseline rows their benches need batch through
  `runner.predict_mixes` as a single vmapped grid execution.
* ONE compiled program per signature group for the oracle's LIFETIME:
  mixes are padded to a fixed `slots` count and the row count to a
  fixed `pad_rows` multiple, so repeated epochs never retrace
  (pinned via `runner.TRACE_COUNT` in tests/test_serving_oracle.py).
* Memoized by frozen mix key: a candidate's benches, sorted, key its
  prediction — an epoch whose candidates were all seen before costs no
  simulation at all. Solo IPCs are cached per bench the same way.
* Fail-soft: with `fail_soft=True` (default) a failing simulation
  chunk poisons only its own candidates (their prediction is None and
  the `FailureRecord` is kept on `self.failures`); the serving loop
  keeps running on the surviving predictions.

Predictions are deterministic: the simulator is seeded and
deterministic, and candidate keys/memo insertion order are canonical.

Overload awareness (PR 10):

* KV pressure: `predict(..., pool_pressure=f)` inflates predicted
  slowdowns of multi-tenant candidates as the paged KV pool nears
  exhaustion, so admission/quota decisions anticipate page exhaustion
  BEFORE it happens (inflation is applied post-memo — the raw
  simulator prediction stays cached pressure-free).
* Self-correction: `Recalibrator` folds achieved per-tenant slowdowns
  back into the profile->bench calibration as a bounded, clamped EWMA
  correction factor — a corrupt measurement (poisoned profile, NaN)
  cannot destabilize placement.
* Tenant eviction: `evict_tenant` drops a departed tenant from the
  tenant-keyed profile-resolution cache immediately, so an id reused
  after churn can never be predicted under the dead tenant's profile.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from repro.core.design import Design, as_design
from repro.sim import runner as sim_runner
from repro.sim.profiles import DEFAULT_PROFILE, bench_for_profile


@dataclasses.dataclass(frozen=True)
class PlacementPrediction:
    """A candidate tenant co-placement with its predicted contention."""

    tenants: Tuple[int, ...]          # sorted tenant ids
    benches: Tuple[str, ...]          # aligned with `tenants`
    weighted_speedup: float
    max_slowdown: float
    slowdown: Mapping[int, float]     # per tenant

    def victim(self) -> int:
        """The tenant predicted to suffer most from this placement."""
        return max(self.tenants, key=lambda t: (self.slowdown[t], t))

    def aggressor(self) -> int:
        """The tenant predicted to suffer least — the one whose presence
        costs the others (preemption's default target)."""
        return min(self.tenants, key=lambda t: (self.slowdown[t], -t))


class Recalibrator:
    """Online profile->bench calibration correction from achieved
    slowdowns (the serving analogue of re-fitting Table 2).

    Per tenant a multiplicative correction factor `c_t` scales the
    oracle's predicted slowdowns; each decision epoch the factor moves
    toward the achieved/predicted ratio by a bounded EWMA step. Three
    guards keep a corrupt measurement (poisoned profile, NaN latency,
    a starved epoch) from destabilizing placement:

    * non-finite / non-positive measurements are ignored outright;
    * one update can move `c_t` by at most `max_step` multiplicatively;
    * `c_t` itself is clamped into `bounds` forever.
    """

    def __init__(self, alpha: float = 0.35,
                 bounds: Tuple[float, float] = (0.5, 4.0),
                 max_step: float = 1.5):
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        if bounds[0] <= 0 or bounds[0] > 1.0 or bounds[1] < 1.0:
            raise ValueError(f"bounds must bracket 1.0, got {bounds}")
        if max_step <= 1.0:
            raise ValueError(f"max_step must be > 1, got {max_step}")
        self.alpha = alpha
        self.bounds = bounds
        self.max_step = max_step
        self._corr: Dict[int, float] = {}
        self.updates = 0
        self.rejected = 0                 # corrupt measurements ignored
        self.last_delta = 0.0             # |log step| of the last update

    def correction(self, tenant: int) -> float:
        return self._corr.get(tenant, 1.0)

    def corrections(self) -> Dict[int, float]:
        return dict(self._corr)

    def observe(self, achieved: Mapping[int, float],
                predicted: Mapping[int, float]) -> None:
        """Fold one epoch's achieved per-tenant slowdowns into the
        correction factors (see class docstring for the guards)."""
        lo, hi = self.bounds
        for t in sorted(achieved):
            ach, pred = achieved[t], predicted.get(t)
            if pred is None:
                continue
            if not (math.isfinite(ach) and math.isfinite(pred)
                    and ach > 0 and pred > 0):
                self.rejected += 1
                continue
            cur = self.correction(t)
            # ratio of achieved to the CORRECTED prediction: 1.0 means
            # the current correction is already right
            ratio = ach / (pred * cur)
            ratio = min(max(ratio, 1.0 / self.max_step), self.max_step)
            step = ratio ** self.alpha
            self._corr[t] = min(max(cur * step, lo), hi)
            self.last_delta = abs(math.log(step))
            self.updates += 1

    def evict(self, tenant: int) -> None:
        """Drop a departed tenant's correction (an id reused after
        churn starts calibration-fresh)."""
        self._corr.pop(tenant, None)


class ContentionOracle:
    """Maps tenant profiles to benches and batch-predicts candidate
    placements through the simulator (see module docstring)."""

    def __init__(self, design: object = "mask", cycles: int = 1_500,
                 slots: int = 4, pad_rows: int = 16,
                 fail_soft: bool = True,
                 kv_watermark: float = 0.6, kv_gain: float = 0.6):
        self.design: Design = as_design(design)
        self.cycles = int(cycles)
        self.slots = int(slots)
        self.pad_rows = int(pad_rows)
        self.fail_soft = fail_soft
        if not 0.0 < kv_watermark < 1.0:
            raise ValueError(f"kv_watermark must be in (0,1): {kv_watermark}")
        self.kv_watermark = kv_watermark
        self.kv_gain = kv_gain
        # frozen mix key (sorted bench tuple) -> prediction (None = failed)
        self._memo: Dict[Tuple[str, ...],
                         Optional[sim_runner.MixPrediction]] = {}
        self._solo: Dict[str, float] = {}       # bench -> IPC_alone
        # tenant id -> resolved bench, evicted on tenant departure so a
        # reused id can never predict under the dead tenant's profile
        self._tenant_bench: Dict[int, str] = {}
        self.failures: List[sim_runner.FailureRecord] = []
        self.grid_calls = 0                     # run_grid invocations

    # ------------------------------------------------------------ core
    def predict_benches(self, bench_mixes: Sequence[Sequence[str]]
                        ) -> List[Optional[sim_runner.MixPrediction]]:
        """Predict raw bench mixes; memoized, one grid call for all
        fresh keys. Returns None for mixes whose simulation failed
        (fail-soft; the FailureRecord lands on `self.failures`)."""
        keys = [tuple(sorted(m)) for m in bench_mixes]
        fresh: List[Tuple[str, ...]] = []
        for k in keys:
            if k not in self._memo and k not in fresh:
                fresh.append(k)
        if fresh:
            preds = sim_runner.predict_mixes(
                self.design, fresh, cycles=self.cycles, slots=self.slots,
                pad_rows=self.pad_rows, fail_soft=self.fail_soft,
                solo_cache=self._solo)
            self.grid_calls += 1
            for k, p in zip(fresh, preds):
                if isinstance(p, sim_runner.FailureRecord):
                    self.failures.append(p)
                    self._memo[k] = None
                else:
                    self._memo[k] = p
        return [self._memo[k] for k in keys]

    def _bench_of(self, tenant: int, profiles: Mapping[int, str]) -> str:
        """Tenant -> bench through the tenant-keyed resolution cache
        (evicted by `evict_tenant` on departure — the churn-staleness
        regression surface)."""
        b = self._tenant_bench.get(tenant)
        if b is None:
            b = bench_for_profile(profiles.get(tenant, DEFAULT_PROFILE))
            self._tenant_bench[tenant] = b
        return b

    def kv_inflation(self, n_tenants: int, pool_pressure: float) -> float:
        """Multiplicative slowdown inflation anticipating KV-page
        exhaustion: grows past `kv_watermark` occupancy and with the
        candidate's width (each extra co-tenant appends pages faster),
        so wide placements become infeasible BEFORE the pool runs dry."""
        excess = max(0.0, pool_pressure - self.kv_watermark)
        if excess <= 0.0 or n_tenants <= 1:
            return 1.0
        return 1.0 + self.kv_gain * (n_tenants - 1) * excess \
            / (1.0 - self.kv_watermark)

    def predict(self, candidates: Sequence[Sequence[int]],
                profiles: Mapping[int, str],
                pool_pressure: float = 0.0
                ) -> List[Optional[PlacementPrediction]]:
        """Predict candidate tenant sets. `profiles` maps tenant id to
        a declared app profile (missing tenants get DEFAULT_PROFILE);
        `pool_pressure` (the KV pool's used_frac) inflates multi-tenant
        candidates' slowdowns post-memo (see `kv_inflation`)."""
        cands = [tuple(sorted(c)) for c in candidates]
        if any(len(c) > self.slots for c in cands):
            raise ValueError(
                f"candidate exceeds oracle slots={self.slots}: "
                f"{max(cands, key=len)}")
        benches = [tuple(self._bench_of(t, profiles) for t in c)
                   for c in cands]
        base = self.predict_benches(benches)
        out: List[Optional[PlacementPrediction]] = []
        for tenants, bs, p in zip(cands, benches, base):
            if p is None:
                out.append(None)
                continue
            # p.benches is the sorted key; align tenants the same way
            # (equal benches are interchangeable slots)
            order = sorted(zip(bs, tenants))
            infl = self.kv_inflation(len(tenants), pool_pressure)
            slowdown = {t: p.slowdown[i] * infl
                        for i, (_, t) in enumerate(order)}
            out.append(PlacementPrediction(
                tenants=tenants, benches=bs,
                weighted_speedup=p.weighted_speedup,
                max_slowdown=max(slowdown.values()), slowdown=slowdown))
        return out

    def evict_tenant(self, tenant: int) -> None:
        """Forget a departed tenant immediately: its profile resolution
        leaves the tenant-keyed cache (bench-keyed sim predictions stay
        — they are profile-content-addressed and shareable)."""
        self._tenant_bench.pop(tenant, None)

    # ------------------------------------------------------ inspection
    @property
    def memo_size(self) -> int:
        return len(self._memo)

    def tenant_benches(self) -> Dict[int, str]:
        """The live tenant->bench resolution cache (a copy)."""
        return dict(self._tenant_bench)

    def solo_ipc(self) -> Dict[str, float]:
        """Cached per-bench IPC_alone baselines (a copy)."""
        return dict(self._solo)
