"""Simulator configuration (paper Table 1, Maxwell-class).

`n_apps` is arbitrary (1 <= n_apps <= n_cores): cores are split between
apps by the oracle partition of §6 (app a owns a contiguous core range),
and the per-app core/warp counts exposed here are the single source of
truth for the scheduler, token distribution, and stats attribution.
"""
from __future__ import annotations

import dataclasses
import os
from typing import TYPE_CHECKING, Optional, Tuple

from repro.core.design import Design, as_design, get_design

if TYPE_CHECKING:   # sim.faults imports this module; annotation only
    from repro.sim.faults import FaultPlan

TLB_BACKENDS = ("xla", "pallas", "pallas-interpret")


def resolve_tlb_backend(value: Optional[str] = None) -> str:
    """Resolve the fused-round backend knob to a concrete value.

    None defers to env `REPRO_TLB_BACKEND` (default "xla"). "pallas"
    demands a real lowering: on platforms without one (CPU) it raises
    rather than silently interpreting, unless `REPRO_TLB_INTERPRET=1`
    explicitly opts into the interpreter (then it resolves to
    "pallas-interpret"). The resolved string is stored on SimConfig, so
    it participates in the frozen-dataclass hash and keys the runner's
    compile caches correctly.
    """
    v = value if value is not None else os.environ.get(
        "REPRO_TLB_BACKEND", "xla")
    v = v.strip().lower().replace("_", "-")
    if v not in TLB_BACKENDS:
        raise ValueError(
            f"tlb_backend must be one of {TLB_BACKENDS}, got {v!r}")
    if v == "pallas":
        import jax
        platform = jax.default_backend()
        if platform not in ("tpu", "gpu"):
            if os.environ.get("REPRO_TLB_INTERPRET", "") in ("1", "true",
                                                             "yes"):
                v = "pallas-interpret"
            else:
                raise RuntimeError(
                    f"tlb_backend='pallas' requested but platform "
                    f"{platform!r} has no Pallas lowering; set "
                    "tlb_backend='pallas-interpret' (or "
                    "REPRO_TLB_INTERPRET=1) to run the interpreter "
                    "explicitly, or use the 'xla' backend")
    return v


@dataclasses.dataclass(frozen=True)
class SimConfig:
    n_cores: int = 30
    warps_per_core: int = 32
    n_apps: int = 2
    # L2 data cache: 2MB, 16-way, 128B lines -> 1024 sets
    l2_sets: int = 1024
    l2_ways: int = 16
    # page-walk cache (Fig. 2a design): 16-way, 1024 entries (§3 fn. 2)
    pwc_entries: int = 1024
    pwc_ways: int = 16
    # DRAM: 8 channels x 8 banks
    n_channels: int = 8
    n_banks: int = 8
    # latencies (cycles)
    lat_l1_tlb: int = 1
    lat_l2_tlb: int = 10
    lat_l2_cache: int = 10
    lat_l1_data: int = 1
    sim_cycles: int = 60_000
    # a repro.core.design.Design; a name or legacy DesignPoint is coerced
    design: Design = dataclasses.field(
        default_factory=lambda: get_design("gpu-mmu"))
    # fused shared-round backend: "xla" | "pallas" | "pallas-interpret";
    # None resolves from env REPRO_TLB_BACKEND (see resolve_tlb_backend)
    tlb_backend: Optional[str] = None
    # deterministic chaos schedule for `runner.run_trace` (sim.faults).
    # Hashable and part of the config identity, but stripped by the
    # runner's compile-cache canonicalization: fault operands are data,
    # so every plan shares the no-fault trace.
    fault_plan: Optional["FaultPlan"] = None

    def __post_init__(self):
        if not 1 <= self.n_apps <= self.n_cores:
            raise ValueError(
                f"n_apps must be in [1, n_cores={self.n_cores}], "
                f"got {self.n_apps}")
        if not isinstance(self.design, Design):
            object.__setattr__(self, "design", as_design(self.design))
        object.__setattr__(self, "tlb_backend",
                           resolve_tlb_backend(self.tlb_backend))

    @property
    def total_warps(self) -> int:
        return self.n_cores * self.warps_per_core

    @property
    def app_of_core(self) -> Tuple[int, ...]:
        """(n_cores,) oracle core split (§6): contiguous, near-equal ranges."""
        return tuple((c * self.n_apps) // self.n_cores
                     for c in range(self.n_cores))

    @property
    def cores_per_app(self) -> Tuple[int, ...]:
        """(n_apps,) core counts under the oracle split."""
        counts = [0] * self.n_apps
        for a in self.app_of_core:
            counts[a] += 1
        return tuple(counts)

    @property
    def warps_per_app(self) -> Tuple[int, ...]:
        """(n_apps,) warp counts — token budgets and IPC denominators."""
        return tuple(c * self.warps_per_core for c in self.cores_per_app)
