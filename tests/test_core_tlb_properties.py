"""Hypothesis property tests for the TLB and page-table cores.

Kept separate from test_core_tlb.py so the deterministic unit tests still
run when `hypothesis` is absent; this module skips itself gracefully.
"""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
import hypothesis.strategies as st  # noqa: E402
from hypothesis import given, settings  # noqa: E402

from repro.core import page_table as pt  # noqa: E402
from repro.core import tlb as tlb_mod  # noqa: E402


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(0, 1000), min_size=1, max_size=16),
       st.integers(0, 3))
def test_tlb_property_fill_probe(vpns, asid):
    st_ = tlb_mod.init(64, 16)
    v = jnp.asarray(vpns, jnp.int32)
    a = jnp.full((len(vpns),), asid, jnp.int32)
    act = jnp.ones(len(vpns), bool)
    st_ = tlb_mod.fill(st_, v, a, act, 1)
    # at least the LAST filled instance of each distinct set survives
    st_, hit = tlb_mod.probe(st_, v, a, act, 2)
    # every distinct vpn whose set wasn't contended must hit
    sets = [x % 4 for x in vpns]
    for i, x in enumerate(vpns):
        if sets.count(x % 4) == 1:
            assert bool(hit[i]), (vpns, i)


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**20 - 1), st.integers(0, 2**20 - 1),
       st.integers(0, 63))
def test_pte_root_sharing_property(vpn_a, vpn_b, asid):
    """Near-root PTE lines are shared by nearby VPNs; leaves diverge."""
    cfg = pt.PageTableConfig()
    la = np.asarray(pt.pte_line_addresses(cfg, jnp.int32(asid),
                                          jnp.int32(vpn_a)))
    lb = np.asarray(pt.pte_line_addresses(cfg, jnp.int32(asid),
                                          jnp.int32(vpn_b)))
    # level 0 covers 2^27+ pages per line -> always shared for 20-bit vpns
    assert la[0] == lb[0]
    if vpn_a // 16 == vpn_b // 16:
        assert la[-1] == lb[-1]   # same leaf line
