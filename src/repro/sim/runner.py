"""Simulation runner: solo/pair runs, full design sweeps, metric extraction."""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mask import design
from repro.sim.config import SimConfig
from repro.sim.memsys import SimState, init_state, step
from repro.sim.workloads import app_matrix

jax.config.update("jax_enable_x64", False)


@functools.lru_cache(maxsize=64)
def _compiled_run(cfg: SimConfig):
    def run(params_mat):
        st = init_state(cfg)

        def body(s, _):
            return step(cfg, params_mat, s), None

        final, _ = jax.lax.scan(body, st, None, length=cfg.sim_cycles)
        return final

    return jax.jit(run)


@functools.lru_cache(maxsize=64)
def _compiled_batch_run(cfg: SimConfig):
    """vmapped over a leading batch of workload parameter matrices — one
    compile serves every pair/solo under a design."""

    def run(params_mat):
        st = init_state(cfg)

        def body(s, _):
            return step(cfg, params_mat, s), None

        final, _ = jax.lax.scan(body, st, None, length=cfg.sim_cycles)
        return final

    return jax.jit(jax.vmap(run))


IDLE_ROW = np.array([1, 1, 1024, 1, 0, 0, 1, 4000, 1024, 1], np.int32)


def run_batch(design_name: str, bench_pairs: Sequence[Tuple[str, str]],
              cycles: int = 60_000) -> List[Dict]:
    """Run many two-app workloads at once (vmap). An entry may be
    (bench, None) for a solo run (idle partner)."""
    cfg = SimConfig(n_apps=2, sim_cycles=cycles, design=design(design_name))
    mats = []
    for a, b in bench_pairs:
        rows = [app_matrix([a])[0],
                app_matrix([b])[0] if b is not None else IDLE_ROW]
        mats.append(np.stack(rows))
    pm = jnp.asarray(np.stack(mats))
    final = _compiled_batch_run(cfg)(pm)
    out = []
    for i in range(len(bench_pairs)):
        sub = jax.tree_util.tree_map(lambda x: np.asarray(x)[i], final)
        out.append(_stats(cfg, SimState(*sub)))
    return out


def _stats(cfg: SimConfig, st: SimState) -> Dict[str, np.ndarray]:
    na = cfg.n_apps
    W = cfg.total_warps
    warp_app = (np.arange(W) // cfg.warps_per_core * na) // cfg.n_cores
    instr = np.asarray(st.instr)
    ipc = np.array([instr[warp_app == a].sum() for a in range(na)]) \
        / float(st.t)
    g = lambda x: np.asarray(x, np.float64)  # noqa: E731
    l1p = g(st.s_l1_hit) + g(st.s_l1_miss)
    l2p = g(st.s_l2_hit) + g(st.s_l2_miss)
    return {
        "ipc": ipc,
        "l1_hit_rate": g(st.s_l1_hit) / np.maximum(l1p, 1),
        "l1_miss_rate": g(st.s_l1_miss) / np.maximum(l1p, 1),
        "l2_hit_rate": g(st.s_l2_hit) / np.maximum(l2p, 1),
        "l2_miss_rate": g(st.s_l2_miss) / np.maximum(l2p, 1),
        "byp_hit_rate": g(st.s_byp_hit) / np.maximum(g(st.s_byp_probe), 1),
        "walk_lat": g(st.s_walk_lat) / np.maximum(g(st.s_walks), 1),
        "walks": g(st.s_walks),
        "stalls_per_miss": g(st.s_stall_per_miss) / np.maximum(g(st.s_walks), 1),
        "dram_tlb_lat": g(st.s_dram_tlb_lat) / np.maximum(g(st.s_dram_tlb_n), 1),
        "dram_data_lat": g(st.s_dram_data_lat) / np.maximum(g(st.s_dram_data_n), 1),
        "dram_tlb_n": g(st.s_dram_tlb_n),
        "dram_data_n": g(st.s_dram_data_n),
        # L2 data-cache hit rate for TLB requests (Table 5)
        "l2c_tlb_hit_rate": (g(st.s_l2c_tlb_hit)
                             / max(g(st.s_l2c_tlb_probe), 1)),
        "l2c_data_hit_rate": (g(st.s_l2c_data_hit)
                              / max(g(st.s_l2c_data_probe), 1)),
        "tokens": np.asarray(st.tokens.tokens),
        "cycles": float(st.t),
    }


def run_pair(design_name: str, bench_a: str, bench_b: str,
             cycles: int = 60_000) -> Dict:
    """Co-run two apps under a design; returns per-app stats."""
    cfg = SimConfig(n_apps=2, sim_cycles=cycles, design=design(design_name))
    pm = jnp.asarray(app_matrix([bench_a, bench_b]))
    st = _compiled_run(cfg)(pm)
    return _stats(cfg, st)


def run_solo(design_name: str, bench: str, cycles: int = 60_000,
             half_gpu: bool = True) -> Dict:
    """IPC_alone: same core count as in the shared run (paper §6), exclusive
    memory system. Modeled as the app running twice (self-paired) under a
    partitioned ideal? No — paper: same cores, alone: we emulate by pairing
    with an idle app (zero-issue)."""
    cfg = SimConfig(n_apps=2, sim_cycles=cycles, design=design(design_name))
    # idle partner: working set 1 page, enormous think gap -> never issues
    # contention
    pm = np.stack([app_matrix([bench])[0],
                   np.array([1, 1, 1024, 0, 1, 4000, 1024], np.int32)])
    st = _compiled_run(cfg)(pm)
    return _stats(cfg, st)


def weighted_speedup(pair_stats, solo_a, solo_b) -> float:
    return float(pair_stats["ipc"][0] / max(solo_a["ipc"][0], 1e-9)
                 + pair_stats["ipc"][1] / max(solo_b["ipc"][0], 1e-9))


def max_slowdown(pair_stats, solo_a, solo_b) -> float:
    return float(max(solo_a["ipc"][0] / max(pair_stats["ipc"][0], 1e-9),
                     solo_b["ipc"][0] / max(pair_stats["ipc"][1], 1e-9)))
