"""Design-vectorized sweep coverage (static/traced design split).

Three layers:

  * `static_signature` / `canonical_design` / `design_params` contracts —
    the signature is hashable, stable under dynamic-knob changes, and
    sensitive to every shape/structure knob; the paper's 8 designs group
    into exactly TWO signatures (ideal + everything else).
  * grid == loop, bit-for-bit — `run_grid` / grid `sweep` reproduce the
    per-design `run_mix` / `Experiment` path exactly (float-hex, all 8
    designs x n_apps in {1, 2}), which chains through the pinned goldens
    in test_memsys_stages.py.
  * compile accounting — a full 8-design sweep traces exactly one
    program per signature group (TRACE_COUNT), and repeating it traces
    nothing new.
"""
import numpy as np
import pytest

from repro.core.design import (DesignParams, canonical_design, design_params,
                               get_design, static_signature)
from repro.core.mask import ALL_DESIGNS
from repro.sim import runner
from repro.sim.runner import Experiment, run_grid, run_mix, sweep

CYCLES = 1_200       # matches the float-hex goldens' executable


# ------------------------------------------------------ signature contracts

def test_builtin_designs_group_into_two_signatures():
    sigs = {name: static_signature(get_design(name)) for name in ALL_DESIGNS}
    groups = {}
    for name, sig in sigs.items():
        groups.setdefault(sig, []).append(name)
    assert len(groups) == 2
    assert groups[sigs["ideal"]] == ["ideal"]
    assert sorted(groups[sigs["mask"]]) == sorted(
        n for n in ALL_DESIGNS if n != "ideal")


def test_signature_stable_under_dynamic_knobs():
    """Dynamic (traced) knobs — policy selectors, token fracs, DRAM quota,
    partitioning, the name — must NOT change the compile key."""
    mask = get_design("mask")
    sig = static_signature(mask)
    for variant in (
            mask.with_(name="x"),
            mask.with_(tokens=dict(enabled=False, initial_frac=0.9,
                                   step_frac=0.1)),
            mask.with_(bypass=dict(enabled=False)),
            mask.with_(dram=dict(kind="fr_fcfs", thres_max=77)),
            mask.with_(partition=dict(kind="static")),
            mask.with_(translation=dict(kind="pwc")),   # non-ideal org
    ):
        assert static_signature(variant) == sig, variant
        assert hash(static_signature(variant)) == hash(sig)
        assert canonical_design(static_signature(variant)) == \
            canonical_design(sig)


def test_signature_sensitive_to_static_knobs():
    """Shape/structure knobs each produce a distinct signature."""
    mask = get_design("mask")
    base = static_signature(mask)
    variants = [
        mask.with_(translation=dict(kind="ideal")),
        mask.with_(translation=dict(l1_entries=32)),
        mask.with_(translation=dict(l2_entries=1024)),
        mask.with_(translation=dict(l2_ways=8)),
        mask.with_(translation=dict(walk_levels=3)),
        mask.with_(translation=dict(max_concurrent_walks=32)),
        mask.with_(tokens=dict(bypass_cache_entries=64)),
        mask.with_(epoch_cycles=4_000),
    ]
    sigs = [static_signature(v) for v in variants]
    assert all(s != base for s in sigs)
    assert len(set(sigs)) == len(sigs)


def test_design_params_values_and_dtypes():
    dp = design_params(get_design("mask"))
    assert isinstance(dp, DesignParams)
    assert bool(dp.use_l2_tlb) and not bool(dp.use_pwc)
    assert bool(dp.tokens_on) and bool(dp.bypass_on) and bool(dp.dram_on)
    assert not bool(dp.static_part)
    assert float(dp.initial_frac) == pytest.approx(0.25)
    assert int(dp.thres_max) == 500
    for leaf in dp:
        assert leaf.shape == ()
    dp_pwc = design_params(get_design("pwc"))
    assert bool(dp_pwc.use_pwc) and not bool(dp_pwc.use_l2_tlb)
    assert not bool(dp_pwc.tokens_on)
    assert bool(design_params(get_design("static")).static_part)


# ------------------------------------------------------- grid == loop exact

def _hexed(s):
    return {k: [x.hex() for x in
                np.asarray(v, np.float64).ravel().tolist()] for k, v in
            s.items()}


@pytest.mark.parametrize("n_apps,mix", [(1, ("3DS",)), (2, ("3DS", "BLK"))])
def test_grid_matches_loop_bitforbit(n_apps, mix):
    """run_grid over all 8 designs == per-design run_mix, float-hex exact
    (so the grid path inherits the GOLDEN pins of test_memsys_stages)."""
    grid = run_grid(list(ALL_DESIGNS), [mix], cycles=CYCLES)
    for i, name in enumerate(ALL_DESIGNS):
        loop = _hexed(run_mix(name, list(mix), cycles=CYCLES))
        got = _hexed(grid[i][0])
        assert got == loop, f"{name} n_apps={n_apps} drifted from loop"


def test_sweep_grid_matches_experiment_loop():
    """Grid-path sweep == per-design Experiment loop: same raw stats
    (float-hex), same derived metrics, same solo-baseline bookkeeping."""
    designs = ["ideal", "gpu-mmu", "mask"]
    mixes = [("3DS", "BLK"), ("MUM", "RED")]
    g = sweep(designs, mixes, cycles=CYCLES, grid=True)
    for name in designs:
        ell = Experiment(name, mixes, cycles=CYCLES).run()
        assert set(g) == set(designs)
        gres = g[name]
        assert gres.solo_ipc == ell.solo_ipc
        assert len(gres) == len(ell)
        for rg, rl in zip(gres, ell):
            assert rg.benches == rl.benches
            assert _hexed(rg.raw) == _hexed(rl.raw)
            assert rg.weighted_speedup() == rl.weighted_speedup()
            assert rg.unfairness() == rl.unfairness()


# --------------------------------------------------------- compile counting

def test_full_sweep_traces_one_program_per_signature_group():
    """The 8-design x 2-mix sweep (solo baselines included) compiles
    exactly len(signature groups) == 2 programs; re-running it compiles
    nothing."""
    mixes = [("3DS", "BLK"), ("MUM", "RED")]
    cycles = 977          # unique -> cannot reuse another test's programs
    before = runner.TRACE_COUNT
    res = sweep(list(ALL_DESIGNS), mixes, cycles=cycles)
    assert runner.TRACE_COUNT - before == 2, \
        "expected ONE traced program per signature group"
    assert set(res) == set(ALL_DESIGNS)
    again = sweep(list(ALL_DESIGNS), mixes, cycles=cycles)
    assert runner.TRACE_COUNT - before == 2, "re-sweep must not retrace"
    for name in ALL_DESIGNS:
        for a, b in zip(res[name], again[name]):
            assert _hexed(a.raw) == _hexed(b.raw)
