"""Training loop, checkpoint/restart, fault-tolerance policy tests."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import ARCHS, reduced_model
from repro.configs.base import RunConfig, ShapeConfig
from repro.distributed.fault_tolerance import (
    MeshTopology, StragglerPolicy, elastic_remesh, resume_or_init)
from repro.train import optimizer as opt_mod
from repro.train.loop import TrainConfig, train


def _tiny_run(steps=12, ckpt_dir=None, seed=0):
    cfg = reduced_model(ARCHS["qwen3-4b"])
    shape = ShapeConfig("t", seq_len=32, global_batch=4, kind="train")
    run = RunConfig(model=cfg, shape=shape, remat=False,
                    attn_block_q=16, attn_block_k=16)
    tcfg = TrainConfig(steps=steps, ckpt_dir=ckpt_dir, ckpt_every=5,
                       log_every=2, seed=seed,
                       opt=opt_mod.OptConfig(lr=2e-3, warmup_steps=2))
    return cfg, run, tcfg


@pytest.mark.slow
def test_loss_decreases():
    cfg, run, tcfg = _tiny_run(steps=30)
    out = train(cfg, run, tcfg, log=lambda *_: None)
    hist = out["history"]
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.1


@pytest.mark.slow
def test_checkpoint_restart_exact(tmp_path):
    """Crash after step 10, restart, and land bit-identical to an unbroken
    run (deterministic data skip-ahead + atomic snapshots)."""
    cfg, run, tcfg = _tiny_run(steps=10, ckpt_dir=str(tmp_path / "a"))
    out_a = train(cfg, run, tcfg, log=lambda *_: None)

    # unbroken reference: same seed, 10 steps, separate dir
    cfg, run, tcfg_b = _tiny_run(steps=10, ckpt_dir=str(tmp_path / "b"))
    out_b = train(cfg, run, tcfg_b, log=lambda *_: None)
    for a, b in zip(jax.tree_util.tree_leaves(out_a["params"]),
                    jax.tree_util.tree_leaves(out_b["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # now simulate restart: resume dir 'a' to 14 steps, vs fresh 14-step run
    cfg, run, tcfg_c = _tiny_run(steps=14, ckpt_dir=str(tmp_path / "a"))
    out_c = train(cfg, run, tcfg_c, log=lambda *_: None)
    cfg, run, tcfg_d = _tiny_run(steps=14, ckpt_dir=str(tmp_path / "d"))
    out_d = train(cfg, run, tcfg_d, log=lambda *_: None)
    for a, b in zip(jax.tree_util.tree_leaves(out_c["params"]),
                    jax.tree_util.tree_leaves(out_d["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity(tmp_path):
    ck = Checkpointer(str(tmp_path))
    params = {"w": jnp.ones((3,))}
    opt = {"m": jnp.zeros((3,)), "step": jnp.zeros((), jnp.int32)}
    ck.save(5, params, opt)
    # partial (uncommitted) newer step must be ignored
    bad = tmp_path / "step_000000009"
    bad.mkdir()
    (bad / "shard_0.npz").write_bytes(b"garbage")
    assert ck.latest_step() == 5
    p2, o2, _ = ck.restore(5, params, opt)
    np.testing.assert_array_equal(np.asarray(p2["w"]), np.ones(3))


def test_checkpoint_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    params = {"w": jnp.ones((2,))}
    opt = {"step": jnp.zeros((), jnp.int32)}
    for s in (1, 2, 3, 4):
        ck.save(s, params, opt)
    assert ck._committed_steps() == [3, 4]


def test_elastic_remesh():
    t = MeshTopology(pod=2, data=16, model=16)
    # lose one pod worth of chips -> single-pod topology
    t2 = elastic_remesh(t, lost_chips=256)
    assert t2 == MeshTopology(1, 16, 16)
    # lose a few chips -> drop to half data axis within 2 pods... policy
    t3 = elastic_remesh(t, lost_chips=10)
    assert t3.chips <= 502 and t3.model == 16
    # catastrophic loss
    assert elastic_remesh(MeshTopology(1, 2, 16), lost_chips=31) is None


def test_straggler_policy():
    sp = StragglerPolicy(threshold=3.0, warmup_steps=3)
    flagged = [sp.record(0.1) for _ in range(10)]
    assert not any(flagged)
    assert sp.record(0.5)   # 5x median
    assert not sp.record(0.12)


def test_resume_or_init_fresh(tmp_path):
    ck = Checkpointer(str(tmp_path))
    calls = []

    def init_fn():
        calls.append(1)
        return {"w": jnp.zeros((2,))}, {"step": jnp.zeros((), jnp.int32)}

    p, o, start = resume_or_init(ck, init_fn)
    assert start == 0 and len(calls) == 1
