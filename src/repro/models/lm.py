"""Model assembly: decoder LMs, MoE, SSM, hybrid, and enc-dec backbones.

All architectures compile to one structure: an embedding, a ``lax.scan`` over
parameter *blocks* (a block = the smallest repeating layer pattern — 1 layer
for homogeneous models, 8 for jamba's 1:7 mamba:attention interleave), a
final norm, and a (possibly tied) vocab projection.

Three modes:
  * ``full``   — train / prefill over (B, S); optionally emits KV caches.
  * ``decode`` — one token per sequence against mutable caches.

Caches are dicts of stacked arrays with leading (repeats, per_block_count)
dims so they thread through the same scan as the parameters.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.models import attention as attn_mod
from repro.models import layers as L
from repro.models import mamba2 as m2
from repro.models import moe as moe_mod
from repro.models.params import Param

Constrain = Callable[[jax.Array, Tuple[Optional[str], ...]], jax.Array]


def _noop_constrain(x, axes):
    return x


# ---------------------------------------------------------------------------
# Block pattern
# ---------------------------------------------------------------------------

def _scan_group(R: int) -> int:
    """Largest divisor of R in [4, 16] closest to sqrt(R); 1 if R < 24."""
    if R < 24:
        return 1
    target = R ** 0.5
    divs = [g for g in range(4, 17) if R % g == 0]
    if not divs:
        return 1
    return min(divs, key=lambda g: abs(g - target))


def block_pattern(cfg: ModelConfig) -> Tuple[int, Tuple[str, ...], Tuple[str, ...]]:
    """Return (period P, kinds[:P], ffns[:P]) — smallest repeating pattern."""
    kinds, ffns = cfg.layer_kinds(), cfg.ffn_kinds()
    n = cfg.n_layers
    for p in range(1, n + 1):
        if n % p:
            continue
        if all(kinds[i] == kinds[i % p] and ffns[i] == ffns[i % p]
               for i in range(n)):
            return p, kinds[:p], ffns[:p]
    return n, kinds, ffns


def _layer_param_tree(cfg: ModelConfig, kind: str, ffn: str) -> Dict[str, Any]:
    d = cfg.d_model
    p: Dict[str, Any] = {"norm1": L.rmsnorm_params(d)}
    if kind == "attn":
        p["attn"] = attn_mod.attn_params(
            d, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.qk_norm)
        if cfg.is_enc_dec:
            p["cross_norm"] = L.rmsnorm_params(d)
            p["cross"] = attn_mod.attn_params(
                d, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, False)
    else:
        p["ssm"] = m2.mamba2_params(cfg)
    if cfg.d_ff > 0 or ffn == "moe":
        p["norm2"] = L.rmsnorm_params(d)
        if ffn == "moe":
            p["moe"] = moe_mod.moe_params(d, cfg.expert_d_ff, cfg.n_experts)
        else:
            p["mlp"] = L.mlp_params(d, cfg.d_ff)
    return p


def build_param_specs(cfg: ModelConfig) -> Dict[str, Any]:
    """Full model Param-spec tree (see repro.models.params)."""
    P, kinds, ffns = block_pattern(cfg)
    R = cfg.n_layers // P
    from repro.models.params import stack_params

    block = {f"layer{j}": _layer_param_tree(cfg, kinds[j], ffns[j])
             for j in range(P)}
    blocks = stack_params([block] * R) if R > 1 else block

    specs: Dict[str, Any] = {
        "embed": L.embed_params(cfg.padded_vocab, cfg.d_model),
        "final_norm": L.rmsnorm_params(cfg.d_model),
        "blocks": blocks,
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = L.lm_head_params(cfg.padded_vocab, cfg.d_model)
    if cfg.n_patches:
        specs["patch_proj"] = {
            "w": Param((cfg.d_model, cfg.d_model), ("embed", "embed2"))}
    if cfg.is_enc_dec:
        enc_layer = {
            "norm1": L.rmsnorm_params(cfg.d_model),
            "attn": attn_mod.attn_params(
                cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, False),
            "norm2": L.rmsnorm_params(cfg.d_model),
            "mlp": L.mlp_params(cfg.d_model, cfg.d_ff),
        }
        specs["encoder"] = {
            "blocks": stack_params([enc_layer] * cfg.n_enc_layers)
            if cfg.n_enc_layers > 1 else enc_layer,
            "norm": L.rmsnorm_params(cfg.d_model),
        }
    return specs


# ---------------------------------------------------------------------------
# Cache specs (decode)
# ---------------------------------------------------------------------------

def cache_shapes(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    """ShapeDtypeStruct tree for the decode cache. SWA archs get a ring
    buffer bounded by the window; SSM layers get O(1) state."""
    P, kinds, ffns = block_pattern(cfg)
    R = cfg.n_layers // P
    n_attn = sum(1 for k in kinds if k == "attn")
    n_ssm = P - n_attn
    S = max_len if cfg.sliding_window is None else min(max_len, cfg.sliding_window)
    out: Dict[str, Any] = {
        "cache_len": jax.ShapeDtypeStruct((batch,), jnp.int32)}
    if n_attn:
        dh, KV = cfg.head_dim, cfg.n_kv_heads
        kv = jax.ShapeDtypeStruct((R, n_attn, batch, S, KV, dh), jnp.bfloat16)
        out["k"] = kv
        out["v"] = kv
    if n_ssm:
        st = m2.ssm_state_specs(cfg, batch)
        out["ssm_h"] = jax.ShapeDtypeStruct((R, n_ssm) + st.h.shape, st.h.dtype)
        out["ssm_conv"] = jax.ShapeDtypeStruct(
            (R, n_ssm) + st.conv.shape, st.conv.dtype)
    if cfg.is_enc_dec and n_attn:
        ckv = jax.ShapeDtypeStruct(
            (R, n_attn, batch, cfg.enc_len, KV, dh), jnp.bfloat16)
        out["cross_k"] = ckv
        out["cross_v"] = ckv
    return out


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    return jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype), cache_shapes(cfg, batch, max_len))


# ---------------------------------------------------------------------------
# Layer application
# ---------------------------------------------------------------------------

def _self_attention_full(cfg, run, lp, x, positions, constrain, build_cache):
    q, k, v = attn_mod.project_qkv(
        lp["attn"], x, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
        dh=cfg.head_dim, positions=positions, rope_theta=cfg.rope_theta,
        qk_norm=cfg.qk_norm)
    q = constrain(q, ("batch", None, "heads", None))
    k = constrain(k, ("batch", None, "kv_heads", None))
    o = attn_mod.attention(
        q, k, v, impl=run.attention_impl, causal=True,
        window=cfg.sliding_window, block_q=run.attn_block_q,
        block_k=run.attn_block_k)
    o = o.reshape(o.shape[0], o.shape[1], cfg.n_heads * cfg.head_dim)
    out = jnp.einsum("bsh,hd->bsd", o, lp["attn"]["wo"])
    cache = (k, v) if build_cache else None
    return constrain(out, ("batch", None, "embed")), cache


def _self_attention_decode(cfg, run, lp, x, cache_k, cache_v, cache_len,
                           constrain):
    """x: (B,1,d); cache_k/v: (B,S,KV,dh); returns out, updated caches."""
    B = x.shape[0]
    positions = cache_len[:, None]  # absolute positions (B,1)
    q, k, v = attn_mod.project_qkv(
        lp["attn"], x, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
        dh=cfg.head_dim, positions=positions, rope_theta=cfg.rope_theta,
        qk_norm=cfg.qk_norm)
    S = cache_k.shape[1]
    if cfg.sliding_window is not None and S <= cfg.sliding_window:
        slot = cache_len % S                       # ring buffer
    else:
        slot = jnp.minimum(cache_len, S - 1)
    bidx = jnp.arange(B)
    cache_k = cache_k.at[bidx, slot].set(k[:, 0])
    cache_v = cache_v.at[bidx, slot].set(v[:, 0])
    cache_k = constrain(cache_k, ("batch", "kvseq", "kv_heads", None))
    cache_v = constrain(cache_v, ("batch", "kvseq", "kv_heads", None))
    if cfg.sliding_window is not None and S <= cfg.sliding_window:
        # ring: everything currently stored is in-window and valid
        n_valid = jnp.minimum(cache_len + 1, S)
        o = attn_mod.decode_attention_dense(q, cache_k, cache_v, n_valid)
    else:
        o = attn_mod.decode_attention_dense(
            q, cache_k, cache_v, cache_len + 1, window=cfg.sliding_window)
    o = o.reshape(B, 1, cfg.n_heads * cfg.head_dim)
    out = jnp.einsum("bsh,hd->bsd", o, lp["attn"]["wo"])
    return constrain(out, ("batch", None, "embed")), cache_k, cache_v


def _cross_attention(cfg, run, lp, x, enc_out=None, cross_kv=None,
                     constrain=_noop_constrain):
    """Cross attention: enc_out given in full mode; cached k/v in decode."""
    B, S, _ = x.shape
    dh, KV = cfg.head_dim, cfg.n_kv_heads
    q = jnp.einsum("bsd,dh->bsh", x, lp["cross"]["wq"]).reshape(
        B, S, cfg.n_heads, dh)
    if cross_kv is None:
        k = jnp.einsum("bsd,dh->bsh", enc_out, lp["cross"]["wk"]).reshape(
            B, -1, KV, dh)
        v = jnp.einsum("bsd,dh->bsh", enc_out, lp["cross"]["wv"]).reshape(
            B, -1, KV, dh)
    else:
        k, v = cross_kv
    if S == 1 or S * k.shape[1] <= 1 << 20:
        o = attn_mod.naive_attention(q, k, v, causal=False)
    else:
        # q-blocked only; kv kept whole (enc_len is small and need not divide
        # a k-block size)
        bq = S // max(1, S // min(run.attn_block_q, S))
        while S % bq:
            bq -= 1
        o = attn_mod.blocked_attention(q, k, v, causal=False,
                                       block_q=bq, block_k=k.shape[1])
    o = o.reshape(B, S, cfg.n_heads * dh)
    out = jnp.einsum("bsh,hd->bsd", o, lp["cross"]["wo"])
    return constrain(out, ("batch", None, "embed")), (k, v)


def _ffn(cfg, run, lp, x, constrain):
    aux = None
    if "moe" in lp:
        y, aux = moe_mod.moe_apply(
            lp["moe"], x, top_k=cfg.top_k,
            capacity_factor=cfg.capacity_factor, constrain=constrain)
    else:
        h = x
        y = L.mlp(lp["mlp"], h)
    return constrain(y, ("batch", None, "embed")), aux


# ---------------------------------------------------------------------------
# Backbone
# ---------------------------------------------------------------------------

def backbone(cfg: ModelConfig, run: RunConfig, params, x, positions, *,
             mode: str = "full", caches=None, enc_out=None,
             constrain: Constrain = _noop_constrain, build_cache=False):
    """x: (B,S,d) embedded inputs. Returns (hidden, new_caches, aux_losses)."""
    P, kinds, ffns = block_pattern(cfg)
    R = cfg.n_layers // P
    attn_ix = [j for j in range(P) if kinds[j] == "attn"]
    ssm_ix = [j for j in range(P) if kinds[j] == "ssm"]

    # per-layer remat inside multi-layer blocks (jamba superblocks): without
    # it the block VJP holds all P layers' internals (SSD decay matrices,
    # MoE dispatch buffers) live at once.
    layer_remat = run.remat and mode == "full" and P > 1

    def apply_block(x, bp, bc):
        """One block of P layers. bc: this block's cache slices (leading dim =
        per-block count). Returns (x, new_bc, aux_sum)."""
        if run.quantize_weights:
            from repro.models.quant import dequant_tree
            bp = dequant_tree(bp)   # per-layer: fuses into consumers
        new_bc = dict(bc) if bc else {}
        aux_sum = jnp.zeros((), jnp.float32)
        kv_out = []
        ssm_out = []
        cross_out = []
        for j in range(P):
            lp = bp[f"layer{j}"]
            if layer_remat:
                def layer_fn(x_in, lp_in, _kind=kinds[j]):
                    h_in = L.rmsnorm(lp_in["norm1"], x_in, cfg.norm_eps)
                    if _kind == "attn":
                        o_in, _ = _self_attention_full(
                            cfg, run, lp_in, h_in, positions, constrain, False)
                    else:
                        o_in, _ = m2.mamba2_forward(lp_in["ssm"], cfg, h_in,
                                                    constrain=constrain)
                        o_in = constrain(o_in, ("batch", None, "embed"))
                    x_in = x_in + o_in
                    a_in = jnp.zeros((), jnp.float32)
                    if "norm2" in lp_in:
                        h2_in = L.rmsnorm(lp_in["norm2"], x_in, cfg.norm_eps)
                        y_in, aux_in = _ffn(cfg, run, lp_in, h2_in, constrain)
                        x_in = x_in + y_in
                        if aux_in is not None:
                            a_in = aux_in["lb_loss"] + 1e-3 * aux_in["z_loss"]
                    return x_in, a_in

                x, a_j = jax.checkpoint(layer_fn)(x, lp)
                aux_sum = aux_sum + a_j
                continue
            h = L.rmsnorm(lp["norm1"], x, cfg.norm_eps)
            if kinds[j] == "attn":
                a = attn_ix.index(j)
                if mode == "decode":
                    o, ck, cv = _self_attention_decode(
                        cfg, run, lp, h, bc["k"][a], bc["v"][a],
                        bc["cache_len"], constrain)
                    kv_out.append((ck, cv))
                else:
                    o, kv = _self_attention_full(
                        cfg, run, lp, h, positions, constrain, build_cache)
                    if build_cache:
                        kv_out.append(kv)
                x = x + o
                if cfg.is_enc_dec:
                    h2 = L.rmsnorm(lp["cross_norm"], x, cfg.norm_eps)
                    ckv = None
                    if mode == "decode":
                        ckv = (bc["cross_k"][a], bc["cross_v"][a])
                    o2, ckv_new = _cross_attention(
                        cfg, run, lp, h2, enc_out=enc_out, cross_kv=ckv,
                        constrain=constrain)
                    x = x + o2
                    if build_cache:
                        cross_out.append(ckv_new)
            else:
                m = ssm_ix.index(j)
                if mode == "decode":
                    st = m2.SSMState(h=bc["ssm_h"][m], conv=bc["ssm_conv"][m])
                    o, st = m2.mamba2_decode(lp["ssm"], cfg, h, st)
                    ssm_out.append(st)
                else:
                    st0 = None
                    o, st = m2.mamba2_forward(lp["ssm"], cfg, h, st0,
                                              constrain=constrain)
                    if build_cache:
                        ssm_out.append(st)
                x = x + constrain(o, ("batch", None, "embed"))
            if "norm2" in lp:
                h = L.rmsnorm(lp["norm2"], x, cfg.norm_eps)
                y, aux = _ffn(cfg, run, lp, h, constrain)
                x = x + y
                if aux is not None:
                    aux_sum = aux_sum + aux["lb_loss"] + 1e-3 * aux["z_loss"]
        if kv_out:
            new_bc["k"] = jnp.stack([k for k, _ in kv_out])
            new_bc["v"] = jnp.stack([v for _, v in kv_out])
        if ssm_out:
            new_bc["ssm_h"] = jnp.stack([s.h for s in ssm_out])
            new_bc["ssm_conv"] = jnp.stack([s.conv for s in ssm_out])
        if cross_out:
            new_bc["cross_k"] = jnp.stack([k for k, _ in cross_out])
            new_bc["cross_v"] = jnp.stack([v for _, v in cross_out])
        return x, new_bc, aux_sum

    # --- cache xs for the scan (strip cache_len: it's shared, not stacked) ---
    cache_len = caches["cache_len"] if caches else None
    scan_caches = {k: v for k, v in (caches or {}).items() if k != "cache_len"}

    if R == 1:
        bc = {k: v[0] for k, v in scan_caches.items()}
        if cache_len is not None:
            bc["cache_len"] = cache_len
        x, new_bc, aux = apply_block(x, params["blocks"], bc)
        new_caches = {k: v[None] for k, v in new_bc.items() if k != "cache_len"}
    else:
        def body(carry, xs):
            x, aux = carry
            bp, bc = xs
            if cache_len is not None:
                bc = dict(bc, cache_len=cache_len)
            x, new_bc, aux_b = apply_block(x, bp, bc)
            new_bc.pop("cache_len", None)
            return (x, aux + aux_b), new_bc

        remat_scan = run.remat and mode == "full"
        # nested sqrt(R) checkpointing for deep stacks: only R/G block
        # boundaries are saved; one group of G blocks is rematerialized at a
        # time during the backward pass.
        group = _scan_group(R) if (remat_scan and not scan_caches) else 1
        if group > 1:
            grouped = jax.tree_util.tree_map(
                lambda a: a.reshape((R // group, group) + a.shape[1:]),
                params["blocks"])

            def outer(carry, bp_group):
                return jax.lax.scan(jax.checkpoint(body), carry,
                                    (bp_group, {}))

            (x, aux), new_caches = jax.lax.scan(
                jax.checkpoint(outer), (x, jnp.zeros((), jnp.float32)),
                grouped)
        else:
            body_fn = jax.checkpoint(body) if remat_scan else body
            (x, aux), new_caches = jax.lax.scan(
                body_fn, (x, jnp.zeros((), jnp.float32)),
                (params["blocks"], scan_caches))

    if mode == "decode":
        new_caches["cache_len"] = cache_len + 1
    elif build_cache:
        new_caches["cache_len"] = jnp.full((x.shape[0],), x.shape[1], jnp.int32)
    else:
        new_caches = None

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, new_caches, aux


# ---------------------------------------------------------------------------
# Encoder (whisper)
# ---------------------------------------------------------------------------

def encode(cfg: ModelConfig, run: RunConfig, params, frames,
           constrain: Constrain = _noop_constrain):
    """frames: (B, enc_len, d) precomputed frame embeddings (stub frontend)."""
    enc = params["encoder"]
    positions = jnp.arange(frames.shape[1])[None, :]

    def enc_layer(x, lp):
        h = L.rmsnorm(lp["norm1"], x, cfg.norm_eps)
        q, k, v = attn_mod.project_qkv(
            lp["attn"], h, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
            dh=cfg.head_dim, positions=positions, rope_theta=cfg.rope_theta)
        o = attn_mod.attention(q, k, v, impl="naive" if frames.shape[1] <= 2048
                               else run.attention_impl, causal=False)
        o = o.reshape(*o.shape[:2], -1)
        x = x + jnp.einsum("bsh,hd->bsd", o, lp["attn"]["wo"])
        h = L.rmsnorm(lp["norm2"], x, cfg.norm_eps)
        return x + L.mlp(lp["mlp"], h), None

    if cfg.n_enc_layers > 1:
        x, _ = jax.lax.scan(lambda c, lp: enc_layer(c, lp),
                            frames, enc["blocks"])
    else:
        x, _ = enc_layer(frames, enc["blocks"])
    return L.rmsnorm(enc["norm"], x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# Top-level entries
# ---------------------------------------------------------------------------

def embed_inputs(cfg, params, batch, constrain: Constrain = _noop_constrain):
    """Assemble (B,S,d) input embeddings from the batch dict."""
    x = L.embed(params["embed"], batch["tokens"])
    if cfg.n_patches and "patch_embeds" in batch:
        pe = jnp.einsum("bpd,de->bpe", batch["patch_embeds"],
                        params["patch_proj"]["w"])
        x = jnp.concatenate([pe.astype(x.dtype), x], axis=1)
    return constrain(x, ("batch", None, "embed"))


def logits_fn(cfg, params, hidden, constrain: Constrain = _noop_constrain):
    table = params["embed"]["table"] if cfg.tie_embeddings \
        else params["lm_head"]["table"]
    logits = jnp.einsum("bsd,vd->bsv", hidden, table)
    return constrain(logits, ("batch", None, "vocab"))


def forward_train(cfg, run, params, batch, constrain=_noop_constrain):
    """Returns (logits, aux_loss)."""
    enc_out = None
    if cfg.is_enc_dec:
        enc_out = encode(cfg, run, params, batch["frames"], constrain)
    x = embed_inputs(cfg, params, batch, constrain)
    positions = jnp.arange(x.shape[1])[None, :]
    h, _, aux = backbone(cfg, run, params, x, positions, mode="full",
                         enc_out=enc_out, constrain=constrain)
    return logits_fn(cfg, params, h, constrain), aux


def forward_prefill(cfg, run, params, batch, max_len,
                    constrain=_noop_constrain):
    """Returns (last-token logits, caches ready for decode)."""
    enc_out = None
    if cfg.is_enc_dec:
        enc_out = encode(cfg, run, params, batch["frames"], constrain)
    x = embed_inputs(cfg, params, batch, constrain)
    positions = jnp.arange(x.shape[1])[None, :]
    h, caches, aux = backbone(cfg, run, params, x, positions, mode="full",
                              enc_out=enc_out, constrain=constrain,
                              build_cache=True)
    logits = logits_fn(cfg, params, h[:, -1:], constrain)
    caches = _pad_prefill_caches(cfg, caches, max_len)
    return logits, caches


def _pad_prefill_caches(cfg, caches, max_len):
    """Grow prefill KV to the decode cache capacity (right-padded)."""
    out = dict(caches)
    for key in ("k", "v"):
        if key in caches:
            arr = caches[key]  # (R, A, B, S, KV, dh)
            S = arr.shape[3]
            cap = max_len if cfg.sliding_window is None \
                else min(max_len, cfg.sliding_window)
            if cap > S:
                pad = [(0, 0)] * arr.ndim
                pad[3] = (0, cap - S)
                out[key] = jnp.pad(arr, pad)
            elif cap < S:
                out[key] = arr[:, :, :, S - cap:]
    return out


def forward_decode(cfg, run, params, token_batch, caches, enc_out=None,
                   constrain=_noop_constrain):
    """token_batch: {'tokens': (B,1)}; returns (logits (B,1,V), new caches)."""
    x = embed_inputs(cfg, params, token_batch, constrain)
    h, new_caches, _ = backbone(cfg, run, params, x, None, mode="decode",
                                caches=caches, enc_out=enc_out,
                                constrain=constrain)
    return logits_fn(cfg, params, h, constrain), new_caches
