"""jit'd public wrapper: layout handling, GQA, CPU-interpret fallback."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bhsd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal=True, window: Optional[int] = None,
                    block_q: int = 512, block_k: int = 512,
                    interpret: Optional[bool] = None):
    """q: (B, S, H, dh); k, v: (B, S, KV, dh) — model-native layout.

    Returns (B, S, H, dh). On CPU the kernel body runs in interpret mode
    (correctness path); on TPU it compiles to Mosaic.
    """
    if interpret is None:
        interpret = not _on_tpu()
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=interpret)
    return jnp.swapaxes(out, 1, 2)
