"""Seeded trace-driven multi-tenant request streams for the engine.

Models the host side of an MLPerf-style offline/server inference
harness: a trace is a per-step list of request arrivals that the
driver submits into the engine's host-side queues ahead of each
continuous-batching step. Arrival processes are per-tenant Poisson,
optionally modulated:

* bursty    — on/off duty cycling (same mean rate, concentrated into
              bursts of `burst_period * burst_duty` steps)
* heavy-tail — Pareto-ish decode lengths (a few requests decode for
              much longer than the median, the classic serving tail)
* churn     — tenants are only live inside their [start, stop) window

Every tenant draws from its OWN RandomState seeded by (trace seed,
tenant id), so a trace replays bit-identically for every policy under
test, and restricting a trace to one tenant (`TraceSpec.only`, the
solo-latency baseline) leaves that tenant's arrivals/lengths untouched
— the A/B discipline the serving benchmark
(`benchmarks/serving_bench.py`) depends on. Prompt lengths come from a
small bucket set so the engine's prefill compiles stay bounded.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.engine import Request, ServingEngine
from repro.sim.workloads import churn_schedule


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant's traffic model inside a trace."""

    tenant: int
    profile: str = "batch"            # -> sim bench via repro.sim.profiles
    rate: float = 0.2                 # mean arrivals per engine step
    prompt_lens: Tuple[int, ...] = (8, 16)   # bucketed (compile-friendly)
    max_new: int = 6                  # decode steps per request
    heavy_tail: bool = False          # Pareto decode lengths (mean ~max_new)
    burst_period: int = 0             # >0: on/off modulated Poisson
    burst_duty: float = 0.5           # fraction of the period that is "on"
    start: int = 0                    # live window [start, stop)
    stop: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class TraceSpec:
    """A named, seeded multi-tenant traffic trace."""

    name: str
    steps: int
    specs: Tuple[TenantSpec, ...]
    seed: int = 0

    def profiles(self) -> Dict[int, str]:
        return {s.tenant: s.profile for s in self.specs}

    def only(self, tenant: int) -> "TraceSpec":
        """The same trace restricted to one tenant (solo baseline).

        Tenants draw from independent per-tenant RandomStates, so the
        kept tenant sees the SAME arrivals/lengths as in the shared
        trace — the solo run isolates scheduling contention, not a
        different workload.
        """
        specs = tuple(s for s in self.specs if s.tenant == tenant)
        return dataclasses.replace(self, name=f"{self.name}:solo{tenant}",
                                   specs=specs)


def _rate_at(spec: TenantSpec, step: int) -> float:
    if step < spec.start or (spec.stop is not None and step >= spec.stop):
        return 0.0
    if spec.burst_period > 0:
        on = (step % spec.burst_period) < spec.burst_duty * spec.burst_period
        return spec.rate / max(spec.burst_duty, 1e-9) if on else 0.0
    return spec.rate


def arrivals(trace: TraceSpec, vocab_size: int,
             rid_base: int = 0) -> List[List[Request]]:
    """Materialize the trace: `out[step]` is the list of requests to
    submit before engine step `step`. Deterministic in `trace.seed`;
    each tenant owns an independent (seed, tenant)-derived stream, so
    one tenant's params never shift another tenant's draws (and
    `TraceSpec.only` baselines replay the kept tenant exactly)."""
    rngs = {s.tenant: np.random.RandomState(
        (trace.seed * 1_000_003 + s.tenant) % (2 ** 31))
        for s in trace.specs}
    out: List[List[Request]] = []
    rid = rid_base
    for step in range(trace.steps):
        batch: List[Request] = []
        for spec in trace.specs:
            rng = rngs[spec.tenant]
            n = int(rng.poisson(_rate_at(spec, step)))
            for _ in range(n):
                plen = int(spec.prompt_lens[
                    rng.randint(len(spec.prompt_lens))])
                if spec.heavy_tail:
                    max_new = int(min(
                        1 + rng.pareto(1.5) * spec.max_new,
                        8 * spec.max_new))
                else:
                    max_new = spec.max_new
                batch.append(Request(
                    rid=rid, tenant=spec.tenant,
                    prompt=rng.randint(0, vocab_size, plen),
                    max_new=max_new))
                rid += 1
        out.append(batch)
    return out


# ------------------------------------------------- shared churn timeline

def schedule_to_specs(schedule: Sequence[Tuple[Optional[str], ...]],
                      seg_steps: int, rate: float = 0.35,
                      prompt_lens: Tuple[int, ...] = (8,),
                      max_new: int = 6) -> Tuple[TenantSpec, ...]:
    """Map a `sim.workloads.churn_schedule` (per-segment bench tuples,
    None = empty slot) onto serving `TenantSpec`s: each contiguous
    occupancy interval of a slot becomes a FRESH tenant (new id) live on
    [seg_start * seg_steps, seg_end * seg_steps) with the slot's bench
    as its declared profile. The simulator's segmented runner and the
    serving trace driver thereby share ONE seeded timeline generator —
    the same birth-death draw drives both. (A same-bench hand-off at a
    boundary is indistinguishable in the tuple encoding and coalesces
    into one tenant.)"""
    if seg_steps < 1:
        raise ValueError(f"seg_steps must be >= 1, got {seg_steps}")
    specs: List[TenantSpec] = []
    n_slots = len(schedule[0])
    tenant = 0
    for slot in range(n_slots):
        seg = 0
        while seg < len(schedule):
            bench = schedule[seg][slot]
            if bench is None:
                seg += 1
                continue
            end = seg
            while end < len(schedule) and schedule[end][slot] == bench:
                end += 1
            specs.append(TenantSpec(
                tenant, profile=bench, rate=rate, prompt_lens=prompt_lens,
                max_new=max_new, start=seg * seg_steps,
                stop=end * seg_steps))
            tenant += 1
            seg = end
    return tuple(specs)


def _tenant_pending(eng: ServingEngine, tenant: int) -> int:
    return (len(eng.queues.get(tenant, ())) +
            sum(1 for r in eng.running if r.tenant == tenant) +
            sum(1 for r in eng.parked if r.tenant == tenant))


def drive(eng: ServingEngine, trace: TraceSpec,
          drain_steps: int = 400) -> List[Request]:
    """The canonical serving loop: submit the trace's arrivals ahead of
    each engine step, RETIRE each departed tenant once its live window
    closed and its last request drained (placement caches evicted — the
    churn-staleness contract), then drain. Used by the launcher, the
    examples, and the serving benchmark so they all exercise one
    lifecycle path."""
    stops = {s.tenant: s.stop for s in trace.specs if s.stop is not None}
    retired: set = set()

    def _retire_done(step: int):
        for t, stop in stops.items():
            if t not in retired and step >= stop \
                    and _tenant_pending(eng, t) == 0:
                eng.retire_tenant(t)
                retired.add(t)

    for step_reqs in arrivals(trace, eng.cfg.vocab_size):
        for r in step_reqs:
            eng.submit(r)
        eng.step()
        _retire_done(eng.step_count)
    for _ in range(drain_steps):
        if eng.pending() == 0:
            break
        eng.step()
        _retire_done(eng.step_count)
    _retire_done(eng.step_count)
    return eng.finished


# ---------------------------------------------------------------- presets

def flood_vs_trickle(seed: int = 0, steps: int = 96) -> TraceSpec:
    """A heavy tenant floods the engine in waves while a light
    interactive tenant trickles — the paper's flooding-aggressor-vs-
    victim shape (Fig. 1) at the serving layer. Long aggressor decodes
    (16 steps) make batch-slot turnover slow, so a victim request
    landing mid-burst waits several times its own solo latency for
    admission unless the placement layer holds a slot open for it; the
    bursts give the aggressor slack between waves, so that reservation
    costs it little. The fairness question: how much does the trickle
    tenant's latency inflate vs running alone?"""
    return TraceSpec("flood_vs_trickle", steps, (
        TenantSpec(0, "heavy", rate=0.45, prompt_lens=(8,), max_new=16,
                   burst_period=24, burst_duty=0.4),
        TenantSpec(1, "interactive", rate=0.1, prompt_lens=(8,),
                   max_new=4),
    ), seed=seed)


def churn(seed: int = 0, steps: int = 120) -> TraceSpec:
    """Tenants arrive and depart mid-trace: placement must adapt as the
    active set changes. The live windows come from the SAME seeded
    birth-death generator the simulator's segmented runner churns with
    (`sim.workloads.churn_schedule` via `schedule_to_specs`) — serving
    traces and sim churn share one timeline."""
    n_segments = 6
    sched = churn_schedule(seed=seed, n_segments=n_segments, n_slots=3,
                           arrival_rate=0.5, departure_rate=0.3)
    specs = schedule_to_specs(sched, max(steps // n_segments, 1),
                              rate=0.35, prompt_lens=(8,), max_new=6)
    return TraceSpec("churn", steps, specs, seed=seed)


def many_tenants(seed: int = 0, steps: int = 120) -> TraceSpec:
    """Tens of tenants churning through a wide slot array (the scale
    stressor): each occupancy interval of a 12-slot churn schedule is a
    fresh tenant, so the trace carries dozens of distinct tenant ids —
    placement, oracle memoization, and the retirement path must all
    stay cheap and correct at this width."""
    n_segments = 6
    sched = churn_schedule(seed=seed, n_segments=n_segments, n_slots=12,
                           arrival_rate=0.6, departure_rate=0.35)
    specs = schedule_to_specs(sched, max(steps // n_segments, 1),
                              rate=0.12, prompt_lens=(8,), max_new=4)
    return TraceSpec("many_tenants", steps, specs, seed=seed)


def heavy_tail(seed: int = 0, steps: int = 96) -> TraceSpec:
    """Bursty arrivals + Pareto decode lengths: a few very long
    requests occupy slots for many epochs (the p99 stressor)."""
    return TraceSpec("heavy_tail", steps, (
        TenantSpec(0, "batch", rate=0.6, prompt_lens=(8,), max_new=6,
                   heavy_tail=True, burst_period=24, burst_duty=0.4),
        TenantSpec(1, "interactive", rate=0.12, prompt_lens=(8,),
                   max_new=6),
        TenantSpec(2, "rag", rate=0.25, prompt_lens=(8, 16), max_new=6,
                   heavy_tail=True),
    ), seed=seed)


PRESETS = {
    "flood_vs_trickle": flood_vs_trickle,
    "churn": churn,
    "heavy_tail": heavy_tail,
    "many_tenants": many_tenants,
}


def make_trace(name: str, seed: int = 0,
               steps: Optional[int] = None) -> TraceSpec:
    if name not in PRESETS:
        raise KeyError(f"unknown trace preset {name!r}: {sorted(PRESETS)}")
    tr = PRESETS[name](seed=seed)
    if steps is not None:
        scale = [dataclasses.replace(
            s,
            stop=None if s.stop is None else max(s.stop * steps
                                                 // tr.steps, 1),
            start=s.start * steps // tr.steps) for s in tr.specs]
        tr = dataclasses.replace(tr, steps=steps, specs=tuple(scale))
    return tr
