"""Admission/placement policies gating the serving engine's `_admit`.

Once per *decision epoch* (every `epoch_steps` engine steps) the active
policy looks at a host-side `EngineView` snapshot — queue depths,
running counts, KV-pool pressure (`repro.memmgr.kv_cache.pool_pressure`)
— and produces a `PlacementDecision`: which tenants may co-run this
epoch (`allowed`) and each tenant's admission cap (`caps`, max running
requests). The engine consults the current decision on every admission;
running requests always finish out (admission gating only, so decisions
are work-conserving for work already placed).

Policies, least to most informed:

  none    — admit everything (the engine's legacy behavior).
  static  — fixed equal partition of the batch over the DECLARED tenant
            universe, never adapted (the paper's Static baseline
            transplanted: isolating but wasteful when tenants idle).
  greedy  — equal share over the tenants with work right now, backing
            off when the KV pool nears exhaustion. Adaptive but
            contention-blind.
  oracle  — consults the `ContentionOracle`: enumerates candidate
            co-run sets, gets predicted weighted-speedup/unfairness
            from the simulator, picks the best candidate whose
            predicted max slowdown clears the unfairness cap, and
            reserves admission slots for predicted victims so an
            aggressor tenant cannot crowd them out of the batch.

Overload tolerance (PR 10) — decisions are no longer admit/deny only.
A decision may carry per-tenant *decode quotas* (the MASK-token
analogue at the serving layer: a cap on decode slots per step, enforced
work-conservingly) and a *preemption directive* (evict N of a tenant's
running requests; the engine releases their KV pages and re-queues them
with seeded exponential backoff). Under KV-pool pressure the oracle
policy walks a degradation ladder instead of falling off a cliff:

    normal -> quota (tighten decode quotas, pressure > quota_watermark)
           -> preempt (evict from the page-heaviest aggressor)
           -> freeze (no admissions until pressure recedes)

and a *self-correcting* loop guards the oracle itself: achieved
per-tenant slowdowns feed a bounded `Recalibrator`
(`repro.serving.oracle`), and when the rolling prediction error exceeds
`degrade_error` the policy degrades to safe mode (static caps, then
admit-all) and re-engages once the SHADOW prediction error recovers —
a mispredicting oracle is never worse than no oracle.

Every decision (with its predictions, for the oracle) is recorded on
the engine's `decisions` log — the serving benchmark reports
predicted-vs-achieved fairness AND per-rung attribution from exactly
these records.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from repro.serving.oracle import (ContentionOracle, PlacementPrediction,
                                  Recalibrator)

# degradation-ladder rung names, least to most degraded (decision.rung)
RUNGS = ("normal", "quota", "preempt", "freeze",
         "stalled", "safe_static", "safe_open")


@dataclasses.dataclass(frozen=True)
class EngineView:
    """Host-side engine snapshot a policy decides from."""

    step: int
    max_batch: int
    queued: Mapping[int, int]          # tenant -> queued request count
    running: Mapping[int, int]         # tenant -> running request count
    waiting_since: Mapping[int, int]   # tenant -> oldest queued submit step
    pool_used_frac: float              # KV pool page pressure [0, 1]
    pool_free_seqs: int
    profiles: Mapping[int, str]        # declared tenant profiles
    pool_free_pages: int = 0
    pages_by_tenant: Mapping[int, int] = dataclasses.field(
        default_factory=dict)          # KV pages held per tenant
    max_running: int = 0               # admission bound (0: == max_batch)

    @property
    def tenants(self) -> Tuple[int, ...]:
        """Tenants with any work (queued or running), sorted."""
        live = {t for t, n in self.queued.items() if n > 0}
        live |= {t for t, n in self.running.items() if n > 0}
        return tuple(sorted(live))


@dataclasses.dataclass(frozen=True)
class PlacementDecision:
    """One epoch's admission plan (+ the evidence, for the oracle)."""

    step: int
    policy: str
    allowed: Tuple[int, ...]           # tenants that may admit this epoch
    caps: Mapping[int, int]            # tenant -> max running requests
    predictions: Tuple[PlacementPrediction, ...] = ()
    chosen: Optional[PlacementPrediction] = None
    note: str = ""
    default_cap: int = 0               # cap for tenants NOT in `allowed`
    decode_quota: Mapping[int, int] = dataclasses.field(
        default_factory=dict)          # tenant -> decode slots per step
    preempt: Mapping[int, int] = dataclasses.field(
        default_factory=dict)          # tenant -> running requests to evict
    rung: str = "normal"               # degradation-ladder rung (RUNGS)

    def cap(self, tenant: int) -> int:
        """Admission cap. Tenants outside `allowed` get `default_cap`:
        0 freezes them out for the epoch (static partitions), 1 lets a
        tenant that was idle at the decision boundary trickle in
        instead of stalling a full epoch (greedy/oracle)."""
        if tenant not in self.allowed:
            return self.default_cap
        return self.caps.get(tenant, 0)


class PlacementPolicy:
    """Base: admit-all ("none"). Subclasses override `_decide`."""

    name = "none"

    def __init__(self, epoch_steps: int = 16):
        if epoch_steps < 1:
            raise ValueError(f"epoch_steps must be >= 1, got {epoch_steps}")
        self.epoch_steps = epoch_steps
        self.decision: Optional[PlacementDecision] = None
        self._last_step: Optional[int] = None
        self._last_active: Tuple[int, ...] = ()
        self._retired_pending = False
        self.stall_until = 0    # oracle-latency fault window (engine-set)

    def due(self, step: int) -> bool:
        if (self._last_step is not None and self.decision is not None
                and self.decision.rung == "freeze"):
            return True     # frozen epochs re-decide every step: the
            #                 freeze must lift the moment pressure does
        return (self._last_step is None
                or step - self._last_step >= self.epoch_steps)

    def stale(self, active: Sequence[int]) -> bool:
        """Decision invalidation on churn: a tenant that was NOT active
        when the epoch's decision was made has work now, so the
        placement no longer covers the live tenant set — re-decide
        early rather than stall the newcomer a whole epoch. (Tenants
        the decision deliberately excluded were seen at decision time
        and do NOT retrigger; oracle memoization keeps early
        re-decides cheap.)"""
        if self.name == "none" or self.decision is None:
            return False
        if self._retired_pending:
            return True     # current decision still places a dead tenant
        return bool(set(active) - set(self._last_active))

    def refresh(self, view: EngineView) -> PlacementDecision:
        self.decision = self._decide(view)
        self._last_step = view.step
        self._last_active = view.tenants
        self._retired_pending = False
        return self.decision

    def observe(self, achieved: Mapping[int, float]) -> None:
        """Achieved per-tenant slowdowns for the closing epoch (engine
        feedback seam). Base policies don't learn; the oracle policy
        recalibrates and drives its safe-mode state machine from this."""

    def retire(self, tenant: int) -> None:
        """A tenant departed for good: no decision epoch may place it
        again. If the CURRENT decision still allows it, the decision is
        marked stale so the next engine step re-decides immediately."""
        self._last_active = tuple(t for t in self._last_active
                                  if t != tenant)
        if self.decision is not None and tenant in self.decision.allowed:
            self._retired_pending = True

    def invalidate(self) -> None:
        """Mark the current decision stale (the world changed under it:
        a poisoned profile, an oracle stall) — the next engine step
        re-decides immediately instead of waiting out the epoch."""
        if self.decision is not None:
            self._retired_pending = True

    def may_admit(self, tenant: int, running_count: int) -> bool:
        """Admission gate consulted per admitted request. The base
        policy is truly admit-all — never gated on the (stale) epoch
        snapshot, so "none" is the engine's legacy behavior exactly."""
        if self.name == "none" or self.decision is None:
            return True
        return running_count < self.decision.cap(tenant)

    def _decide(self, view: EngineView) -> PlacementDecision:
        ts = view.tenants
        return PlacementDecision(
            step=view.step, policy=self.name, allowed=ts,
            caps={t: view.max_batch for t in ts},
            default_cap=view.max_batch)


class StaticPartition(PlacementPolicy):
    """Fixed 1/N admission slice per DECLARED tenant — isolating but
    non-adaptive: an idle tenant's slice is never reused."""

    name = "static"

    def __init__(self, tenants: Sequence[int], epoch_steps: int = 16):
        super().__init__(epoch_steps)
        self._universe = tuple(sorted(set(tenants)))
        if not self._universe:
            raise ValueError("static partition needs >= 1 declared tenant")

    def stale(self, active: Sequence[int]) -> bool:
        return False        # the partition is fixed; churn changes nothing

    def _decide(self, view: EngineView) -> PlacementDecision:
        share = max(view.max_batch // len(self._universe), 1)
        return PlacementDecision(
            step=view.step, policy=self.name, allowed=self._universe,
            caps={t: share for t in self._universe})


class GreedyShare(PlacementPolicy):
    """Equal share over currently-active tenants + pool backpressure.
    Adaptive (idle tenants' slots are redistributed) but blind to WHICH
    tenants contend on the memory system."""

    name = "greedy"

    def __init__(self, epoch_steps: int = 16,
                 pool_high_water: float = 0.9,
                 freeze_watermark: float = 0.97):
        super().__init__(epoch_steps)
        self.pool_high_water = pool_high_water
        self.freeze_watermark = freeze_watermark

    def _decide(self, view: EngineView) -> PlacementDecision:
        ts = view.tenants
        if not ts:
            return PlacementDecision(step=view.step, policy=self.name,
                                     allowed=(), caps={}, default_cap=1)
        if view.pool_used_frac >= self.freeze_watermark:
            return PlacementDecision(
                step=view.step, policy=self.name, allowed=(), caps={},
                default_cap=0, rung="freeze",
                note=f"pool pressure {view.pool_used_frac:.2f}: "
                     "admission frozen")
        budget = view.max_batch
        note, rung = "", "normal"
        if view.pool_used_frac > self.pool_high_water:
            budget = max(budget // 2, len(ts))
            note = f"pool pressure {view.pool_used_frac:.2f}: halved budget"
            rung = "quota"
        share = max(-(-budget // len(ts)), 1)       # ceil
        return PlacementDecision(
            step=view.step, policy=self.name, allowed=ts,
            caps={t: share for t in ts}, note=note, default_cap=1,
            rung=rung)


class OraclePlacement(PlacementPolicy):
    """Simulator-driven placement (see module docstring).

    Per epoch: enumerate co-run candidates over the (up to `slots`)
    longest-waiting active tenants, predict each through the oracle
    (KV-pressure-inflated, recalibration-corrected), keep candidates
    whose corrected max slowdown clears `unfairness_cap`, and pick the
    one serving the most tenants at the highest predicted weighted
    speedup. Admission caps then reserve batch slots for predicted
    victims; decode quotas shape per-step decode shares toward the
    predicted victims; and under KV pressure or heavy predicted
    unfairness the decision walks the degradation ladder
    (quota -> preempt -> freeze). The safe-mode state machine guards
    the whole thing: persistent prediction error degrades to static
    caps, then admit-all, and re-engages when the SHADOW error
    recovers.
    """

    name = "oracle"

    def __init__(self, oracle: ContentionOracle, epoch_steps: int = 16,
                 unfairness_cap: float = 1.15,
                 pool_high_water: float = 0.9,
                 quota_watermark: float = 0.75,
                 preempt_watermark: float = 0.9,
                 freeze_watermark: float = 0.97,
                 preempt_slowdown: float = 1.6,
                 max_preempt: int = 1,
                 degrade_error: float = 0.6,
                 reengage_error: float = 0.25,
                 error_window: int = 3,
                 recalibrator: Optional[Recalibrator] = None):
        super().__init__(epoch_steps)
        if not (0.0 < quota_watermark <= preempt_watermark
                <= freeze_watermark <= 1.0):
            raise ValueError(
                "watermarks must satisfy 0 < quota <= preempt <= freeze "
                f"<= 1, got {(quota_watermark, preempt_watermark, freeze_watermark)}")
        if reengage_error >= degrade_error:
            raise ValueError("need reengage_error < degrade_error "
                             "(hysteresis), got "
                             f"{(reengage_error, degrade_error)}")
        self.oracle = oracle
        self.unfairness_cap = unfairness_cap
        self.pool_high_water = pool_high_water
        self.quota_watermark = quota_watermark
        self.preempt_watermark = preempt_watermark
        self.freeze_watermark = freeze_watermark
        self.preempt_slowdown = preempt_slowdown
        self.max_preempt = max_preempt
        self.degrade_error = degrade_error
        self.reengage_error = reengage_error
        self.recalibrator = recalibrator if recalibrator is not None \
            else Recalibrator()
        # safe-mode state machine: 0 = oracle, 1 = static caps,
        # 2 = admit-all; driven by the rolling prediction error
        self.safe_level = 0
        self._errors: deque = deque(maxlen=max(error_window, 1))
        self._epochs_observed = 0
        self.mode_log: List[Tuple[int, int, float]] = []  # (obs#, level, err)
        # raw predicted slowdowns of the last chosen/shadow placement —
        # the recalibrator compares achieved feedback against these
        self._last_pred: Dict[int, float] = {}
        self._last_corrected_max: Optional[float] = None

    # -------------------------------------------------------- feedback
    def rolling_error(self) -> Optional[float]:
        if not self._errors:
            return None
        return sum(self._errors) / len(self._errors)

    def observe(self, achieved: Mapping[int, float]) -> None:
        """One closing epoch's achieved per-tenant slowdowns: update
        the recalibrator, the rolling prediction error, and the
        safe-mode level (full-window hysteresis both ways)."""
        self._epochs_observed += 1
        self.recalibrator.observe(achieved, self._last_pred)
        pred, vals = self._last_corrected_max, list(achieved.values())
        if pred is not None and vals:
            ach = max(vals)
            if ach > 0 and all(v > 0 and v == v for v in vals):
                self._errors.append(abs(pred - ach) / ach)
        roll = self.rolling_error()
        if roll is None or len(self._errors) < self._errors.maxlen:
            return
        level = self.safe_level
        if roll > self.degrade_error and level < 2:
            level += 1
        elif roll < self.reengage_error and level > 0:
            level -= 1
        if level != self.safe_level:
            self.safe_level = level
            self.mode_log.append((self._epochs_observed, level, roll))
            self._errors.clear()     # re-fill the window before moving again

    def retire(self, tenant: int) -> None:
        super().retire(tenant)
        self.oracle.evict_tenant(tenant)
        self.recalibrator.evict(tenant)
        self._last_pred.pop(tenant, None)

    # ---------------------------------------------------------- decide
    def _candidates(self, tenants: Tuple[int, ...]
                    ) -> List[Tuple[int, ...]]:
        """All non-empty subsets, smallest-last so ties in scoring
        resolve toward serving more tenants; deterministic order."""
        out: List[Tuple[int, ...]] = []
        n = len(tenants)
        for bits in range(1, 2 ** n):
            out.append(tuple(t for i, t in enumerate(tenants)
                             if bits >> i & 1))
        return sorted(out, key=lambda c: (len(c), c))

    def _corrected(self, p: PlacementPrediction) -> PlacementPrediction:
        """Apply the recalibrator's per-tenant corrections on top of
        the oracle's (already KV-inflated) prediction."""
        slow = {t: s * self.recalibrator.correction(t)
                for t, s in p.slowdown.items()}
        return dataclasses.replace(p, slowdown=slow,
                                   max_slowdown=max(slow.values()))

    def _equal_share(self, view: EngineView, note: str,
                     rung: str) -> PlacementDecision:
        limit = view.max_running or view.max_batch
        active = view.tenants
        share = max(-(-limit // max(len(active), 1)), 1)
        return PlacementDecision(
            step=view.step, policy=self.name, allowed=active,
            caps={t: share for t in active}, default_cap=1,
            note=note, rung=rung)

    def _decode_quota(self, view: EngineView,
                      chosen: PlacementPrediction,
                      tighten: bool) -> Dict[int, int]:
        """Per-step decode shares proportional to corrected predicted
        slowdown (predicted victims get more of the decode batch; the
        aggressor is throttled). Enforcement is work-conserving — the
        engine backfills idle decode slots with throttled requests —
        so shaping only redistributes under contention. `tighten`
        (pool pressure past the quota watermark) halves every share,
        slowing the pool's page-append rate."""
        if len(chosen.tenants) < 2:
            return {}
        tot = sum(chosen.slowdown.values())
        quota: Dict[int, int] = {}
        for t in chosen.tenants:
            q = max(int(round(view.max_batch * chosen.slowdown[t] / tot)), 1)
            quota[t] = max(q // 2, 1) if tighten else q
        return quota

    def _preempt_plan(self, view: EngineView,
                      chosen: Optional[PlacementPrediction],
                      pressure_rung: bool) -> Dict[int, int]:
        """Who to evict. Pool-pressure preemption targets the tenant
        holding the most KV pages; fairness preemption targets the
        predicted aggressor when the predicted victim has queued work
        and the running set is full (admission caps can't evict — this
        is the mechanism that pays off on saturating floods)."""
        if pressure_rung and view.pages_by_tenant:
            heavy = max(sorted(view.pages_by_tenant),
                        key=lambda t: view.pages_by_tenant[t])
            if view.running.get(heavy, 0) > 0:
                return {heavy: self.max_preempt}
        if chosen is not None and len(chosen.tenants) >= 2 \
                and chosen.max_slowdown > self.preempt_slowdown:
            victim, aggr = chosen.victim(), chosen.aggressor()
            limit = view.max_running or view.max_batch
            full = sum(view.running.values()) >= limit
            if (victim != aggr and view.queued.get(victim, 0) > 0
                    and full and view.running.get(aggr, 0) >= 2):
                return {aggr: self.max_preempt}
        return {}

    def _decide(self, view: EngineView) -> PlacementDecision:
        active = view.tenants
        if not active:
            return PlacementDecision(step=view.step, policy=self.name,
                                     allowed=(), caps={}, default_cap=1)
        if view.step < self.stall_until:
            # oracle-latency fault: predictions missed their budget this
            # epoch — fail soft to contention-blind equal share
            return self._equal_share(
                view, "oracle stalled: equal share", "stalled")
        # consider the longest-waiting tenants first when over-wide
        consider = sorted(
            active,
            key=lambda t: (view.waiting_since.get(t, view.step), t)
        )[: self.oracle.slots]
        consider = tuple(sorted(consider))
        cands = self._candidates(consider)
        preds = [self._corrected(p) for p in self.oracle.predict(
            cands, view.profiles, pool_pressure=view.pool_used_frac)
            if p is not None]
        if not preds:
            # every candidate's simulation failed: fail soft to greedy
            return self._equal_share(
                view, "oracle predictions unavailable; equal share",
                "normal")
        note = ""
        feasible = [p for p in preds
                    if p.max_slowdown <= self.unfairness_cap]
        if feasible:
            # serve the most tenants at the best predicted speedup;
            # deterministic tie-break on the tenant tuple
            chosen = max(feasible, key=lambda p: (
                len(p.tenants), p.weighted_speedup, p.tenants))
        else:
            chosen = min(preds, key=lambda p: (
                p.max_slowdown, -len(p.tenants), p.tenants))
            note = (f"no candidate under unfairness cap "
                    f"{self.unfairness_cap}: min-slowdown fallback")
        # feedback anchors: achieved slowdowns are compared against the
        # RAW (pre-correction) predictions for the placement we applied
        # (or would have applied — the safe-mode shadow)
        corr = self.recalibrator
        self._last_pred = {
            t: chosen.slowdown[t] / max(corr.correction(t), 1e-9)
            for t in chosen.tenants}
        self._last_corrected_max = chosen.max_slowdown

        # ---- safe mode: the oracle's own output is not trusted -------
        if self.safe_level >= 2:
            limit = view.max_running or view.max_batch
            return PlacementDecision(
                step=view.step, policy=self.name, allowed=active,
                caps={t: limit for t in active}, default_cap=limit,
                note="safe mode: admit-all (oracle disengaged)",
                rung="safe_open")
        if self.safe_level == 1:
            d = self._equal_share(
                view, "safe mode: static equal caps", "safe_static")
            return d

        # ---- engaged: build the placement, then walk the ladder ------
        pressure = view.pool_used_frac
        if pressure >= self.freeze_watermark:
            return PlacementDecision(
                step=view.step, policy=self.name, allowed=(), caps={},
                default_cap=0, predictions=tuple(preds), chosen=chosen,
                preempt=self._preempt_plan(view, chosen, True),
                note=f"pool pressure {pressure:.2f}: admission frozen",
                rung="freeze")
        allowed = chosen.tenants
        limit = view.max_running or view.max_batch
        # Latent-tenant headroom: declared tenants (profiles) that are
        # idle right now WILL come back; holding a slot for them means
        # their first request admits instantly instead of waiting out a
        # full batch of long decodes (admission caps can't evict).
        latent = min(len([t for t in view.profiles if t not in allowed]), 2)
        caps: Dict[int, int] = {}
        if len(allowed) == 1:
            caps[allowed[0]] = max(limit - latent, 1)
        else:
            # one reserved admission slot per co-tenant: enough for the
            # predicted victim's first request to admit instantly, and
            # cheap enough (1/limit capacity) that a backlogged
            # aggressor is not pushed into queue divergence
            for t in allowed:
                others = len(allowed) - 1
                caps[t] = max(limit - others - latent, 1)
        rung = "normal"
        tighten = pressure >= self.quota_watermark
        if tighten:
            rung = "quota"
            note = (note + "; " if note else "") + (
                f"pool pressure {pressure:.2f}: decode quotas tightened")
        if pressure > self.pool_high_water:
            caps = {t: max(c // 2, 1) for t, c in caps.items()}
            note = (note + "; " if note else "") + (
                f"pool pressure {pressure:.2f}: halved caps")
        quota = self._decode_quota(view, chosen, tighten)
        preempt = self._preempt_plan(
            view, chosen, pressure >= self.preempt_watermark)
        if preempt:
            rung = "preempt"
            note = (note + "; " if note else "") + (
                "preempting " + ", ".join(
                    f"{k}x tenant {t}" for t, k in sorted(preempt.items())))
        return PlacementDecision(
            step=view.step, policy=self.name, allowed=allowed, caps=caps,
            predictions=tuple(preds), chosen=chosen, note=note,
            default_cap=1, decode_quota=quota, preempt=preempt,
            rung=rung)


POLICIES = ("none", "static", "greedy", "oracle")


def make_policy(name: str,
                profiles: Optional[Mapping[int, str]] = None,
                oracle: Optional[ContentionOracle] = None,
                epoch_steps: int = 16,
                **kw) -> PlacementPolicy:
    """Factory used by the benchmark/CLI: policy name -> instance.

    `profiles` (tenant -> declared app profile) is required for
    "static" (it declares the tenant universe); "oracle" builds a
    default `ContentionOracle` when none is passed (kw: design, cycles,
    slots, unfairness_cap, ...).
    """
    if name == "none":
        return PlacementPolicy(epoch_steps=epoch_steps)
    if name == "static":
        if not profiles:
            raise ValueError("static placement needs declared profiles "
                             "(the tenant universe)")
        return StaticPartition(tuple(profiles), epoch_steps=epoch_steps)
    if name == "greedy":
        return GreedyShare(epoch_steps=epoch_steps, **kw)
    if name == "oracle":
        pol_kw = {k: kw.pop(k) for k in (
            "unfairness_cap", "pool_high_water", "quota_watermark",
            "preempt_watermark", "freeze_watermark", "preempt_slowdown",
            "max_preempt", "degrade_error", "reengage_error",
            "error_window", "recalibrator") if k in kw}
        if oracle is None:
            oracle = ContentionOracle(**kw)
        return OraclePlacement(oracle, epoch_steps=epoch_steps, **pol_kw)
    raise KeyError(f"unknown placement policy {name!r}: {POLICIES}")
