"""Deterministic synthetic token pipeline with sharded host loading.

Production posture: each data-parallel host materializes only its shard of
the global batch (`host_batch_slice`), steps are addressable by index
(deterministic skip-ahead on restart — no state files needed beyond the
step counter), and an async double-buffered prefetcher hides host latency.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 1234
    # markov-ish synthetic text: token t+1 = f(t) with noise, so models can
    # actually learn (loss decreases) in the examples
    noise: float = 0.3


def _mix64(x: np.ndarray) -> np.ndarray:
    x = np.asarray(x, np.uint64)
    x = (x ^ (x >> np.uint64(33))) * np.uint64(0xFF51AFD7ED558CCD)
    x = (x ^ (x >> np.uint64(33))) * np.uint64(0xC4CEB9FE1A85EC53)
    return x ^ (x >> np.uint64(33))


def _batch_for_step(cfg: ModelConfig, shape: ShapeConfig, dcfg: DataConfig,
                    step: int, lo: int, hi: int) -> Dict[str, np.ndarray]:
    """Rows [lo, hi) of the global batch for `step` — per-row hash-addressed
    so any host slice of the same step is bit-identical to the full batch."""
    n = hi - lo
    S = shape.seq_len
    s_text = S - (cfg.n_patches or 0)
    rows = np.arange(lo, hi, dtype=np.uint64)[:, None]
    key = np.uint64((dcfg.seed * 1_000_003 + step) % (2**31))
    h1 = _mix64(rows * np.uint64(0x9E3779B97F4A7C15) + key)
    base = (h1 % np.uint64(cfg.vocab_size)).astype(np.int64)
    steps = (_mix64(h1) % np.uint64(6) + np.uint64(1)).astype(np.int64)
    pos = np.arange(S, dtype=np.int64)[None, :]
    seq = (base + steps * pos) % cfg.vocab_size
    h2 = _mix64(h1 + np.uint64(7) * pos.astype(np.uint64))
    noise_mask = (h2 % np.uint64(1024)) < np.uint64(int(dcfg.noise * 1024))
    noise_tok = (_mix64(h2) % np.uint64(cfg.vocab_size)).astype(np.int64)
    seq = np.where(noise_mask, noise_tok, seq).astype(np.int32)

    batch = {"tokens": seq[:, :s_text], "labels": seq}
    if cfg.n_patches:
        h3 = _mix64(h1 + np.uint64(13))
        rng = np.random.RandomState((int(h3[0, 0]) ^ step) % (2**31))
        batch["patch_embeds"] = rng.randn(
            n, cfg.n_patches, cfg.d_model).astype(np.float32) * 0.02
    if cfg.is_enc_dec:
        rng = np.random.RandomState((step * 7919 + lo) % (2**31))
        batch["frames"] = rng.randn(
            n, cfg.enc_len, cfg.d_model).astype(np.float32) * 0.02
    return batch


class DataPipeline:
    def __init__(self, cfg: ModelConfig, shape: ShapeConfig,
                 dcfg: DataConfig = DataConfig(),
                 host_index: int = 0, host_count: int = 1,
                 prefetch: int = 2):
        self.cfg, self.shape, self.dcfg = cfg, shape, dcfg
        per_host = shape.global_batch // host_count
        self.lo = host_index * per_host
        self.hi = self.lo + per_host
        self.prefetch = prefetch

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        return _batch_for_step(self.cfg, self.shape, self.dcfg, step,
                               self.lo, self.hi)

    def iterate(self, start_step: int = 0,
                stop_step: Optional[int] = None) -> Iterator[Dict]:
        """Async double-buffered iterator with deterministic skip-ahead."""
        q: "queue.Queue" = queue.Queue(maxsize=self.prefetch)
        stop = threading.Event()

        def producer():
            s = start_step
            while not stop.is_set() and (stop_step is None or s < stop_step):
                q.put((s, self.batch_at(s)))
                s += 1
            q.put(None)

        th = threading.Thread(target=producer, daemon=True)
        th.start()
        try:
            while True:
                item = q.get()
                if item is None:
                    return
                yield item
        finally:
            stop.set()
