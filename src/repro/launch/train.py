"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --smoke \
      --steps 200 --ckpt-dir /tmp/ckpt

--smoke trains the reduced same-family config on local devices; the full
configs are exercised via the dry-run (no allocation on CPU hosts).
"""
from __future__ import annotations

import argparse
import dataclasses

from repro.configs import get_model, get_run_config, reduced_model
from repro.configs.base import RunConfig, ShapeConfig
from repro.train import optimizer as opt_mod
from repro.train.loop import TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config on local devices")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    model = get_model(args.arch)
    if args.smoke:
        model = reduced_model(model)
    shape = ShapeConfig("local", seq_len=args.seq_len,
                        global_batch=args.batch, kind="train")
    run = RunConfig(model=model, shape=shape, remat=True, microbatches=1,
                    attn_block_q=min(64, args.seq_len),
                    attn_block_k=min(64, args.seq_len))
    tcfg = TrainConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                       ckpt_every=args.ckpt_every,
                       opt=opt_mod.OptConfig(lr=args.lr, warmup_steps=20))
    out = train(model, run, tcfg)
    hist = out["history"]
    if hist:
        print(f"first loss {hist[0]['loss']:.4f} -> last {hist[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
