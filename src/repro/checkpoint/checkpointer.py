"""Sharded checkpointing with async writes and atomic commit.

Layout: <dir>/step_<N>/
  shard_<i>.npz   — flattened param/opt leaves owned by process i
  index.json      — treedef paths, shapes, dtypes, step, mesh topology
  COMMITTED       — atomic marker written last

Restart semantics (fault tolerance): `latest_step` finds the newest
COMMITTED checkpoint; partial writes from a crashed run are ignored and
garbage-collected. `restore` accepts a *different* mesh topology than the
one that saved (elastic re-scale): leaves are saved unsharded per-host in
this reference implementation, so any mesh can reload them.
"""
from __future__ import annotations

import json
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._pending: Optional[threading.Thread] = None

    # ------------------------------------------------------------- save
    def save(self, step: int, params, opt_state, extra: Dict = None,
             blocking: bool = True):
        """Snapshot (host-gathered); async unless blocking. bf16 leaves are
        widened to f32 on disk (npz has no bf16) — lossless round trip."""

        def _np(v):
            a = np.asarray(v)
            return a.astype(np.float32) if a.dtype.str == "<V2" or \
                str(a.dtype) == "bfloat16" else a

        flat_p = {f"p/{k}": _np(v) for k, v in _flatten(params).items()}
        flat_o = {f"o/{k}": _np(v) for k, v in _flatten(opt_state).items()}

        def _write():
            target = self.dir / f"step_{step:09d}"
            tmp = self.dir / f".tmp_step_{step:09d}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            np.savez(tmp / "shard_0.npz", **flat_p, **flat_o)
            (tmp / "index.json").write_text(json.dumps({
                "step": step,
                "n_leaves": len(flat_p) + len(flat_o),
                "extra": extra or {},
            }))
            (tmp / "COMMITTED").write_text("ok")
            if target.exists():
                shutil.rmtree(target)
            tmp.rename(target)
            self._gc()

        self.wait()
        if blocking:
            _write()
        else:
            self._pending = threading.Thread(target=_write, daemon=True)
            self._pending.start()

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self):
        steps = sorted(self._committed_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:09d}", ignore_errors=True)
        # remove uncommitted partials
        for p in self.dir.glob(".tmp_step_*"):
            shutil.rmtree(p, ignore_errors=True)

    # ---------------------------------------------------------- restore
    def _committed_steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            if (p / "COMMITTED").exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self._committed_steps()
        return max(steps) if steps else None

    def restore(self, step: int, params_like, opt_like
                ) -> Tuple[Any, Any, Dict]:
        """Reload into the structure of `params_like`/`opt_like` (possibly
        sharded differently than at save time — device_put reshards)."""
        d = self.dir / f"step_{step:09d}"
        data = np.load(d / "shard_0.npz")
        index = json.loads((d / "index.json").read_text())

        def _rebuild(tree, prefix):
            flat = _flatten(tree)
            leaves = {}
            for k, like in flat.items():
                arr = data[f"{prefix}/{k}"]
                want = getattr(like, "dtype", None)
                if want is not None and str(arr.dtype) != str(want):
                    arr = arr.astype(want)   # bf16 widened on disk
                sharding = getattr(like, "sharding", None)
                leaves[k] = (jax.device_put(arr, sharding)
                             if sharding is not None else arr)
            # reassemble in tree order
            paths, treedef = jax.tree_util.tree_flatten_with_path(tree)
            vals = []
            for path, _ in paths:
                key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                               for p in path)
                vals.append(leaves[key])
            return jax.tree_util.tree_unflatten(treedef, vals)

        return (_rebuild(params_like, "p"), _rebuild(opt_like, "o"),
                index.get("extra", {}))
