"""Attention: GQA + RoPE + qk-norm + sliding-window, in three implementations.

* ``naive``        — full O(S^2) softmax; oracle for tests (small shapes only).
* ``xla_blocked``  — memory-bounded blocked attention (lax.scan over q/k blocks
                     with online softmax). This is the XLA production path and
                     the shape-safe path used by the dry-run.
* ``pallas_flash`` — Pallas TPU kernel (repro.kernels.flash_attention), used on
                     real TPUs for the hot prefill/train path.

Decode uses a dense-cache path (dry-run/roofline) and a paged path (serving +
Pallas paged_attention kernel) — see repro/models/lm.py and repro/memmgr.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, head_rmsnorm_params
from repro.models.params import Param

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def attn_params(d_model: int, n_heads: int, n_kv: int, dh: int, qk_norm: bool = False):
    p = {
        "wq": Param((d_model, n_heads * dh), ("embed", "heads")),
        "wk": Param((d_model, n_kv * dh), ("embed", "heads")),
        "wv": Param((d_model, n_kv * dh), ("embed", "heads")),
        "wo": Param((n_heads * dh, d_model), ("heads", "embed")),
    }
    if qk_norm:
        p["q_norm"] = head_rmsnorm_params(dh)
        p["k_norm"] = head_rmsnorm_params(dh)
    return p


def _head_norm(scale, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale["scale"]).astype(x.dtype)


def project_qkv(params, x, *, n_heads, n_kv, dh, positions, rope_theta,
                qk_norm=False, use_rope=True):
    """x: (B, S, d) -> q (B,S,H,dh), k,v (B,S,KV,dh)."""
    B, S, _ = x.shape
    q = jnp.einsum("bsd,dh->bsh", x, params["wq"]).reshape(B, S, n_heads, dh)
    k = jnp.einsum("bsd,dh->bsh", x, params["wk"]).reshape(B, S, n_kv, dh)
    v = jnp.einsum("bsd,dh->bsh", x, params["wv"]).reshape(B, S, n_kv, dh)
    if qk_norm:
        q = _head_norm(params["q_norm"], q)
        k = _head_norm(params["k_norm"], k)
    if use_rope:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    return q, k, v


# ---------------------------------------------------------------------------
# Naive oracle
# ---------------------------------------------------------------------------

def naive_attention(q, k, v, *, causal=True, window: Optional[int] = None,
                    q_offset: int = 0):
    """q: (B,Sq,H,dh); k,v: (B,Sk,KV,dh). GQA by head repetition. fp32 softmax."""
    B, Sq, H, dh = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, dh)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32)
    scores *= 1.0 / jnp.sqrt(dh).astype(jnp.float32)
    qpos = jnp.arange(Sq) + q_offset
    kpos = jnp.arange(k.shape[1])
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > (qpos[:, None] - window)
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v.dtype), v)
    return out.reshape(B, Sq, H, dh)


# ---------------------------------------------------------------------------
# Blocked (flash-style) attention in pure XLA
# ---------------------------------------------------------------------------

def _block_attend(q, k, v, mask, m_prev, l_prev, acc_prev, sm_scale):
    """One (q_block, k_block) tile of online softmax — flat-head layout.

    q: (B,Bq,H,dh)  k,v: (B,Bk,H,dh)  mask: (Bq,Bk) bool
    state: m,l (B,H,Bq), acc (B,Bq,H,dh) fp32.
    """
    s = jnp.einsum("bqhd,bshd->bhqs", q, k).astype(jnp.float32) * sm_scale
    s = jnp.where(mask[None, None], s, NEG_INF)
    m_cur = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new[..., None])
    correction = jnp.exp(m_prev - m_new)
    l_new = l_prev * correction + jnp.sum(p, axis=-1)
    pv = jnp.einsum("bhqs,bshd->bqhd", p.astype(v.dtype), v).astype(jnp.float32)
    acc_new = acc_prev * jnp.moveaxis(correction, -1, 1)[..., None] + pv
    return m_new, l_new, acc_new


def blocked_attention(q, k, v, *, causal=True, window: Optional[int] = None,
                      block_q=512, block_k=1024):
    """Memory-bounded attention. GQA k/v are broadcast to H heads up front —
    a flat-head layout keeps the head dim shardable by GSPMD (splitting it
    into (KV, G) inside the math kills the mesh-axis mapping and silently
    replicates scores). Causal path masks all visited tiles (baseline; the
    'wedge' optimization in §Perf removes the dead upper triangle). SWA
    restricts visited k-tiles to the window (static trip count)."""
    B, Sq, H, dh = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    if KV != H:
        G = H // KV
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, block_q, Sk, block_k)
    nq, nk = Sq // block_q, Sk // block_k
    sm_scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)
    qg = q.reshape(B, nq, block_q, H, dh)

    if window is not None:
        nk_vis = min(nk, window // block_k + 2)  # tiles that can intersect window
    else:
        nk_vis = nk

    def q_step(_, qi):
        qb = qg[:, qi]
        qpos = qi * block_q + jnp.arange(block_q)
        m0 = jnp.full((B, H, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, block_q), jnp.float32)
        a0 = jnp.zeros((B, block_q, H, dh), jnp.float32)

        if window is not None:
            # visit only tiles [k_start, k_start+nk_vis) — static trip count
            k_start = jnp.maximum(qi - (nk_vis - 1), 0)
        else:
            k_start = 0

        def k_step(carry, kj_rel):
            m, l, acc = carry
            kj = k_start + kj_rel
            kb = jax.lax.dynamic_slice_in_dim(k, kj * block_k, block_k, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(v, kj * block_k, block_k, axis=1)
            kpos = kj * block_k + jnp.arange(block_k)
            mask = jnp.ones((block_q, block_k), bool)
            if causal:
                mask &= kpos[None, :] <= qpos[:, None]
            if window is not None:
                mask &= kpos[None, :] > (qpos[:, None] - window)
            m, l, acc = _block_attend(qb, kb, vb, mask, m, l, acc, sm_scale)
            return (m, l, acc), None

        (m, l, acc), _ = jax.lax.scan(
            k_step, (m0, l0, a0), jnp.arange(nk_vis))
        out = acc / jnp.moveaxis(jnp.maximum(l, 1e-30), -1, 1)[..., None]
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, jnp.arange(nq))
    # outs: (nq, B, block_q, H, dh) -> (B, Sq, H, dh)
    return jnp.moveaxis(outs, 0, 1).reshape(B, Sq, H, dh)


# ---------------------------------------------------------------------------
# Decode (single new token against a cache)
# ---------------------------------------------------------------------------

def decode_attention_dense(q, k_cache, v_cache, cache_len, *,
                           window: Optional[int] = None):
    """q: (B,1,H,dh); caches: (B,S,KV,dh); cache_len: (B,) valid lengths.

    Reads the whole cache (memory-roofline-faithful); masked beyond length
    and outside the sliding window.
    """
    B, S, KV, dh = k_cache.shape
    H = q.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, dh)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache).astype(jnp.float32)
    s *= 1.0 / jnp.sqrt(dh).astype(jnp.float32)
    kpos = jnp.arange(S)[None, :]
    valid = kpos < cache_len[:, None]
    if window is not None:
        valid &= kpos > (cache_len[:, None] - 1 - window)
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache)
    return out.reshape(B, 1, H, dh)


def attention(q, k, v, *, impl="xla_blocked", causal=True, window=None,
              block_q=512, block_k=1024):
    if impl == "naive":
        return naive_attention(q, k, v, causal=causal, window=window)
    if impl == "xla_blocked":
        return blocked_attention(q, k, v, causal=causal, window=window,
                                 block_q=block_q, block_k=block_k)
    if impl == "pallas_flash":
        from repro.kernels.flash_attention import ops as fa_ops
        return fa_ops.flash_attention(q, k, v, causal=causal, window=window)
    raise ValueError(f"unknown attention impl {impl!r}")
