"""Contention-oracle serving loop: oracle predictions, placement
decisions, and the compile discipline (one `run_grid` program per
signature group for the oracle's lifetime, pinned via
`runner.TRACE_COUNT`)."""
import pytest

from repro.serving import stream as strm
from repro.serving.oracle import ContentionOracle, PlacementPrediction
from repro.serving.placement import (EngineView, OraclePlacement,
                                     PlacementPolicy, make_policy)
from repro.sim import runner as sim_runner
from repro.sim.profiles import PROFILES, bench_for_profile

# small-but-real sim settings: big enough to discriminate, small
# enough for tier-1
CYC = 200
PROF = {0: "heavy", 1: "interactive"}


@pytest.fixture(scope="module")
def oracle():
    # pad_rows must exceed any epoch's row count (mixes + solo rows) so
    # every grid call pads to the SAME shape -> one compile, lifetime
    return ContentionOracle(cycles=CYC, slots=2, pad_rows=8)


def test_profiles_map_to_benches():
    for prof, bench in PROFILES.items():
        assert bench_for_profile(prof) == bench
    # bench names pass through; unknown profiles raise
    assert bench_for_profile("GUP") == "GUP"
    with pytest.raises(KeyError):
        bench_for_profile("no-such-profile")


def test_one_grid_compile_per_epoch_lifetime(oracle):
    """The acceptance pin: epoch 1 compiles the grid program(s) for its
    signature group; every later epoch — fresh candidates or not —
    reuses them (mix padding to `slots` + row padding to `pad_rows`
    keep the traced shapes identical)."""
    t0 = sim_runner.TRACE_COUNT
    preds = oracle.predict([(0,), (1,), (0, 1)], PROF)
    first_epoch_traces = sim_runner.TRACE_COUNT - t0
    assert first_epoch_traces >= 1          # it really compiled
    assert oracle.grid_calls == 1           # ...in ONE run_grid call
    assert all(p is not None for p in preds)

    # epoch 2: all-memoized -> no grid call, no traces
    t1 = sim_runner.TRACE_COUNT
    oracle.predict([(0, 1), (0,)], PROF)
    assert oracle.grid_calls == 1
    assert sim_runner.TRACE_COUNT == t1

    # epoch 3: a FRESH mix (new tenant profile) -> one more grid call
    # but ZERO new traces: same compiled program, new rows
    oracle.predict([(0, 2), (2,)], {**PROF, 2: "batch"})
    assert oracle.grid_calls == 2
    assert sim_runner.TRACE_COUNT == t1


def test_oracle_predictions_deterministic(oracle):
    """Same seed/design/cycles -> bit-identical predictions, across
    oracle instances (the sim is seeded; memo keys are canonical)."""
    other = ContentionOracle(cycles=CYC, slots=2, pad_rows=8)
    a = oracle.predict([(0, 1)], PROF)[0]
    b = other.predict([(1, 0)], PROF)[0]    # order-insensitive key
    assert a.tenants == b.tenants == (0, 1)
    assert a.weighted_speedup == b.weighted_speedup
    assert a.max_slowdown == b.max_slowdown
    assert a.slowdown == b.slowdown


def test_prediction_shape(oracle):
    p = oracle.predict([(0, 1)], PROF)[0]
    assert set(p.slowdown) == {0, 1}
    assert p.max_slowdown == pytest.approx(max(p.slowdown.values()))
    assert p.weighted_speedup <= len(p.tenants) + 1e-6
    assert min(p.slowdown.values()) > 0
    assert p.victim() in p.tenants


def test_candidate_wider_than_slots_raises(oracle):
    with pytest.raises(ValueError):
        oracle.predict([(0, 1, 2)], {**PROF, 2: "batch"})


# --------------------------------------------------------------- policy
class FakeOracle:
    """Scripted oracle for placement-decision unit tests."""

    def __init__(self, table, slots=4):
        self.table = table              # frozenset(tenants) -> max_slowdown
        self.slots = slots

    def predict(self, candidates, profiles, pool_pressure=0.0):
        out = []
        for c in candidates:
            c = tuple(sorted(c))
            ms = self.table.get(frozenset(c))
            if ms is None:
                out.append(None)
                continue
            out.append(PlacementPrediction(
                tenants=c, benches=tuple("B" for _ in c),
                weighted_speedup=float(len(c)) / ms,
                max_slowdown=ms,
                slowdown={t: (ms if i == len(c) - 1 else 1.0)
                          for i, t in enumerate(c)}))
        return out


def _view(step=8, queued=None, running=None, profiles=None, max_batch=8):
    queued = queued or {}
    running = running or {}
    return EngineView(
        step=step, max_batch=max_batch, queued=queued, running=running,
        waiting_since={t: 0 for t in queued},
        pool_used_frac=0.1, pool_free_seqs=8,
        profiles=profiles or {0: "heavy", 1: "interactive"})


def test_oracle_policy_feasible_pair():
    pol = OraclePlacement(FakeOracle({frozenset({0}): 1.0,
                                      frozenset({1}): 1.0,
                                      frozenset({0, 1}): 1.05}),
                          unfairness_cap=1.15)
    d = pol.refresh(_view(queued={0: 5, 1: 1}))
    assert d.allowed == (0, 1)
    assert d.chosen.tenants == (0, 1)
    # one reserved slot per co-tenant: caps stay below the full batch
    assert d.caps[0] == d.caps[1] == 7
    assert pol.may_admit(0, 6) and not pol.may_admit(0, 7)


def test_oracle_policy_unfairness_cap_splits():
    """A pair predicted over the cap is rejected: a feasible singleton
    co-run set is chosen instead."""
    pol = OraclePlacement(FakeOracle({frozenset({0}): 1.0,
                                      frozenset({1}): 1.0,
                                      frozenset({0, 1}): 1.8}),
                          unfairness_cap=1.15)
    d = pol.refresh(_view(queued={0: 5, 1: 1}))
    assert len(d.allowed) == 1
    assert d.chosen.max_slowdown <= 1.15


def test_oracle_policy_min_slowdown_fallback():
    """NO candidate clears the cap -> pick the least-bad one and say so
    in the decision note (the benchmark surfaces these epochs)."""
    pol = OraclePlacement(FakeOracle({frozenset({0}): 1.3,
                                      frozenset({1}): 1.6,
                                      frozenset({0, 1}): 1.8}),
                          unfairness_cap=1.15)
    d = pol.refresh(_view(queued={0: 5, 1: 1}))
    assert d.allowed == (0,)                # min max_slowdown candidate
    assert "cap" in d.note


def test_oracle_policy_latent_headroom():
    """A declared tenant idle at the decision boundary keeps one
    admission slot reserved, so its first request admits instantly."""
    pol = OraclePlacement(FakeOracle({frozenset({0}): 1.0}),
                          unfairness_cap=1.15)
    d = pol.refresh(_view(queued={0: 5}))
    assert d.allowed == (0,)
    assert d.caps[0] == 7                   # max_batch - 1 latent slot
    assert d.cap(1) == 1                    # newcomer may trickle in


def test_oracle_policy_fail_soft_equal_share():
    pol = OraclePlacement(FakeOracle({}), unfairness_cap=1.15)
    d = pol.refresh(_view(queued={0: 3, 1: 2}))
    assert d.allowed == (0, 1)
    assert d.caps[0] == d.caps[1] == 4
    assert "unavailable" in d.note


def test_stale_on_new_tenant_only():
    pol = OraclePlacement(FakeOracle({frozenset({0}): 1.0,
                                      frozenset({1}): 1.0,
                                      frozenset({0, 1}): 1.8}),
                          unfairness_cap=1.15)
    pol.refresh(_view(queued={0: 5, 1: 1}))
    # both tenants were CONSIDERED (one excluded by the cap): not stale
    assert len(pol.decision.allowed) == 1
    assert not pol.stale((0, 1))
    # tenant 2 was never seen: stale -> early re-decide
    assert pol.stale((0, 1, 2))


def test_none_policy_is_admit_all():
    pol = make_policy("none")
    pol.refresh(_view(queued={0: 5}))
    assert pol.may_admit(7, 10 ** 6)        # any tenant, any count
    assert not pol.stale((0, 1, 2, 3))


# ------------------------------------------------- end-to-end fairness
def test_oracle_beats_none_on_flood_vs_trickle():
    """The tentpole law: on the seeded flood-vs-trickle trace the
    oracle policy strictly improves max-slowdown (unfairness) over
    admit-all `none` — the committed BENCH_serving.json records the
    same comparison."""
    from repro.memmgr import kv_cache as kvc
    from repro.serving import metrics as smet
    from repro.serving.engine import (EngineConfig, ServingEngine,
                                      stub_forwards, stub_model_config)

    pool = kvc.PoolConfig(n_pages=256, page_size=8, n_kv=1, head_dim=4,
                          n_layers=1, max_seqs=16, pages_per_seq=8)
    trace = strm.make_trace("flood_vs_trickle", seed=0, steps=96)

    def run(tr, policy):
        cfg = stub_model_config()
        eng = ServingEngine(cfg, None, None, pool, EngineConfig(),
                            placement=policy, profiles=tr.profiles(),
                            forwards=stub_forwards())
        for step_reqs in strm.arrivals(tr, cfg.vocab_size):
            for r in step_reqs:
                eng.submit(r)
            eng.step()
        eng.run_until_drained(max_steps=800)
        return eng

    solo_lat = {}
    for spec in trace.specs:
        e = run(trace.only(spec.tenant), PlacementPolicy())
        solo_lat.update(smet.tenant_mean_latency(e.finished))

    unfair = {}
    decisions = {}
    for pol in ("none", "oracle"):
        oracle = (ContentionOracle(cycles=300, slots=2, pad_rows=8)
                  if pol == "oracle" else None)
        e = run(trace, make_policy(pol, profiles=trace.profiles(),
                                   oracle=oracle, epoch_steps=8))
        rep = smet.fairness_report(e.finished, solo_lat, e.decisions)
        assert not rep["starved_tenants"]
        unfair[pol] = rep["unfairness"]
        decisions[pol] = e.decisions

    assert unfair["oracle"] < unfair["none"]
    # the oracle's decisions carry its evidence
    chosen = [d.chosen for d in decisions["oracle"] if d.chosen]
    assert chosen and all(c.max_slowdown > 0 for c in chosen)


def test_oracle_engine_decisions_deterministic():
    """Same trace seed -> identical decision log (steps, allowed sets,
    caps) across two engines with fresh oracles."""
    from repro.memmgr import kv_cache as kvc
    from repro.serving.engine import (EngineConfig, ServingEngine,
                                      stub_forwards, stub_model_config)

    pool = kvc.PoolConfig(n_pages=256, page_size=8, n_kv=1, head_dim=4,
                          n_layers=1, max_seqs=16, pages_per_seq=8)
    trace = strm.make_trace("flood_vs_trickle", seed=1, steps=48)

    def decide():
        cfg = stub_model_config()
        oracle = ContentionOracle(cycles=CYC, slots=2, pad_rows=4)
        eng = ServingEngine(cfg, None, None, pool, EngineConfig(),
                            placement=make_policy(
                                "oracle", profiles=trace.profiles(),
                                oracle=oracle, epoch_steps=8),
                            profiles=trace.profiles(),
                            forwards=stub_forwards())
        for step_reqs in strm.arrivals(trace, cfg.vocab_size):
            for r in step_reqs:
                eng.submit(r)
            eng.step()
        eng.run_until_drained(max_steps=400)
        return [(d.step, d.allowed, tuple(sorted(d.caps.items())))
                for d in eng.decisions]

    assert decide() == decide()


# ------------------------------------------------------------- streams
def test_trace_only_replays_identical_arrivals():
    trace = strm.make_trace("heavy_tail", seed=5, steps=48)
    full = strm.arrivals(trace, 64)
    solo = strm.arrivals(trace.only(1), 64)
    a = [(r.submit_step, len(r.prompt), r.max_new, tuple(r.prompt))
         for batch in full for r in batch if r.tenant == 1]
    b = [(r.submit_step, len(r.prompt), r.max_new, tuple(r.prompt))
         for batch in solo for r in batch]
    assert a == b and a           # same requests, nonempty


def test_trace_presets_deterministic_and_windowed():
    t1 = strm.arrivals(strm.make_trace("churn", seed=2, steps=60), 64)
    t2 = strm.arrivals(strm.make_trace("churn", seed=2, steps=60), 64)
    assert ([(r.tenant, tuple(r.prompt)) for b in t1 for r in b]
            == [(r.tenant, tuple(r.prompt)) for b in t2 for r in b])
    spec = strm.make_trace("churn", seed=2, steps=60)
    stops = {s.tenant: (s.start, s.stop) for s in spec.specs}
    for b_ix, batch in enumerate(t1):
        for r in batch:
            start, stop = stops[r.tenant]
            assert b_ix >= start and (stop is None or b_ix < stop)


def test_bursty_rate_modulation():
    spec = strm.TenantSpec(0, rate=0.5, burst_period=10, burst_duty=0.5)
    rates = [strm._rate_at(spec, s) for s in range(10)]
    assert rates[:5] == [1.0] * 5 and rates[5:] == [0.0] * 5
    # mean preserved
    assert sum(rates) / len(rates) == pytest.approx(spec.rate)


def test_heavy_tail_bounded():
    tr = strm.make_trace("heavy_tail", seed=0, steps=96)
    cap = 8 * max(s.max_new for s in tr.specs)
    for batch in strm.arrivals(tr, 64):
        for r in batch:
            assert 1 <= r.max_new <= cap      # capped Pareto
