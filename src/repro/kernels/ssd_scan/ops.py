"""jit'd SSD wrapper: Pallas intra-chunk kernel + jnp inter-chunk scan."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_intra_chunk


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, B, C, *, chunk: int = 256,
             interpret: Optional[bool] = None):
    """Full SSD: y (b, S, nh, hd) and final state (b, nh, hd, ds).

    x: (b, S, nh, hd); dt: (b, S, nh) positive; A: (nh,) negative;
    B, C: (b, S, ds).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, S, nh, hd = x.shape
    ds = B.shape[-1]
    assert S % chunk == 0
    nc = S // chunk

    xf = (x * dt[..., None]).astype(jnp.float32)
    dA = (dt * A[None, None, :]).astype(jnp.float32)
    xc = xf.reshape(b, nc, chunk, nh, hd)
    dAc = dA.reshape(b, nc, chunk, nh)
    Bc = B.reshape(b, nc, chunk, ds).astype(jnp.float32)
    Cc = C.reshape(b, nc, chunk, ds).astype(jnp.float32)

    y_intra, s_chunk, decay = ssd_intra_chunk(
        xc, dAc, Bc, Cc, interpret=interpret)

    # ---- inter-chunk recurrence (tiny, stays in XLA) ----
    h0 = jnp.zeros((b, nh, hd, ds), jnp.float32)

    def step(h, inp):
        s_c, d_c = inp
        h_out = h
        return h * d_c[..., None, None] + s_c, h_out

    h_final, h_enter = jax.lax.scan(
        step, h0, (jnp.moveaxis(s_chunk, 1, 0), jnp.moveaxis(decay, 1, 0)))
    h_enter = jnp.moveaxis(h_enter, 0, 1)              # (b, nc, nh, hd, ds)

    dA_cum = jnp.cumsum(dAc, axis=2)
    y_inter = jnp.einsum("bnqd,bnqh,bnhpd->bnqhp",
                         Cc, jnp.exp(dA_cum), h_enter)
    y = (y_intra + y_inter).reshape(b, S, nh, hd)
    return y, h_final
