"""Parameter-spec system.

Models are described as pytrees of :class:`Param` leaves. Each leaf carries
its shape, dtype, init recipe, and *logical* axis names. The same tree is:

* materialized into real arrays for CPU smoke tests / small training runs, or
* turned into ``jax.ShapeDtypeStruct`` stand-ins (with ``NamedSharding``
  attached) for the multi-pod dry-run — no device allocation.

Logical axes are mapped to mesh axes by :mod:`repro.distributed.sharding`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Param:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]  # logical axis names (len == rank)
    dtype: Any = jnp.bfloat16
    init: str = "normal"             # normal | zeros | ones | constant
    scale: Optional[float] = None    # None -> 1/sqrt(fan_in)
    const: float = 0.0

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_param(x) -> bool:
    return isinstance(x, Param)


def tree_map_params(fn: Callable[[Param], Any], tree):
    return jax.tree_util.tree_map(fn, tree, is_leaf=is_param)


def _fan_in(p: Param) -> int:
    # convention: last axis is the output dim for 2D+ weights
    if len(p.shape) <= 1:
        return max(int(np.prod(p.shape)), 1)
    return int(np.prod(p.shape[:-1]))


def materialize(rng: jax.Array, tree, dtype_override=None):
    """Instantiate real arrays (used by smoke tests and small runs)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree, is_leaf=is_param)
    keys = jax.random.split(rng, len(leaves))
    out = []
    for key, p in zip(keys, leaves):
        dt = dtype_override or p.dtype
        if p.init == "zeros":
            arr = jnp.zeros(p.shape, dt)
        elif p.init == "ones":
            arr = jnp.ones(p.shape, dt)
        elif p.init == "constant":
            arr = jnp.full(p.shape, p.const, dt)
        else:
            scale = p.scale if p.scale is not None else 1.0 / np.sqrt(_fan_in(p))
            arr = (jax.random.normal(key, p.shape, jnp.float32) * scale).astype(dt)
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)


def abstractify(tree, sharding_fn: Optional[Callable[[Param], Any]] = None):
    """ShapeDtypeStruct tree (optionally with NamedSharding) — zero allocation."""

    def _mk(p: Param):
        if sharding_fn is None:
            return jax.ShapeDtypeStruct(p.shape, p.dtype)
        return jax.ShapeDtypeStruct(p.shape, p.dtype, sharding=sharding_fn(p))

    return tree_map_params(_mk, tree)


def stack_params(trees):
    """Stack a list of identically-structured Param trees along a new leading
    'layers' axis (for lax.scan over layers)."""

    def _stack(*ps: Param) -> Param:
        p0 = ps[0]
        assert all(p.shape == p0.shape for p in ps)
        return dataclasses.replace(
            p0, shape=(len(ps),) + p0.shape, axes=("layers",) + p0.axes
        )

    return jax.tree_util.tree_map(_stack, *trees, is_leaf=is_param)


def count_params(tree) -> int:
    leaves = jax.tree_util.tree_leaves(tree, is_leaf=is_param)
    total = 0
    for leaf in leaves:
        if is_param(leaf):
            total += int(np.prod(leaf.shape))
        else:
            total += int(np.prod(jnp.shape(leaf)))
    return total


def param_bytes(tree) -> int:
    leaves = jax.tree_util.tree_leaves(tree, is_leaf=is_param)
    total = 0
    for leaf in leaves:
        itemsize = jnp.dtype(leaf.dtype).itemsize
        total += int(np.prod(leaf.shape)) * itemsize
    return total
