"""Int8 weight-only quantization for serving (§Perf beyond-paper C2).

Decode is weight-read-bound once the KV cache is sharded; int8 weights
halve the per-token HBM weight traffic AND remove the FSDP gather (the
whole TP shard fits residently). Layer weights are stored as
{"q": int8, "scale": f32[out_channels]} and dequantized per layer *inside*
the scan body, so the bf16 copy never materializes globally.

Only transformer-block weights (ndim >= 2, bf16) quantize; norms/scalars
and the embedding/lm-head tables stay bf16 (they are gathered per token).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models.params import Param, is_param


def _quantizable(p: Param) -> bool:
    return len(p.shape) >= 2 and p.dtype == jnp.bfloat16


def quantize_spec_tree(tree):
    """Param-spec tree -> same tree with {"q", "scale"} leaf dicts."""

    def q(p: Param):
        if not _quantizable(p):
            return p
        if len(p.shape) >= 3:  # stacked layers / experts: per-slice scales
            sshape, saxes = (p.shape[0], p.shape[-1]), (p.axes[0], p.axes[-1])
        else:
            sshape, saxes = p.shape[-1:], (p.axes[-1],)
        return {
            "q": dataclasses.replace(p, dtype=jnp.int8),
            "scale": Param(sshape, saxes, dtype=jnp.float32, init="ones"),
        }

    return jax.tree_util.tree_map(q, tree, is_leaf=is_param)


def quantize_arrays(tree):
    """Real bf16 arrays -> int8 + per-out-channel scales (symmetric)."""

    def q(arr):
        if not (hasattr(arr, "ndim") and arr.ndim >= 2
                and arr.dtype == jnp.bfloat16):
            return arr
        a = arr.astype(jnp.float32)
        if a.ndim >= 3:
            red = tuple(range(1, a.ndim - 1))  # per (slice, out-channel)
        else:
            red = tuple(range(a.ndim - 1))
        amax = jnp.maximum(jnp.max(jnp.abs(a), axis=red), 1e-8)
        scale = amax / 127.0
        bshape = ((scale.shape[0],) + (1,) * (a.ndim - 2) + (scale.shape[-1],)
                  if a.ndim >= 3 else scale.shape)
        qv = jnp.clip(jnp.round(a / scale.reshape(bshape)),
                      -127, 127).astype(jnp.int8)
        return {"q": qv, "scale": scale}

    return jax.tree_util.tree_map(q, tree)


def is_qleaf(x) -> bool:
    return isinstance(x, dict) and set(x.keys()) == {"q", "scale"}


def dequant_tree(tree):
    """{"q","scale"} dicts -> bf16 arrays (applied per scanned layer slice
    so the full-precision copy is fused into the consumer, not stored)."""

    def d(x):
        if is_qleaf(x):
            q, s = x["q"], x["scale"]
            if q.ndim >= 3 and s.ndim == 2:
                s = s.reshape((s.shape[0],) + (1,) * (q.ndim - 2)
                              + (s.shape[-1],))
            return q.astype(jnp.bfloat16) * s.astype(jnp.bfloat16)
        return x

    return jax.tree_util.tree_map(d, tree, is_leaf=is_qleaf)
