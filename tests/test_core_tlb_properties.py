"""Hypothesis property tests for the TLB and page-table cores.

Kept separate from test_core_tlb.py so the deterministic unit tests still
run when `hypothesis` is absent; this module skips itself gracefully.
"""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
import hypothesis.strategies as st  # noqa: E402
from hypothesis import given, settings  # noqa: E402

from repro.core import page_table as pt  # noqa: E402
from repro.core import tlb as tlb_mod  # noqa: E402


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(0, 1000), min_size=1, max_size=16),
       st.integers(0, 3))
def test_tlb_property_fill_probe(vpns, asid):
    st_ = tlb_mod.init(64, 16)
    v = jnp.asarray(vpns, jnp.int32)
    a = jnp.full((len(vpns),), asid, jnp.int32)
    act = jnp.ones(len(vpns), bool)
    st_ = tlb_mod.fill(st_, v, a, act, 1)
    # at least the LAST filled instance of each distinct set survives
    st_, hit = tlb_mod.probe(st_, v, a, act, 2)
    # every distinct vpn whose set wasn't contended must hit
    sets = [x % 4 for x in vpns]
    for i, x in enumerate(vpns):
        if sets.count(x % 4) == 1:
            assert bool(hit[i]), (vpns, i)


# ------------------------------------------------------- access_fused
# The fused-round contract, checked identically against both backends
# (the inline XLA path and the Pallas kernel in interpret mode) from an
# empty cache (tags -1, random LRU): every tag change is then a fill,
# which makes the port/victim/forwarding properties directly observable.

_SETS, _WAYS = 4, 2


def _fused_round(backend, lru0, vpn, act, mf, n_waves):
    tags = jnp.full((_SETS, _WAYS), -1, jnp.int32)
    state = tlb_mod.TLBState(
        tags=tags, asids=jnp.full((_SETS, _WAYS), -1, jnp.int32),
        lru=jnp.asarray(lru0, jnp.int32).reshape(_SETS, _WAYS),
        hits=jnp.zeros((), jnp.int32), misses=jnp.zeros((), jnp.int32))
    state, hit, filled = tlb_mod.access_fused(
        state, jnp.asarray(vpn, jnp.int32), jnp.zeros(len(vpn), jnp.int32),
        jnp.asarray(act), jnp.asarray(mf), 7,
        n_waves=n_waves, track_asids=False, backend=backend)
    return (np.asarray(state.tags), np.asarray(state.lru),
            np.asarray(hit), np.asarray(filled))


@pytest.mark.parametrize("backend", ["xla", "pallas-interpret"])
@settings(max_examples=25, deadline=None)
@given(st.data())
def test_access_fused_contract_properties(backend, data):
    W = data.draw(st.sampled_from([1, 2, 3]), label="n_waves")
    C = data.draw(st.sampled_from([1, 2, 4]), label="lanes_per_wave")
    N = W * C
    vpn = np.asarray(data.draw(st.lists(
        st.integers(0, 30), min_size=N, max_size=N)))
    act = np.asarray(data.draw(st.lists(
        st.booleans(), min_size=N, max_size=N)))
    mf = np.asarray(data.draw(st.lists(
        st.booleans(), min_size=N, max_size=N)))
    lru0 = np.asarray(data.draw(st.lists(
        st.integers(0, 50), min_size=_SETS * _WAYS,
        max_size=_SETS * _WAYS))).reshape(_SETS, _WAYS)
    tags1, lru1, hit, filled = _fused_round(backend, lru0, vpn, act, mf, W)

    set_ix = vpn % _SETS
    wave = np.arange(N) // C

    # fill-port uniqueness: at most one fill per (set, wave)
    ports = list(zip(set_ix[filled].tolist(), wave[filled].tolist()))
    assert len(ports) == len(set(ports)), ports

    # victim-chain monotonicity: the r fills a set received landed in
    # exactly its r least-recently-used ways (stable (lru, way) order)
    for s in range(_SETS):
        changed = set(np.nonzero(tags1[s] != -1)[0].tolist())
        r = int((filled & (set_ix == s)).sum())
        lru_order = np.lexsort((np.arange(_WAYS), lru0[s]))
        assert changed == set(lru_order[:r].tolist()), (s, tags1, lru0)

    # forwarding == post-fill re-probe: from an empty cache there are no
    # pre-hits, so a lane hits iff it is active, did not fill itself,
    # and its line is present in the post-fill tags of its set
    expect_hit = act & ~filled & \
        (tags1[set_ix] == vpn[:, None]).any(1)
    np.testing.assert_array_equal(hit, expect_hit)


@settings(max_examples=25, deadline=None)
@given(st.data())
def test_access_fused_backends_bitwise_equal(data):
    W = data.draw(st.sampled_from([1, 2, 3]))
    C = data.draw(st.sampled_from([1, 2, 4]))
    N = W * C
    vpn = data.draw(st.lists(st.integers(0, 30), min_size=N, max_size=N))
    act = data.draw(st.lists(st.booleans(), min_size=N, max_size=N))
    mf = data.draw(st.lists(st.booleans(), min_size=N, max_size=N))
    lru0 = np.asarray(data.draw(st.lists(
        st.integers(0, 50), min_size=_SETS * _WAYS,
        max_size=_SETS * _WAYS))).reshape(_SETS, _WAYS)
    a = _fused_round("xla", lru0, vpn, act, mf, W)
    b = _fused_round("pallas-interpret", lru0, vpn, act, mf, W)
    for xa, xb, name in zip(a, b, ("tags", "lru", "hit", "filled")):
        np.testing.assert_array_equal(xa, xb, err_msg=name)


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**20 - 1), st.integers(0, 2**20 - 1),
       st.integers(0, 63))
def test_pte_root_sharing_property(vpn_a, vpn_b, asid):
    """Near-root PTE lines are shared by nearby VPNs; leaves diverge."""
    cfg = pt.PageTableConfig()
    la = np.asarray(pt.pte_line_addresses(cfg, jnp.int32(asid),
                                          jnp.int32(vpn_a)))
    lb = np.asarray(pt.pte_line_addresses(cfg, jnp.int32(asid),
                                          jnp.int32(vpn_b)))
    # level 0 covers 2^27+ pages per line -> always shared for 20-bit vpns
    assert la[0] == lb[0]
    if vpn_a // 16 == vpn_b // 16:
        assert la[-1] == lb[-1]   # same leaf line
