"""State auditor: structural invariants of `SimState` / `StatState`.

`check_state` verifies everything the simulator's functional updates are
supposed to preserve — on host-side numpy trees (a `jax.device_get` of
the state), so auditing never perturbs the compiled programs. It
collects EVERY violated invariant and raises one `AuditError` listing
them all, with enough coordinates to localize the corruption.

Wired in at `runner._stats`: setting env `REPRO_AUDIT=1` (or passing
`audit=True` to `run_trace` / `_stats`) audits every state that stats
are derived from — the full tier-1 suite runs clean under it, and an
injected corruption fails loudly.

Invariants:

  * TLB caches (L1 bank / shared L2 TLB / bypass cache — ASID-tagged):
    tag/ASID validity agree ((tag<0) iff (asid<0)), no duplicate
    (tag, asid) entry within a set, every live ASID belongs to a current
    generation (`SimState.asid_of_app` — a stale translation surviving a
    shootdown is exactly this violation), LRU stamps within [0, t].
  * Tag-only caches (PWC, L2 data): ASID plane untouched (-1); LRU
    within [0, t]. (Duplicate tags are NOT checked here: the fused
    one-cycle round documents transient cross-core duplicates,
    `core/tlb.py::access_fused`.)
  * Walk table: in-flight rows (done > t) carry a valid vpn and a
    live-generation ASID; merged counts non-negative.
  * Tokens: within [1, warps_per_app], direction in {-1, +1}, epoch
    counters non-negative, miss rate finite in [0, 1].
  * DRAM: queues/pressure non-negative, silver owner a real slot with
    quota >= 1, open rows >= -1.
  * Warps/stats: t >= 0, stream positions and stall deadlines
    non-negative, retired-instruction and counter planes finite and
    non-negative (int32 wraparound shows up here as a negative count).
  * ASID map: slot recovery holds (asid % n_apps == slot, asid >= slot).

`check_monotone(prev, cur, changed)` covers the cross-snapshot law:
cumulative counters never decrease for slots whose membership did not
change between two boundary snapshots.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.sim.config import SimConfig


class AuditError(AssertionError):
    """One or more state invariants are violated; message lists all."""

    def __init__(self, violations: List[str]):
        self.violations = list(violations)
        lines = "\n".join(f"  [{i + 1}] {v}"
                          for i, v in enumerate(self.violations))
        super().__init__(
            f"state audit failed: {len(self.violations)} invariant(s) "
            f"violated\n{lines}")


def _where(mask: np.ndarray, limit: int = 4) -> str:
    """Compact coordinate list of the first offending entries."""
    idx = np.argwhere(mask)
    shown = ", ".join(str(tuple(int(c) for c in row))
                      for row in idx[:limit])
    more = f" (+{len(idx) - limit} more)" if len(idx) > limit else ""
    return f"at {shown}{more}"


def _check_tlb(v: List[str], name: str, tlb, live_asids: np.ndarray,
               t: int, tracked: bool) -> None:
    tags = np.asarray(tlb.tags)
    asids = np.asarray(tlb.asids)
    lru = np.asarray(tlb.lru)
    valid = tags >= 0
    if tracked:
        bad = valid != (asids >= 0)
        if bad.any():
            v.append(f"{name}: tag/asid validity disagree {_where(bad)}")
        stale = valid & (asids >= 0) & \
            ~np.isin(asids, live_asids)
        if stale.any():
            v.append(f"{name}: stale translation for dead ASID "
                     f"{sorted(set(asids[stale].tolist()))} "
                     f"(live: {live_asids.tolist()}) {_where(stale)}")
        # no duplicate (tag, asid) within a set: encode pairs, sort the
        # way axis, compare neighbors (works for banked leading axes)
        key = np.where(valid, tags.astype(np.int64) * (1 << 32)
                       + asids.astype(np.int64), -1 - np.arange(
                           tags.shape[-1], dtype=np.int64))
        ks = np.sort(key, axis=-1)
        dup = (ks[..., 1:] == ks[..., :-1]) & (ks[..., 1:] >= 0)
        if dup.any():
            v.append(f"{name}: duplicate (tag, asid) entries within a "
                     f"set {_where(dup)}")
    else:
        if (asids != -1).any():
            v.append(f"{name}: tag-only cache grew ASID entries "
                     f"{_where(asids != -1)}")
    bad_lru = (lru < 0) | (lru > t)
    if bad_lru.any():
        v.append(f"{name}: LRU stamp outside [0, t={t}] {_where(bad_lru)}")
    for c in ("hits", "misses"):   # scalar, or (n_banks,) on the L1 bank
        n = np.asarray(getattr(tlb, c))
        if (n < 0).any():
            v.append(f"{name}: {c} counter negative ({n}) — int32 wrap")


def check_state(cfg: SimConfig, st, audit_stats: bool = True) -> None:
    """Audit one (host-side) SimState; raises AuditError on violation."""
    from repro.sim import memsys  # avoid import cycle at module load

    v: List[str] = []
    t = int(np.asarray(st.t))
    na = cfg.n_apps
    if t < 0:
        v.append(f"t negative: {t}")

    asid_of_app = np.asarray(st.asid_of_app)
    slots = np.arange(na)
    if asid_of_app.shape != (na,):
        v.append(f"asid_of_app shape {asid_of_app.shape} != ({na},)")
    else:
        bad = (asid_of_app % na != slots) | (asid_of_app < slots)
        if bad.any():
            v.append(f"asid_of_app violates slot recovery "
                     f"(asid % n_apps == slot, asid >= slot): "
                     f"{asid_of_app.tolist()}")

    _check_tlb(v, "l1_tlb_bank", st.trans.l1, asid_of_app, t, tracked=True)
    _check_tlb(v, "l2_tlb", st.trans.l2tlb, asid_of_app, t, tracked=True)
    _check_tlb(v, "bypass_tlb", st.trans.bypass_tlb, asid_of_app, t,
               tracked=True)
    _check_tlb(v, "pwc", st.trans.pwc, asid_of_app, t, tracked=False)
    _check_tlb(v, "l2_data", st.data.l2c, asid_of_app, t, tracked=False)

    walk = np.asarray(st.trans.walk)
    live = walk[:, memsys.WDONE] > t
    wasid = walk[:, memsys.WASID]
    bad = live & ~np.isin(wasid, asid_of_app)
    if bad.any():
        v.append(f"walk table: in-flight walk for dead ASID "
                 f"{sorted(set(wasid[bad].tolist()))} {_where(bad[:, None])}")
    if (live & (walk[:, memsys.WVPN] < 0)).any():
        v.append("walk table: in-flight walk with invalid vpn")
    if (walk[:, memsys.WMERGED] < 0).any():
        v.append("walk table: negative merge count")

    tok = st.tokens
    wpa = np.asarray(cfg.warps_per_app)
    tokens = np.asarray(tok.tokens)
    if ((tokens < 1) | (tokens > wpa)).any():
        v.append(f"tokens outside [1, warps_per_app={wpa.tolist()}]: "
                 f"{tokens.tolist()}")
    if (~np.isin(np.asarray(tok.direction), (-1, 1))).any():
        v.append(f"token direction not in {{-1,+1}}: "
                 f"{np.asarray(tok.direction).tolist()}")
    for c in ("epoch_hits", "epoch_misses"):
        if (np.asarray(getattr(tok, c)) < 0).any():
            v.append(f"tokens.{c} negative: "
                     f"{np.asarray(getattr(tok, c)).tolist()}")
    pmr = np.asarray(tok.prev_miss_rate)
    if (~np.isfinite(pmr)).any() or ((pmr < 0) | (pmr > 1)).any():
        v.append(f"tokens.prev_miss_rate outside [0, 1]: {pmr.tolist()}")

    dram = st.data.dram
    if (np.asarray(dram.queue_len) < 0).any():
        v.append(f"dram.queue_len negative {_where(np.asarray(dram.queue_len) < 0)}")
    # open_row is NOT range-checked: row ids are `lines // (channels *
    # banks * 32)` over hash-derived int32 line addresses, which can be
    # negative — any int32 is a legal row tag (-1 init just means
    # "closed", and a real -1 row id colliding with it is harmless).
    for c in ("conc_walks", "warps_stalled"):
        if (np.asarray(getattr(dram, c)) < 0).any():
            v.append(f"dram.{c} negative: "
                     f"{np.asarray(getattr(dram, c)).tolist()}")
    sa = int(np.asarray(dram.silver_app))
    if not 0 <= sa < na:
        v.append(f"dram.silver_app {sa} outside [0, {na})")
    if int(np.asarray(dram.silver_left)) < 1:
        v.append(f"dram.silver_left {int(np.asarray(dram.silver_left))} < 1")

    instr = np.asarray(st.instr)
    if (~np.isfinite(instr)).any() or (instr < 0).any():
        v.append("retired-instruction counters non-finite or negative "
                 f"{_where(~np.isfinite(instr) | (instr < 0))}")
    if (np.asarray(st.pos) < 0).any():
        v.append("warp stream positions negative")
    if (np.asarray(st.stall_until) < 0).any():
        v.append("warp stall deadlines negative")

    if audit_stats:
        s = st.stats
        if (np.asarray(s.ints) < 0).any():
            v.append(f"stats int counters negative "
                     f"{_where(np.asarray(s.ints) < 0)} — int32 wrap")
        fl = np.asarray(s.floats)
        if (~np.isfinite(fl)).any() or (fl < 0).any():
            v.append(f"stats float accumulators non-finite or negative "
                     f"{_where(~np.isfinite(fl) | (fl < 0))}")
        if (np.asarray(s.scalars) < 0).any():
            v.append("stats scalar counters negative — int32 wrap")

    if v:
        raise AuditError(v)


def check_monotone(prev, cur, changed: Optional[np.ndarray] = None) -> None:
    """Cross-snapshot law: cumulative per-app counters never decrease
    between two boundary states, except for slots whose membership
    changed (their counters reset to a cold start by design).

    `prev` / `cur` are host-side SimStates; `changed` is the (n_apps,)
    bool membership-change mask applied between them (None = no change).
    Raises AuditError."""
    v: List[str] = []
    t0, t1 = int(np.asarray(prev.t)), int(np.asarray(cur.t))
    if t1 < t0:
        v.append(f"time ran backwards: {t0} -> {t1}")
    keep = (~np.asarray(changed, bool) if changed is not None
            else np.ones(np.asarray(cur.stats.ints).shape[0], bool))
    for plane in ("ints", "floats"):
        p = np.asarray(getattr(prev.stats, plane))[keep]
        c = np.asarray(getattr(cur.stats, plane))[keep]
        if (c < p).any():
            v.append(f"stats.{plane} decreased for an unchanged slot "
                     f"{_where(c < p)}")
    p, c = np.asarray(prev.stats.scalars), np.asarray(cur.stats.scalars)
    if (c < p).any():
        v.append(f"stats.scalars decreased {_where(c < p)}")
    if v:
        raise AuditError(v)
