"""Paged decode attention Pallas TPU kernel.

The serving-side translation layer (DESIGN.md §2b): each sequence's KV
lives in scattered physical pages; the logical->physical map (block table)
is prefetched into scalar memory (``PrefetchScalarGridSpec``), and the
BlockSpec index_map *translates on the access path* — the TPU-idiomatic
equivalent of a TLB sitting next to the shader core. Pages beyond
``seq_lens`` are masked (and contribute no state).

Shapes:
  q:           (B, H, dh)                  one new token per sequence
  k_pages:     (P_total, page, KV, dh)     physical KV pool
  v_pages:     (P_total, page, KV, dh)
  block_table: (B, pages_per_seq) int32    logical page -> physical page
  seq_lens:    (B,) int32
Output:        (B, H, dh)
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(block_table, seq_lens,            # scalar-prefetch refs
            q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *,
            page: int, n_pages: int, sm_scale: float):
    b = pl.program_id(0)
    pi = pl.program_id(1)

    @pl.when(pi == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    seq_len = seq_lens[b]
    page_start = pi * page
    live = page_start < seq_len

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                    # (H, dh)
        k = k_ref[0].astype(jnp.float32)                    # (page, KV, dh)
        v = v_ref[0]
        H = q.shape[0]
        KV = k.shape[1]
        G = H // KV
        qg = q.reshape(KV, G, q.shape[1])
        s = jax.lax.dot_general(                             # (KV, G, page)
            qg, jnp.swapaxes(k, 0, 1),                       # (KV, page, dh)
            (((2,), (2,)), ((0,), (0,))),
            preferred_element_type=jnp.float32) * sm_scale
        pos = page_start + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 2)
        s = jnp.where(pos < seq_len, s, NEG_INF)

        m_prev = m_ref[...]                                  # (KV, G)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=2))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=2)
        m_ref[...] = m_new
        pv = jax.lax.dot_general(                            # (KV, G, dh)
            p.astype(v.dtype), jnp.swapaxes(v, 0, 1),
            (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr[..., None] + pv

    @pl.when(pi == n_pages - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        out = acc_ref[...] / l[..., None]                    # (KV, G, dh)
        o_ref[0] = out.reshape(o_ref.shape[1], o_ref.shape[2]).astype(o_ref.dtype)


def paged_attention(q, k_pages, v_pages, block_table, seq_lens, *,
                    interpret: bool = False):
    """See module docstring. Returns (B, H, dh)."""
    B, H, dh = q.shape
    P_total, page, KV, _ = k_pages.shape
    pages_per_seq = block_table.shape[1]
    sm_scale = 1.0 / (dh ** 0.5)

    kern = functools.partial(_kernel, page=page, n_pages=pages_per_seq,
                             sm_scale=sm_scale)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, pages_per_seq),
        in_specs=[
            pl.BlockSpec((1, H, dh), lambda b, pi, bt, sl: (b, 0, 0)),
            pl.BlockSpec((1, page, KV, dh),
                         lambda b, pi, bt, sl: (bt[b, pi], 0, 0, 0)),
            pl.BlockSpec((1, page, KV, dh),
                         lambda b, pi, bt, sl: (bt[b, pi], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, dh), lambda b, pi, bt, sl: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((KV, H // KV), jnp.float32),
            pltpu.VMEM((KV, H // KV), jnp.float32),
            pltpu.VMEM((KV, H // KV, dh), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, dh), q.dtype),
        interpret=interpret,
    )(block_table, seq_lens, q, k_pages, v_pages)
