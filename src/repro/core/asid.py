"""Address-space identifiers and protection domains.

The paper's §5.1: per-core page table root registers (CR3-like) select the
active address space; L2 TLB entries are ASID-tagged; flushes target one
core's L1 TLB + matching-ASID L2 entries. Here an AddressSpace is the unit
of isolation for both the simulator (one per co-scheduled app) and the
serving stack (one per tenant).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional


@dataclasses.dataclass(frozen=True)
class AddressSpace:
    asid: int
    name: str
    # synthetic page-table root (frame number); distinct roots guarantee
    # disjoint PTE addresses across address spaces
    root_frame: int

    def __post_init__(self):
        assert 0 <= self.asid < 256, "8-bit ASIDs (paper §7.5)"


class AsidAllocator:
    """Monotonic ASID allocation with recycling (64 concurrent max, matching
    the paper's 6-bit concurrent-walk counters)."""

    def __init__(self, max_live: int = 64):
        self.max_live = max_live
        self._live: Dict[int, AddressSpace] = {}
        self._next = 0

    def allocate(self, name: str) -> AddressSpace:
        if len(self._live) >= self.max_live:
            raise RuntimeError(f"too many live address spaces (max {self.max_live})")
        while self._next % 256 in self._live:
            self._next += 1
        asid = self._next % 256
        self._next += 1
        sp = AddressSpace(asid=asid, name=name, root_frame=(asid + 1) << 20)
        self._live[asid] = sp
        return sp

    def release(self, asid: int):
        self._live.pop(asid, None)

    def get(self, asid: int) -> Optional[AddressSpace]:
        return self._live.get(asid)

    @property
    def live(self):
        return dict(self._live)
