"""mamba2-1.3b [ssm] — SSD (state-space duality), attention-free.

48L d_model=2048 d_ff=0 vocab=50280, ssm_state=128. [arXiv:2405.21060]

Mamba2 blocks have no separate FFN (d_ff=0): the block's expansion
(ssm_expand=2) is the only width multiplier, matching the reference model.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv_width=4,
    tie_embeddings=True,
)
