"""Mixture-of-Experts FFN with group-local, sort-based capacity dispatch.

GShard-style groups: each *sequence* routes its own tokens independently
(group = sequence), so every dispatch intermediate carries the batch dim and
stays sharded over the data axis. Expert buffers are laid out
(batch -> data, experts -> model, capacity, d); the scatter into them is the
token all-to-all. Compiled FLOPs are proportional to *active* experts:
per-group capacity C = ceil(S*top_k/E * capacity_factor).

Expert weights shard experts->model (EP); under FSDP the ffn dim additionally
shards over data (2D: consumed in place, w_down psums over data) — see
repro.distributed.sharding.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.params import Param


def moe_params(d: int, d_ff: int, n_experts: int):
    return {
        "router": Param((d, n_experts), ("embed", "experts"), dtype=jnp.float32),
        "w_gate": Param((n_experts, d, d_ff), ("experts", "embed", "ffn")),
        "w_up": Param((n_experts, d, d_ff), ("experts", "embed", "ffn")),
        "w_down": Param((n_experts, d_ff, d), ("experts", "ffn", "embed")),
    }


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def moe_apply(params, x: jax.Array, *, top_k: int, capacity_factor: float = 1.25,
              constrain=None):
    """x: (B, S, d) -> (B, S, d), aux dict. Routing is per sequence (group).

    Decode (S == 1): the whole batch routes as ONE group — per-sequence
    groups would round capacity up to 8 slots per (expert, sequence) and
    waste ~E/top_k x expert compute (§Perf hillclimb A1)."""
    B, S, d = x.shape
    if S == 1 and B > 1:
        out, aux = moe_apply(params, x.reshape(1, B, d), top_k=top_k,
                             capacity_factor=capacity_factor,
                             constrain=constrain)
        return out.reshape(B, S, d), aux
    E = params["router"].shape[-1]
    cap = _round_up(int(max(1, round(S * top_k / E * capacity_factor))), 8)
    cap = min(cap, S * top_k)

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)                    # (B, S, E)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)          # (B, S, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # ---- aux losses (Switch formulation, averaged over groups) ----
    me = jnp.mean(probs, axis=1)                               # (B, E)
    ce = jnp.mean(jnp.sum(jax.nn.one_hot(gate_idx, E, dtype=jnp.float32),
                          axis=2), axis=1)                     # (B, E)
    lb_loss = E * jnp.mean(jnp.sum(me * ce, axis=-1))
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))

    # ---- per-group slot assignment (vectorized over B) ----
    SK = S * top_k
    eids = gate_idx.reshape(B, SK)                             # (B, SK)
    tok_of = jnp.broadcast_to(
        jnp.repeat(jnp.arange(S), top_k)[None], (B, SK))       # (B, SK)
    w_of = gate_vals.reshape(B, SK)

    order = jnp.argsort(eids, axis=1, stable=True)             # (B, SK)
    sorted_eids = jnp.take_along_axis(eids, order, axis=1)
    counts = jax.vmap(lambda e: jnp.bincount(e, length=E))(eids)  # (B, E)
    starts = jnp.concatenate(
        [jnp.zeros((B, 1), counts.dtype), jnp.cumsum(counts, axis=1)[:, :-1]],
        axis=1)                                                # (B, E)
    pos_sorted = jnp.arange(SK)[None, :] - jnp.take_along_axis(
        starts, sorted_eids, axis=1)
    pos = jnp.zeros((B, SK), jnp.int32)
    pos = jax.vmap(lambda p, o, v: p.at[o].set(v))(
        pos, order, pos_sorted.astype(jnp.int32))
    valid = pos < cap
    slot = jnp.where(valid, eids * cap + pos, E * cap)         # (B, SK)

    # ---- slot tables: slot -> (token, weight); tiny int/scalar arrays ----
    # Dispatch and combine are formulated as gathers/scatters against the
    # EXPERT-LOCAL buffer so no full-(E*cap, d) tensor is ever materialized
    # replicated across the model axis (neither in fwd nor as a bwd
    # cotangent) — the expert-dim contraction becomes a psum.
    cb = constrain if constrain is not None else (lambda a, ax: a)
    n_slots = E * cap + 1                                      # last = trash
    tok_tbl = jnp.full((B, n_slots), S, jnp.int32)             # S = pad row
    tok_tbl = jax.vmap(lambda tt, ss, vv: tt.at[ss].set(vv))(
        tok_tbl, slot, tok_of.astype(jnp.int32))
    w_tbl = jnp.zeros((B, n_slots), jnp.float32)
    w_tbl = jax.vmap(lambda wt, ss, vv: wt.at[ss].set(vv))(
        w_tbl, slot, jnp.where(valid, w_of, 0.0))
    tok_tbl = tok_tbl[:, : E * cap]
    w_tbl = w_tbl[:, : E * cap]

    xp = jnp.concatenate([x, jnp.zeros((B, 1, d), x.dtype)], axis=1)
    ebuf = jnp.take_along_axis(xp, tok_tbl[..., None], axis=1)
    ebuf = cb(ebuf.reshape(B, E, cap, d), ("batch", "experts", None, None))

    # ---- expert FFN (SwiGLU), batched over (group, expert) ----
    # gate activation stays in bf16: an f32 upcast here makes every backward
    # cotangent (and its cross-shard all-reduce) f32 — 2x HBM and 2x ICI
    g = jnp.einsum("becd,edf->becf", ebuf, params["w_gate"])
    u = jnp.einsum("becd,edf->becf", ebuf, params["w_up"])
    h = jax.nn.silu(g) * u
    y = jnp.einsum("becf,efd->becd", h, params["w_down"])
    if constrain is not None:
        y = constrain(y, ("batch", "experts", None, None))

    # ---- combine: weighted scatter back to token positions ----
    contrib = y.reshape(B, E * cap, d) * w_tbl[..., None].astype(y.dtype)
    out = jnp.zeros((B, S + 1, d), y.dtype)
    out = jax.vmap(lambda oo, tt, cc: oo.at[tt].add(cc))(out, tok_tbl, contrib)
    out = cb(out[:, :S], ("batch", None, None))

    dropped = jnp.sum(~valid) / (B * SK)
    aux = {"lb_loss": lb_loss, "z_loss": z_loss, "dropped_frac": dropped}
    return out, aux
