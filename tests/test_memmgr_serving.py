"""Multi-tenant paged KV manager + serving engine integration tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.memmgr import block_table as bt_mod
from repro.memmgr import kv_cache as kvc


def _pool(n_pages=32, page=8, max_seqs=8, pps=4):
    cfg = kvc.PoolConfig(n_pages=n_pages, page_size=page, n_kv=2, head_dim=16,
                         n_layers=2, max_seqs=max_seqs, pages_per_seq=pps)
    return cfg, kvc.init(cfg)


def test_admit_translate_release_lifecycle():
    cfg, pool = _pool()
    pool, ok = kvc.admit_seq(cfg, pool, jnp.int32(0), jnp.int32(1),
                             jnp.int32(20))  # 20 tokens -> 3 pages
    assert bool(ok)
    assert int(pool.seq_lens[0]) == 20
    pool, phys, fault, _ = kvc.lookup(cfg, pool, jnp.asarray([0, 0]),
                                      jnp.asarray([0, 2]))
    assert not bool(fault.any())
    # unmapped logical page faults
    pool, _, fault, _ = kvc.lookup(cfg, pool, jnp.asarray([0]),
                                   jnp.asarray([3]))
    assert bool(fault[0])
    before = int(bt_mod.n_free(pool.tables))
    pool = kvc.release_seq(cfg, pool, jnp.int32(0))
    assert int(bt_mod.n_free(pool.tables)) == before + 3


def test_protection_domain_fault():
    """Cross-ASID access is a protection fault (the paper's §5.1 isolation)."""
    cfg, pool = _pool()
    pool, _ = kvc.admit_seq(cfg, pool, jnp.int32(0), jnp.int32(1),
                            jnp.int32(8))
    # forge: seq 1 owned by tenant 2 pointing at tenant 1's page
    leaf = pool.tables.leaf.at[1, 0].set(pool.tables.leaf[0, 0])
    pool = pool._replace(tables=pool.tables._replace(leaf=leaf),
                         seq_asid=pool.seq_asid.at[1].set(2),
                         seq_lens=pool.seq_lens.at[1].set(4))
    _, fault = bt_mod.translate(pool.tables, jnp.asarray([1]),
                                jnp.asarray([0]), jnp.asarray([2]))
    assert bool(fault[0])


def test_append_allocates_on_page_boundary():
    cfg, pool = _pool(page=4)
    pool, _ = kvc.admit_seq(cfg, pool, jnp.int32(0), jnp.int32(0),
                            jnp.int32(4))   # exactly one page
    free0 = int(bt_mod.n_free(pool.tables))
    pool, ok = kvc.append_token_alloc(cfg, pool, jnp.int32(0))  # needs page 2
    assert bool(ok)
    assert int(bt_mod.n_free(pool.tables)) == free0 - 1
    pool, ok = kvc.append_token_alloc(cfg, pool, jnp.int32(0))  # same page
    assert int(bt_mod.n_free(pool.tables)) == free0 - 1


def test_pool_exhaustion():
    cfg, pool = _pool(n_pages=4, pps=4)
    pool, ok1 = kvc.admit_seq(cfg, pool, jnp.int32(0), jnp.int32(0),
                              jnp.int32(32))  # 4 pages
    pool, ok2 = kvc.admit_seq(cfg, pool, jnp.int32(1), jnp.int32(0),
                              jnp.int32(8))
    assert bool(ok1) and not bool(ok2)


def test_write_kv_and_block_table_gather():
    cfg, pool = _pool(page=4)
    pool, _ = kvc.admit_seq(cfg, pool, jnp.int32(0), jnp.int32(0),
                            jnp.int32(5))
    k = jnp.ones((1, cfg.n_kv, cfg.head_dim), jnp.bfloat16)
    pool, fault = kvc.write_kv(cfg, pool, 0, jnp.asarray([0]), k, k)
    assert not bool(fault.any())
    bt = kvc.gather_block_table(cfg, pool, jnp.asarray([0]))
    assert bt.shape == (1, cfg.pages_per_seq)
    # the written cell is nonzero
    phys = int(bt[0, 1])  # token index 4 -> page 1, offset 0
    assert float(jnp.sum(pool.k[0, phys, 0])) > 0


@pytest.mark.slow
def test_engine_two_tenants_fairness():
    from repro.launch.serve import build_engine
    from repro.serving import metrics as smet
    from repro.serving.engine import Request

    eng = build_engine("qwen3-4b")
    rng = np.random.RandomState(0)
    for i in range(6):
        eng.submit(Request(rid=i, tenant=i % 2,
                           prompt=rng.randint(0, eng.cfg.vocab_size, 8),
                           max_new=4))
    finished = eng.run_until_drained(max_steps=200)
    assert len(finished) == 6
    tput = smet.tenant_throughput(finished, eng.step_count)
    assert set(tput) == {0, 1}
    ratio = max(tput.values()) / max(min(tput.values()), 1e-9)
    assert ratio < 2.5  # silver rotation keeps tenants comparable
    ws = smet.weighted_speedup(tput, tput)
    assert abs(ws - 2.0) < 1e-6
