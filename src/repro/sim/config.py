"""Simulator configuration (paper Table 1, Maxwell-class)."""
from __future__ import annotations

import dataclasses

from repro.core.mask import DesignPoint, MaskConfig, design


@dataclasses.dataclass(frozen=True)
class SimConfig:
    n_cores: int = 30
    warps_per_core: int = 32
    n_apps: int = 2
    # L2 data cache: 2MB, 16-way, 128B lines -> 1024 sets
    l2_sets: int = 1024
    l2_ways: int = 16
    # page-walk cache (Fig. 2a design): 16-way, 1024 entries (§3 fn. 2)
    pwc_entries: int = 1024
    pwc_ways: int = 16
    # DRAM: 8 channels x 8 banks
    n_channels: int = 8
    n_banks: int = 8
    # latencies (cycles)
    lat_l1_tlb: int = 1
    lat_l2_tlb: int = 10
    lat_l2_cache: int = 10
    lat_l1_data: int = 1
    sim_cycles: int = 60_000
    design: DesignPoint = dataclasses.field(
        default_factory=lambda: design("gpu-mmu"))

    @property
    def total_warps(self) -> int:
        return self.n_cores * self.warps_per_core
