"""Synthetic GPGPU address-stream generators.

The paper's 27 benchmarks (Table 2) fall into four locality categories by
(L1 TLB, L2 TLB) miss rates. We synthesize one deterministic generator per
benchmark: parameters are drawn per-category with a stable per-name jitter,
so 3DS ≠ BLK but both stress the TLB the way the paper's high/high class
does. Streams mix: sequential striding (spatial locality), a hot page set
(temporal locality), and uniform-random far pages (reach).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.page_table import _mix

# Table 2 categorization
CATEGORY: Dict[str, Tuple[str, str]] = {}
for _n in ("LUD", "NN"):
    CATEGORY[_n] = ("low", "low")
for _n in ("BFS2", "FFT", "HISTO", "NW", "QTC", "RAY", "SAD", "SCP"):
    CATEGORY[_n] = ("low", "high")
for _n in ("BP", "GUP", "HS", "LPS"):
    CATEGORY[_n] = ("high", "low")
for _n in ("3DS", "BLK", "CFD", "CONS", "FWT", "LUH", "MM", "MUM", "RED",
           "SC", "SCAN", "SRAD", "TRD"):
    CATEGORY[_n] = ("high", "high")

BENCHES: List[str] = sorted(CATEGORY)


@dataclasses.dataclass(frozen=True)
class AppParams:
    """Traced-friendly scalar params of one application's stream.

    Four temperature tiers: hot (zipf, app-global), warm (PER WARP-GROUP
    working sets reused on a shared-L2-TLB timescale — the tier MASK's
    tokens protect: restricting fills to token-holding groups shrinks the
    active footprint until it fits), sequential streams (page-spatial
    locality, shared within a group -> MSHR merges), and cold-random reach.
    """

    name: str
    ws_pages: int        # total working-set size in pages (cold reach)
    hot_pages: int       # zipf-hot subset
    hot_milli: int       # P(hot access) in 1/1024
    warm_pages: int      # per-group mid-temperature set (L2-TLB-scale reuse)
    warm_milli: int      # P(warm access)
    seq_milli: int       # P(sequential-stream access)
    stride: int          # pages per sequential step
    gap: int             # compute instructions between memory ops
    l1d_hit_milli: int   # L1 data-cache hit probability (1/1024)
    revisit: int         # accesses per page before moving on (spatial loc.)

    def as_array(self) -> np.ndarray:
        out = np.array([getattr(self, f) for f in FIELDS], np.int32)
        assert out.shape == (N_FIELDS,)
        return out


# field order of the (n_apps, N_FIELDS) parameter matrices, derived from the
# dataclass so it cannot drift from `as_array` / `gen_vpn` / `idle_app`
FIELDS: Tuple[str, ...] = tuple(
    f.name for f in dataclasses.fields(AppParams) if f.name != "name")
FIELD: Dict[str, int] = {name: i for i, name in enumerate(FIELDS)}
N_FIELDS = len(FIELDS)


def _jitter(name: str, lo: float, hi: float) -> float:
    h = int(hashlib.md5(name.encode()).hexdigest()[:8], 16)
    return lo + (h / 0xFFFFFFFF) * (hi - lo)


def make_app(name: str) -> AppParams:
    l1c, l2c = CATEGORY[name]
    j = lambda lo, hi: _jitter(name, lo, hi)  # noqa: E731
    warm, warm_m = 1, 0
    if (l1c, l2c) == ("low", "low"):
        # tiny working set: everything fits the 64-entry L1 TLB
        ws = int(j(24, 48))
        hot, hot_m, seq_m, rev = ws // 2, 700, 280, 24
    elif (l1c, l2c) == ("low", "high"):
        # streaming: strong page-level spatial reuse (L1 hits) but unique-
        # page reach far beyond the 512-entry shared L2 TLB
        ws = int(j(16384, 65536))
        hot, hot_m, seq_m, rev = 16, 50, 900, int(j(16, 32))
        warm, warm_m = 64, 40
    elif (l1c, l2c) == ("high", "low"):
        # scattered (no spatial reuse) within a modest set: misses the
        # 64-entry L1, fits the shared L2 TLB when running alone
        ws = int(j(160, 300))
        hot, hot_m, seq_m, rev = 8, 80, 80, 1
        warm, warm_m = ws, 520
    else:  # high, high
        # warm tier sized so its per-page re-touch interval falls BETWEEN
        # the baseline eviction horizon (fills from every warp -> thrash,
        # especially with a co-runner) and the token-restricted horizon
        # (fills from ~1/4 of warps -> resident). This is precisely the
        # regime TLB-Fill Tokens exploit. GB-scale cold reach sends leaf
        # PTE lines to DRAM.
        ws = int(j(16384, 65536))
        hot, hot_m = 64, int(j(100, 160))
        warm, warm_m = int(j(224, 384)), int(j(360, 440))
        seq_m, rev = int(j(120, 220)), int(j(1, 3))
    return AppParams(
        name=name,
        ws_pages=ws,
        hot_pages=max(hot, 1),
        hot_milli=hot_m,
        warm_pages=max(warm, 1),
        warm_milli=warm_m,
        seq_milli=seq_m,
        stride=1,
        gap=int(j(6, 28)),
        l1d_hit_milli=int(j(350, 800)),
        revisit=max(rev, 1),
    )


def idle_app() -> AppParams:
    """Partner that effectively never issues (gap >> per-access budget) and
    never misses (single hot page): the §6 `IPC_alone` baseline keeps the
    app's core share while leaving the memory system uncontended."""
    return AppParams(name="__idle__", ws_pages=1, hot_pages=1, hot_milli=1024,
                     warm_pages=1, warm_milli=0, seq_milli=0, stride=1,
                     gap=4000, l1d_hit_milli=1024, revisit=1)


IDLE_ROW = idle_app().as_array()


def app_matrix(names) -> np.ndarray:
    """(n_apps, N_FIELDS) int32 parameter matrix. None entries -> idle app."""
    return np.stack([make_app(n).as_array() if n is not None else IDLE_ROW
                     for n in names])


def gen_vpn(params_row, app_id, warp_id, pos, t):
    """Deterministic VPN for one access. All args traced int32 arrays.

    params_row: (N_FIELDS,) int32 for this app; t: scalar sim time.
    """
    f = lambda name: params_row[..., FIELD[name]]  # noqa: E731
    ws, hot, hot_m = f("ws_pages"), f("hot_pages"), f("hot_milli")
    warm, warm_m, seq_m = f("warm_pages"), f("warm_milli"), f("seq_milli")
    stride, rev = f("stride"), f("revisit")
    # page index advances every `rev` accesses (intra-page spatial locality);
    # the stream selector is drawn per page-epoch so revisits return to the
    # SAME page.
    pg = pos // jnp.maximum(rev, 1)
    r = _mix(pg.astype(jnp.uint32) * jnp.uint32(2654435761)
             + warp_id.astype(jnp.uint32) * jnp.uint32(40503)
             + app_id.astype(jnp.uint32))
    sel = (r % jnp.uint32(1024)).astype(jnp.int32)
    r2 = _mix(r + jnp.uint32(0x9E3779B9))
    # zipf-ish skew within the hot set (nested modulus ≈ 1/rank weights):
    # a handful of pages dominate — what the 32-entry bypass cache catches
    hot_span = jnp.uint32(1) + (_mix(r2) % hot.astype(jnp.uint32))
    hot_vpn = (r2 % hot_span).astype(jnp.int32)
    group = warp_id // 8
    warm_vpn = hot + (r2 % warm.astype(jnp.uint32)).astype(jnp.int32)
    warm_hi = hot + warm
    # the sequential stream is TIME-based and shared app-wide (a kernel
    # sweeping an array): every warp touching it in the same window lands
    # on the SAME page -> concurrent same-page misses merge in the walker
    # and stall many warps at once (the paper's Fig. 4/5 pile-ups)
    seq_vpn = warm_hi + ((t // 64) * stride + group % 4) % ws
    rnd_vpn = warm_hi + (r2 % ws.astype(jnp.uint32)).astype(jnp.int32)
    vpn = jnp.where(
        sel < hot_m, hot_vpn,
        jnp.where(sel < hot_m + warm_m, warm_vpn,
                  jnp.where(sel < hot_m + warm_m + seq_m, seq_vpn, rnd_vpn)))
    # per-app base offset keeps address spaces visibly disjoint even before
    # ASID tagging (ASIDs are what actually isolates them)
    return vpn + app_id * (1 << 22)


def mix_workloads(seed: int = 7, n_mixes: int = 35,
                  n_apps: int = 2) -> List[Tuple[str, ...]]:
    """Random N-app bundles avoiding low-low apps (paper §6 generalized).

    The n_apps=2 draw sequence is identical to the paper sweep's historical
    pairing, so cached sweep results stay valid.
    """
    import math
    rng = np.random.RandomState(seed)
    eligible = [b for b in BENCHES if CATEGORY[b] != ("low", "low")]
    if n_apps > len(eligible):
        raise ValueError(f"n_apps={n_apps} exceeds {len(eligible)} "
                         "eligible benchmarks")
    if n_mixes > math.comb(len(eligible), n_apps):
        raise ValueError(
            f"n_mixes={n_mixes} exceeds the "
            f"{math.comb(len(eligible), n_apps)} distinct {n_apps}-app "
            "bundles")
    seen, out = set(), []
    while len(out) < n_mixes:
        mix = tuple(str(b) for b in rng.choice(eligible, n_apps,
                                               replace=False))
        if frozenset(mix) in seen:
            continue
        seen.add(frozenset(mix))
        out.append(mix)
    return out


def pair_workloads(seed: int = 7, n_pairs: int = 35) -> List[Tuple[str, str]]:
    """35 random pairs avoiding low-low apps (paper §6)."""
    return mix_workloads(seed, n_pairs, 2)


def hmr_class(mix: Tuple[str, ...]) -> int:
    """0..len(mix) HMR: count of high-L1,high-L2 apps in the bundle."""
    return sum(1 for b in mix if CATEGORY[b] == ("high", "high"))


def churn_schedule(seed: int = 0, n_segments: int = 8, n_slots: int = 2,
                   arrival_rate: float = 0.4, departure_rate: float = 0.25,
                   benches: Optional[List[str]] = None
                   ) -> List[Tuple[Optional[str], ...]]:
    """Seeded time-varying membership for `runner.run_trace`.

    Returns one bench tuple per segment (None = empty slot). Per
    boundary, each occupied slot departs with `departure_rate` and each
    empty slot admits a random app with `arrival_rate` — a discrete
    birth-death process over the slot array, the thesis's (arXiv
    1803.06958) time-varying sharing shape. A departure immediately
    followed by an arrival in the same slot is a slot hand-off: the
    runner tears the predecessor down and starts the successor on a
    fresh ASID generation. Deterministic in `seed`.
    """
    if n_segments < 1 or n_slots < 1:
        raise ValueError("need n_segments >= 1 and n_slots >= 1")
    rng = np.random.RandomState(seed)
    pool = list(benches) if benches is not None else [
        b for b in BENCHES if CATEGORY[b] != ("low", "low")]
    cur: List[Optional[str]] = [None] * n_slots
    # start half-occupied (at least one app, so segment 0 is never fully
    # idle) — the ramp-up to steady-state occupancy is part of the churn
    for s in rng.choice(n_slots, size=max(n_slots // 2, 1), replace=False):
        cur[s] = str(rng.choice(pool))
    out = [tuple(cur)]
    for _ in range(n_segments - 1):
        for s in range(n_slots):
            if cur[s] is not None and rng.rand() < departure_rate:
                cur[s] = None
            if cur[s] is None and rng.rand() < arrival_rate:
                cur[s] = str(rng.choice(pool))
        out.append(tuple(cur))
    return out
