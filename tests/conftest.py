import os

# smoke tests and benches must see the real (single) device — the 512-device
# override belongs ONLY to the dry-run (see launch/dryrun.py)
assert "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", "")


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
