"""Composable design points: per-layer policy specs + a design registry.

A `Design` is a frozen, hashable composition of one policy spec per
memory-system layer:

  translation — which TLB organization serves address translation
                (ideal / page-walk-cache / shared L2 TLB) and its sizing
  partition   — whether shared L2$/DRAM resources are statically split
                per app (the paper's `Static` baseline) or fully shared
  tokens      — TLB-Fill Tokens (§5.2): epoch hill-climb on fill rights
  bypass      — TLB-request-aware L2 data-cache bypass (§5.3)
  dram        — address-space-aware DRAM scheduling (§5.4)

Every design point of the paper (ideal / PWC / GPU-MMU / Static /
MASK±components) is a registered composition of these specs, and new
points — e.g. MASK with a different token schedule, or bypass-only with a
bigger shared TLB — are expressed by composing specs, never by editing
simulator internals:

    mask = get_design("mask")
    mine = mask.with_(name="mask-small-tokens",
                      tokens=dict(initial_frac=0.1),
                      bypass=dict(enabled=False))
    register_design(mine)

Specs are plain frozen dataclasses: hashable (so a `SimConfig` carrying a
`Design` keys jit/compile caches correctly) and static under jit (stage
dispatch in `repro.sim.memsys` branches on them at trace time).

`repro.core.mask` keeps the legacy `DesignPoint`/`MaskConfig` dataclasses
and the `design(name)` / `ALL_DESIGNS` shims on top of this registry.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

# translation organizations (paper Fig. 2a/2b + the ideal upper bound)
TRANSLATION_KINDS = ("ideal", "pwc", "shared_l2_tlb", "walk_only")
PARTITION_KINDS = ("shared", "static")
DRAM_KINDS = ("fr_fcfs", "mask")


@dataclasses.dataclass(frozen=True)
class TranslationSpec:
    """Translation-layer policy: organization + cache sizing (Table 1).

    kind:
      "ideal"         — every TLB access hits (no translation overhead)
      "pwc"           — per-core L1 TLBs + shared page-walk cache (Fig. 2a)
      "shared_l2_tlb" — per-core L1 TLBs + shared L2 TLB (Fig. 2b)
      "walk_only"     — L1 TLBs only; every miss walks (no shared level)
    """

    kind: str = "shared_l2_tlb"
    l1_entries: int = 64             # fully associative, per core
    l2_entries: int = 512            # 16-way, ASID-tagged, shared
    l2_ways: int = 16
    walk_levels: int = 4             # radix page-table depth
    max_concurrent_walks: int = 64   # walker threads (Table 1)

    def __post_init__(self):
        if self.kind not in TRANSLATION_KINDS:
            raise ValueError(f"translation kind {self.kind!r} not in "
                             f"{TRANSLATION_KINDS}")


@dataclasses.dataclass(frozen=True)
class PartitionSpec:
    """Shared-resource partitioning: "shared" contends everything;
    "static" gives each app a contiguous ~1/n slice of L2 sets and DRAM
    channels (the `Static` baseline, §6)."""

    kind: str = "shared"

    def __post_init__(self):
        if self.kind not in PARTITION_KINDS:
            raise ValueError(f"partition kind {self.kind!r} not in "
                             f"{PARTITION_KINDS}")


@dataclasses.dataclass(frozen=True)
class TokenSpec:
    """TLB-Fill Tokens (§5.2): only token-holding warps may fill the
    shared L2 TLB; the rest fill a small bypass cache. Token counts adapt
    per epoch by hill-climbing on the shared-TLB miss rate."""

    enabled: bool = False
    # paper initializes at 0.8 with 100K-cycle epochs; our scaled runs see
    # ~7 epochs, so the default starts near the converged region
    initial_frac: float = 0.25
    step_frac: float = 0.5           # geometric hill-climb step
    bypass_cache_entries: int = 32   # fully associative


@dataclasses.dataclass(frozen=True)
class BypassSpec:
    """TLB-request-aware L2 data-cache bypass (§5.3): per-walk-level fill
    gating against the data-request hit rate."""

    enabled: bool = False


@dataclasses.dataclass(frozen=True)
class DramSpec:
    """DRAM scheduling: "fr_fcfs" is the baseline; "mask" adds the
    golden/silver/normal queues with Eq. (1) silver quotas (§5.4)."""

    kind: str = "fr_fcfs"
    thres_max: int = 500             # Eq. (1) quota ceiling

    def __post_init__(self):
        if self.kind not in DRAM_KINDS:
            raise ValueError(f"dram kind {self.kind!r} not in {DRAM_KINDS}")

    @property
    def enabled(self) -> bool:
        return self.kind == "mask"


@dataclasses.dataclass(frozen=True)
class Design:
    """A named, frozen, hashable design point: one policy spec per layer.

    Hashability matters: `SimConfig` embeds the `Design`, and the runner's
    compile caches are keyed on the full config — two designs that differ
    in any spec field never share a compiled executable, even if they
    share a name.
    """

    name: str
    translation: TranslationSpec = TranslationSpec()
    partition: PartitionSpec = PartitionSpec()
    tokens: TokenSpec = TokenSpec()
    bypass: BypassSpec = BypassSpec()
    dram: DramSpec = DramSpec()
    epoch_cycles: int = 8_000        # paper: 100K; scaled to sim length

    # ---------------------------------------------------------- overrides

    def with_(self, **overrides) -> "Design":
        """Ablation-grid helper: `dataclasses.replace` with nested-merge
        sugar — a dict value merges into the corresponding spec instead of
        replacing it wholesale.

            mask.with_(name="my-mask", tokens=dict(initial_frac=0.1),
                       bypass=dict(enabled=False))
        """
        fields = {f.name for f in dataclasses.fields(self)}
        updates = {}
        for key, val in overrides.items():
            if key not in fields:
                raise TypeError(f"Design has no layer/field {key!r} "
                                f"(have: {', '.join(sorted(fields))})")
            cur = getattr(self, key)
            if isinstance(val, dict) and dataclasses.is_dataclass(cur):
                val = dataclasses.replace(cur, **val)
            updates[key] = val
        return dataclasses.replace(self, **updates)

    replace = with_

    # ------------------------------------------------- legacy flag views
    # Read-only views matching the pre-registry `DesignPoint` flag bag, so
    # code written against `design(name).mask.epoch_cycles` etc. keeps
    # working unchanged.

    @property
    def ideal_tlb(self) -> bool:
        return self.translation.kind == "ideal"

    @property
    def use_pwc(self) -> bool:
        return self.translation.kind == "pwc"

    @property
    def use_l2_tlb(self) -> bool:
        return self.translation.kind in ("shared_l2_tlb", "ideal")

    @property
    def static_partition(self) -> bool:
        return self.partition.kind == "static"

    @property
    def mask(self):
        from repro.core.mask import MaskConfig
        return MaskConfig(
            tlb_tokens=self.tokens.enabled,
            l2_bypass=self.bypass.enabled,
            dram_sched=self.dram.enabled,
            l1_tlb_entries=self.translation.l1_entries,
            l2_tlb_entries=self.translation.l2_entries,
            l2_tlb_ways=self.translation.l2_ways,
            bypass_cache_entries=self.tokens.bypass_cache_entries,
            epoch_cycles=self.epoch_cycles,
            initial_token_frac=self.tokens.initial_frac,
            token_step_frac=self.tokens.step_frac,
            thres_max=self.dram.thres_max,
            walk_levels=self.translation.walk_levels,
            max_concurrent_walks=self.translation.max_concurrent_walks,
        )


# ---------------------------------------------------------------------------
# static / traced split: StaticSignature + DesignParams
# ---------------------------------------------------------------------------
# A Design splits into two planes:
#
#   * the STATIC SIGNATURE — every field that changes array shapes or the
#     traced program structure (cache sizing, walk depth, walk-table size,
#     epoch length, and whether translation is "ideal", which traces the
#     whole walk machinery out of the program). Designs sharing a
#     signature share ONE compiled executable.
#   * the traced DESIGN PARAMS — every remaining knob (policy booleans,
#     token budgets, hill-climb step, DRAM quota ceiling), packed as a
#     small pytree of scalars and fed to the compiled program as inputs.
#     The memsys stages select on them with `jnp.where`, so a whole
#     design x mix grid can be vmapped through one executable.


@dataclasses.dataclass(frozen=True)
class StaticSignature:
    """The compile-relevant plane of a Design (hashable compile key).

    Two designs with equal signatures are guaranteed to lower to the same
    XLA program; everything else about them rides in `DesignParams`.
    """

    ideal: bool                   # "ideal" translation traces out the walks
    l1_entries: int
    l2_entries: int
    l2_ways: int
    walk_levels: int
    max_concurrent_walks: int
    bypass_cache_entries: int
    epoch_cycles: int


def static_signature(d) -> StaticSignature:
    """The static (shape/structure) plane of a design — the compile key."""
    d = as_design(d)
    tr = d.translation
    return StaticSignature(
        ideal=tr.kind == "ideal",
        l1_entries=tr.l1_entries,
        l2_entries=tr.l2_entries,
        l2_ways=tr.l2_ways,
        walk_levels=tr.walk_levels,
        max_concurrent_walks=tr.max_concurrent_walks,
        bypass_cache_entries=d.tokens.bypass_cache_entries,
        epoch_cycles=d.epoch_cycles,
    )


def canonical_design(sig: StaticSignature) -> Design:
    """The canonical representative `Design` of a signature group.

    Deterministic in the signature, so configs built from it compare/hash
    equal and key one shared compile-cache entry per group. Its dynamic
    fields are placeholders: the simulator must read those from
    `DesignParams` only (the float-hex goldens enforce this — a stage
    reading a placeholder statically would collapse all same-signature
    designs onto one behavior)."""
    kind = "ideal" if sig.ideal else "shared_l2_tlb"
    return Design(
        name=f"__sig:{'ideal' if sig.ideal else 'std'}__",
        translation=TranslationSpec(
            kind=kind, l1_entries=sig.l1_entries,
            l2_entries=sig.l2_entries, l2_ways=sig.l2_ways,
            walk_levels=sig.walk_levels,
            max_concurrent_walks=sig.max_concurrent_walks),
        tokens=TokenSpec(bypass_cache_entries=sig.bypass_cache_entries),
        epoch_cycles=sig.epoch_cycles,
    )


class DesignParams(NamedTuple):
    """The traced plane of a Design: scalar knobs fed to the compiled sim.

    All leaves are 0-d jax arrays so a stack of designs is just a leading
    axis + vmap. Policy selectors are booleans the stages `jnp.where` on
    (masked TLB probes/fills are state no-ops), never Python branches.
    """

    use_l2_tlb: jax.Array       # () bool: shared L2 TLB organization
    use_pwc: jax.Array          # () bool: page-walk-cache organization
    tokens_on: jax.Array        # () bool: TLB-Fill Tokens (§5.2)
    initial_frac: jax.Array     # () float32 initial token fraction
    step_frac: jax.Array        # () float32 hill-climb step
    bypass_on: jax.Array        # () bool: L2 data-cache bypass (§5.3)
    dram_on: jax.Array          # () bool: MASK DRAM scheduler (§5.4)
    thres_max: jax.Array        # () int32 Eq. (1) quota ceiling
    static_part: jax.Array      # () bool: static L2$/DRAM partitioning


def design_params(d) -> DesignParams:
    """Pack a design's dynamic knobs into the traced `DesignParams` plane."""
    d = as_design(d)
    return DesignParams(
        use_l2_tlb=jnp.asarray(d.translation.kind == "shared_l2_tlb", bool),
        use_pwc=jnp.asarray(d.translation.kind == "pwc", bool),
        tokens_on=jnp.asarray(d.tokens.enabled, bool),
        initial_frac=jnp.asarray(d.tokens.initial_frac, jnp.float32),
        step_frac=jnp.asarray(d.tokens.step_frac, jnp.float32),
        bypass_on=jnp.asarray(d.bypass.enabled, bool),
        dram_on=jnp.asarray(d.dram.enabled, bool),
        thres_max=jnp.asarray(d.dram.thres_max, jnp.int32),
        static_part=jnp.asarray(d.partition.kind == "static", bool),
    )


def from_legacy(dp) -> Design:
    """Convert a legacy `repro.core.mask.DesignPoint` to a `Design`."""
    if isinstance(dp, Design):
        return dp
    m = dp.mask
    if dp.ideal_tlb:
        kind = "ideal"
    elif dp.use_pwc:
        if dp.use_l2_tlb:
            # the old pipeline would run BOTH the shared L2 TLB and the
            # PWC for this flag combo; no TranslationSpec kind expresses
            # that, so refuse rather than silently drop one of them
            raise ValueError(
                f"legacy DesignPoint {dp.name!r} sets both use_l2_tlb and "
                "use_pwc; that combination has no Design equivalent — "
                "pick one translation organization")
        kind = "pwc"
    elif dp.use_l2_tlb:
        kind = "shared_l2_tlb"
    else:
        kind = "walk_only"
    return Design(
        name=dp.name,
        translation=TranslationSpec(
            kind=kind, l1_entries=m.l1_tlb_entries,
            l2_entries=m.l2_tlb_entries, l2_ways=m.l2_tlb_ways,
            walk_levels=m.walk_levels,
            max_concurrent_walks=m.max_concurrent_walks),
        partition=PartitionSpec(
            "static" if dp.static_partition else "shared"),
        tokens=TokenSpec(enabled=m.tlb_tokens,
                         initial_frac=m.initial_token_frac,
                         step_frac=m.token_step_frac,
                         bypass_cache_entries=m.bypass_cache_entries),
        bypass=BypassSpec(enabled=m.l2_bypass),
        dram=DramSpec("mask" if m.dram_sched else "fr_fcfs",
                      thres_max=m.thres_max),
        epoch_cycles=m.epoch_cycles,
    )


def as_design(d) -> Design:
    """Normalize str | Design | legacy DesignPoint to a Design."""
    if isinstance(d, Design):
        return d
    if isinstance(d, str):
        return get_design(d)
    if hasattr(d, "mask") and hasattr(d, "name"):  # legacy DesignPoint
        return from_legacy(d)
    raise TypeError(f"not a design name/Design/DesignPoint: {d!r}")


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Design] = {}


def register_design(d: Design, *, overwrite: bool = False) -> Design:
    """Register a design under its name; returns it for chaining.

    Refuses to silently shadow an existing *different* design (re-registering
    an identical one is a no-op) unless `overwrite=True`.
    """
    if not isinstance(d, Design):
        d = as_design(d)
    prev = _REGISTRY.get(d.name)
    if prev is not None and prev != d and not overwrite:
        raise ValueError(
            f"design {d.name!r} already registered with different specs; "
            "pass overwrite=True or pick another name")
    _REGISTRY[d.name] = d
    return d


def get_design(name: str) -> Design:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown design {name!r}; registered: "
                       f"{', '.join(sorted(_REGISTRY))}") from None


def list_designs() -> Tuple[str, ...]:
    """Registered design names, built-ins first (registration order)."""
    return tuple(_REGISTRY)


# ------------------------------------------------------------- built-ins
# The paper's named baselines and MASK±component ablations (§6).

_MECHS_OFF = dict(tokens=TokenSpec(enabled=False),
                  bypass=BypassSpec(enabled=False),
                  dram=DramSpec("fr_fcfs"))

BUILTIN_DESIGNS: Tuple[Design, ...] = (
    Design("ideal", translation=TranslationSpec(kind="ideal"), **_MECHS_OFF),
    Design("pwc", translation=TranslationSpec(kind="pwc"), **_MECHS_OFF),
    Design("gpu-mmu", **_MECHS_OFF),
    Design("static", partition=PartitionSpec("static"), **_MECHS_OFF),
    Design("mask", tokens=TokenSpec(enabled=True),
           bypass=BypassSpec(enabled=True), dram=DramSpec("mask")),
    Design("mask-tlb", tokens=TokenSpec(enabled=True)),
    Design("mask-cache", bypass=BypassSpec(enabled=True)),
    Design("mask-dram", dram=DramSpec("mask")),
)

for _d in BUILTIN_DESIGNS:
    register_design(_d)
