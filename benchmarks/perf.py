"""Simulator throughput microbenchmark -> BENCH_sim.json.

Measures steps/sec of the compiled one-cycle pipeline in four shapes:

  2app    — one 2-app mix (the paper's pair setting)
  4app    — one 4-app mix (N-way sharing)
  batch8  — eight 2-app mixes vmapped through one executable
  churn   — the same 2-app mix run through the SEGMENTED runner
            (`run_trace`, K=4 epoch-aligned segments, constant
            membership) so the scenario's work is identical to a
            monolithic run of the same total cycles: its rate vs
            `2app` — and its `--compare` ratio against a
            pre-segmentation baseline tree, which falls back to the
            monolithic `run_mix` of the same workload — isolates the
            segmentation overhead (per-boundary state round-trip +
            host-side snapshot), honestly, rather than timing a
            different workload
  grid    — the full 8-design x 2-mix ablation sweep at the sweep-
            iteration scale (min(--cycles, GRID_CYCLES) cycles): one
            compiled, vmapped grid execution per static-signature group
            (two for the paper designs); on trees without the grid path
            it falls back to the per-design loop. Under `--compare`
            this scenario is timed END-TO-END from cold — compile +
            execute at a fresh cycle count per round — because the
            sweep's dominant cost at this scale is its XLA compiles (8
            programs pre-vectorization vs one per signature group)

With `--devices N` (N > 1) a fifth scenario rides along:

  grid_sharded — the grid sweep with its stacked rows sharded over N
            devices (runner `_row_sharding`/`_pad_rows`); if fewer
            devices are visible the benchmark re-executes itself with
            `--xla_force_host_platform_device_count=N`. Under
            `--compare` it is timed cold like `grid`, new-side sharded
            vs old-side single-device, at a disjoint cycle count so
            neither side reuses the `grid` round's compiles.

`--tlb-backend {xla,pallas,pallas-interpret}` selects the fused
shared-round backend for the current tree (SimConfig.tlb_backend; all
backends are bit-for-bit identical, see tests/test_tlb_backends.py).

The scenarios are interleaved round-robin inside ONE process and
the median per-scenario rate is reported: this box's absolute throughput
drifts with neighbor load, so sequential before/after blocks are not
comparable — interleaving keeps the scenarios under the same drift, and
the recorded JSON gives future PRs a perf trajectory (compare ratios
between scenarios / versions, not absolute steps/sec across days).

`--compare <git-ref>` is the honest A/B protocol for the same reason:
the baseline tree is materialized from git into a renamed `repro_base`
package, both versions are compiled into THIS process, and each round
times them back-to-back (pair-by-pair) so neighbor drift hits both
sides equally; the reported number is the median new/old speedup per
scenario, never a cross-run absolute.

Compiles are cached persistently under `.jax_cache/` (repo root) so
repeated invocations skip XLA recompiles; disable with
`--no-compile-cache`. `--compare` removes its materialized baseline
tree on exit unless `--keep-baseline`.

Run:  PYTHONPATH=src python -m benchmarks.perf [--cycles N] [--rounds R]
      PYTHONPATH=src python -m benchmarks.perf --compare HEAD
"""
from __future__ import annotations

import argparse
import atexit
import dataclasses
import importlib
import json
import os
import platform
import re
import shutil
import subprocess
import sys
import tarfile
import time
from io import BytesIO
from pathlib import Path

import jax
import numpy as np

REPO_ROOT = Path(__file__).resolve().parent.parent
OUT_PATH = REPO_ROOT / "BENCH_sim.json"
COMPARE_DIR = REPO_ROOT / ".bench_compare"
CACHE_DIR = REPO_ROOT / ".jax_cache"
_IMPORT_RE = re.compile(r"^(\s*(?:from|import)\s+)repro(?=[.\s])",
                        re.MULTILINE)
GRID_N_MIXES = 2     # grid scenario: all 8 paper designs x this many pairs
# The grid scenario runs at min(--cycles, GRID_CYCLES): it benchmarks the
# sweep-harness shape that design-vectorization targets — short iterative
# sweeps (CI smoke, test goldens, dev loops) where the 8-vs-2 XLA compiles
# dominate wall time. At paper scale (60K cycles) a sweep is
# execution-bound and the vmapped grid is execution-neutral on this box
# (flat per-sim batch scaling, measured G=2..14; see README), so the
# saving there is the fixed compile time, not a proportional factor.
GRID_CYCLES = 2_000
CHURN_SEGMENTS = 4   # churn scenario: K segments of cycles/K each
# Subprocess guard rails: a wedged `git` (e.g. a lock held by another
# process) or a hung re-exec child must fail the benchmark loudly, not
# hang CI forever. Generous on purpose — these bound pathology, they are
# not performance budgets.
GIT_TIMEOUT_S = 120
REEXEC_TIMEOUT_S = 4 * 3600


def enable_compilation_cache(cache_dir: Path = CACHE_DIR) -> None:
    """Enable JAX's persistent compilation cache under `cache_dir` so
    repeated benchmark invocations skip recompiles (opt out with
    --no-compile-cache; see README "Performance")."""
    jax.config.update("jax_compilation_cache_dir", str(cache_dir))
    # cache every entry, however small/fast — sim compiles are the cost
    # (0, not the default 1s: CI-smoke-scale programs compile sub-second)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0)


def _mk_cfg(config_mod, **kw):
    """SimConfig for `config_mod`, dropping kwargs the tree predates
    (e.g. `tlb_backend` does not exist on pre-PR-6 baseline copies)."""
    fields = {f.name for f in dataclasses.fields(config_mod.SimConfig)}
    return config_mod.SimConfig(**{k: v for k, v in kw.items()
                                   if k in fields})


def _signature_groups(pkg: str = "repro"):
    """Count of static-signature groups over the paper's 8 designs, or
    None for trees that predate the static/traced design split."""
    design_mod = importlib.import_module(pkg + ".core.design")
    mask_mod = importlib.import_module(pkg + ".core.mask")
    if not hasattr(design_mod, "static_signature"):
        return None
    return len({design_mod.static_signature(design_mod.get_design(n))
                for n in mask_mod.ALL_DESIGNS})


def _scenarios(design: str, cycles: int, pkg: str = "repro",
               include_grid: bool = True, tlb_backend: str = "xla",
               devices: int = 0):
    """name -> (zero-arg compiled call, sim-steps per call).

    `pkg` selects the simulator package ("repro" or a baseline copy such
    as "repro_base") so two versions can be timed in one process.
    `include_grid=False` skips building the grid scenarios (the compare
    harness times grid sweeps cold via `_grid_sweep` instead).
    `tlb_backend` selects the fused-round backend on trees that have the
    knob (silently dropped on older baseline copies, which ARE the xla
    path). `devices > 1` adds a `grid_sharded` scenario: the same sweep
    with its rows sharded over that many devices.
    """
    import jax.numpy as jnp
    config_mod = importlib.import_module(pkg + ".sim.config")
    runner_mod = importlib.import_module(pkg + ".sim.runner")
    workloads_mod = importlib.import_module(pkg + ".sim.workloads")
    design_mod = importlib.import_module(pkg + ".core.design")
    d = design_mod.get_design(design)

    def single(benches):
        cfg = _mk_cfg(config_mod, n_apps=len(benches), sim_cycles=cycles,
                      design=d, tlb_backend=tlb_backend)
        pm = jnp.asarray(runner_mod._mix_matrix(benches))
        fn = runner_mod._compiled_run(cfg)
        return (lambda: jax.block_until_ready(fn(pm))), cycles

    def batch(mixes):
        cfg = _mk_cfg(config_mod, n_apps=len(mixes[0]), sim_cycles=cycles,
                      design=d, tlb_backend=tlb_backend)
        pm = jnp.asarray(np.stack([runner_mod._mix_matrix(m)
                                   for m in mixes]))
        fn = runner_mod._compiled_batch_run(cfg)
        return (lambda: jax.block_until_ready(fn(pm))), cycles * len(mixes)

    def churn():
        """Segmented runner over the 2app workload (constant membership,
        K = CHURN_SEGMENTS segments). On trees that predate `run_trace`
        the MONOLITHIC `run_mix` of the same total cycles stands in, so
        a --compare ratio measures segmentation overhead on identical
        work. Runs the tree's default TLB backend (run_trace owns its
        SimConfig)."""
        segc = max(1, cycles // CHURN_SEGMENTS)
        total = segc * CHURN_SEGMENTS
        mix = ("3DS", "BLK")
        if hasattr(runner_mod, "run_trace"):
            call = (lambda: runner_mod.run_trace(
                design, [mix] * CHURN_SEGMENTS, seg_cycles=segc,
                collect_segments=False))
        else:
            call = (lambda: runner_mod.run_mix(design, list(mix),
                                               cycles=total))
        return call, total

    mix4 = workloads_mod.mix_workloads(seed=7, n_mixes=1, n_apps=4)[0]
    scen = {
        "2app": single(["3DS", "BLK"]),
        "4app": single(list(mix4)),
        "batch8": batch(workloads_mod.pair_workloads()[:8]),
        "churn": churn(),
    }
    if include_grid:
        scen["grid"] = _grid_sweep(pkg, min(cycles, GRID_CYCLES),
                                   tlb_backend)
        if devices and devices > 1:
            scen["grid_sharded"] = _grid_sweep(pkg, min(cycles, GRID_CYCLES),
                                               tlb_backend, devices)
    return scen


def _grid_sweep(pkg: str, cycles: int, tlb_backend: str = "xla",
                devices: int = 0):
    """The paper's 8-design ablation sweep over GRID_N_MIXES pairs:
    (zero-arg call, sim-steps). The call compiles lazily on first use,
    so timing a FRESH `cycles` value measures the sweep end-to-end
    (compile + execute) — the compare harness exploits this.

    On grid-capable trees: one vmapped execution per signature group.
    On older trees: the per-design loop (one vmapped mix batch per
    design) — the honest pre-vectorization sweep shape. Both run the
    identical designs x mixes work. `devices > 1` shards each group's
    rows over that many devices (runner `_row_sharding`/`_pad_rows`;
    requires a sharding-capable tree)."""
    import jax.numpy as jnp
    config_mod = importlib.import_module(pkg + ".sim.config")
    runner_mod = importlib.import_module(pkg + ".sim.runner")
    workloads_mod = importlib.import_module(pkg + ".sim.workloads")
    design_mod = importlib.import_module(pkg + ".core.design")
    mask_mod = importlib.import_module(pkg + ".core.mask")
    if devices and devices > 1 and not hasattr(runner_mod, "_row_sharding"):
        raise ValueError(f"{pkg} tree has no sharded grid support")

    names = list(mask_mod.ALL_DESIGNS)
    mixes = workloads_mod.pair_workloads()[:GRID_N_MIXES]
    steps = cycles * len(names) * len(mixes)
    pms = np.stack([runner_mod._mix_matrix(list(m)) for m in mixes])
    calls = []
    if hasattr(runner_mod, "_compiled_grid_run"):
        groups = {}
        for n in names:
            dd = design_mod.get_design(n)
            groups.setdefault(design_mod.static_signature(dd),
                              []).append(dd)
        for sig, gds in groups.items():
            ccfg = _mk_cfg(config_mod, n_apps=2, sim_cycles=cycles,
                           design=design_mod.canonical_design(sig),
                           tlb_backend=tlb_backend)
            dp_stack = jax.tree_util.tree_map(
                lambda *leaves: jnp.repeat(jnp.stack(leaves),
                                           len(mixes), axis=0),
                *[design_mod.design_params(dd) for dd in gds])
            pm_stack = jnp.asarray(np.tile(pms, (len(gds), 1, 1)))
            if devices and devices > 1:
                sharding = runner_mod._row_sharding(devices)
                (dp_stack, pm_stack), _ = runner_mod._pad_rows(
                    (dp_stack, pm_stack), devices)
                dp_stack, pm_stack = jax.device_put(
                    (dp_stack, pm_stack), sharding)
            fn = runner_mod._compiled_grid_run(ccfg)
            calls.append((fn, (dp_stack, pm_stack)))
    else:
        for n in names:
            cfg = _mk_cfg(config_mod, n_apps=2, sim_cycles=cycles,
                          design=design_mod.get_design(n),
                          tlb_backend=tlb_backend)
            calls.append((runner_mod._compiled_batch_run(cfg),
                          (jnp.asarray(pms),)))
    return (lambda: [jax.block_until_ready(fn(*args))
                     for fn, args in calls]), steps


# ---------------------------------------------------------------------------
# baseline materialization for --compare
# ---------------------------------------------------------------------------

def _materialize_baseline(ref: str) -> str:
    """Extract src/repro at `ref` into .bench_compare/<sha>/src/repro_base
    (imports rewritten), put it on sys.path, and return the resolved sha."""
    sha = subprocess.run(["git", "rev-parse", ref], cwd=REPO_ROOT,
                         capture_output=True, text=True,
                         check=True, timeout=GIT_TIMEOUT_S).stdout.strip()
    dest = COMPARE_DIR / sha[:12]
    pkg_dir = dest / "src" / "repro_base"
    if not pkg_dir.exists():
        # stage into a temp dir and rename into place only when fully
        # rewritten — a half-rewritten cached baseline would silently
        # import the CURRENT `repro` modules and fake a ~1.0x ratio
        shutil.rmtree(dest, ignore_errors=True)
        tmp = COMPARE_DIR / (dest.name + ".tmp")
        shutil.rmtree(tmp, ignore_errors=True)
        tar_bytes = subprocess.run(
            ["git", "archive", "--format=tar", sha, "src/repro"],
            cwd=REPO_ROOT, capture_output=True, check=True,
            timeout=GIT_TIMEOUT_S).stdout
        with tarfile.open(fileobj=BytesIO(tar_bytes)) as tf:
            try:
                tf.extractall(tmp, filter="data")
            except TypeError:            # Python < 3.12
                tf.extractall(tmp)
        (tmp / "src" / "repro").rename(tmp / "src" / "repro_base")
        for py in (tmp / "src" / "repro_base").rglob("*.py"):
            py.write_text(_IMPORT_RE.sub(r"\1repro_base", py.read_text()))
        tmp.rename(dest)
    path = str(dest / "src")
    if path not in sys.path:
        sys.path.insert(0, path)
    mod = importlib.import_module("repro_base.sim.runner")
    assert mod.__file__.startswith(str(dest)), mod.__file__
    return sha


def run_compare(ref: str, design: str = "mask", cycles: int = 8_000,
                rounds: int = 5, out_path: Path = OUT_PATH,
                keep_baseline: bool = False, tlb_backend: str = "xla",
                devices: int = 0) -> dict:
    """Interleaved A/B: current tree vs the committed tree at `ref`.

    Each round times (new, old) back-to-back per scenario; the headline
    number is the median over rounds of old_time / new_time (>1 means
    the working tree is faster).

    The warm scenarios (2app/4app/batch8) time pre-compiled execution.
    The `grid` scenario instead times the 8-design sweep END-TO-END —
    compile + execute, at a fresh cycle count every round so neither
    side can reuse a compiled program — because the sweep's real cost
    includes its XLA compiles (8 programs pre-vectorization, one per
    signature group after). With `devices > 1` a `grid_sharded` round
    rides along: the NEW side shards the sweep's rows over the devices,
    the OLD side runs its plain single-device sweep, both cold at a
    cycle count distinct from the `grid` round's (so neither side can
    reuse those compiles). The persistent compilation cache is disabled
    for the whole compare run for the same reason. The materialized
    baseline tree under `.bench_compare/` is removed on exit unless
    `keep_baseline` — guaranteed even on a crash: removal is registered
    with atexit BEFORE the baseline is materialized, so an unhandled
    exception (or plain sys.exit) anywhere in the run still cleans up;
    the `finally` below only makes it prompt."""
    if not keep_baseline:
        atexit.register(shutil.rmtree, COMPARE_DIR, ignore_errors=True)
    try:
        sha = _materialize_baseline(ref)
        jax.config.update("jax_compilation_cache_dir", None)
        print("# persistent compilation cache disabled for --compare "
              "(grid rounds time cold compiles)", flush=True)
        scen_new = _scenarios(design, cycles, "repro", include_grid=False,
                              tlb_backend=tlb_backend)
        scen_old = _scenarios(design, cycles, "repro_base",
                              include_grid=False)
        warm_names = list(scen_new)
        for name in warm_names:            # compile + warm both sides
            for tag, scen in (("new", scen_new), ("old", scen_old)):
                t0 = time.perf_counter()
                scen[name][0]()
                print(f"# warm {name}/{tag}: "
                      f"{time.perf_counter() - t0:.1f}s", flush=True)

        names = warm_names + ["grid"]
        if devices and devices > 1:
            names.append("grid_sharded")
        ratios = {name: [] for name in names}
        rates = {name: {"new": [], "old": []} for name in names}
        for r in range(rounds):
            for name in warm_names:
                call_new, steps = scen_new[name]
                call_old, _ = scen_old[name]
                t0 = time.perf_counter()
                call_new()
                t_new = time.perf_counter() - t0
                t0 = time.perf_counter()
                call_old()
                t_old = time.perf_counter() - t0
                ratios[name].append(t_old / t_new)
                rates[name]["new"].append(steps / t_new)
                rates[name]["old"].append(steps / t_old)
            # grid: cold end-to-end sweep, fresh cycles -> fresh compiles
            gc = min(cycles, GRID_CYCLES) + r + 1
            call_new, gsteps = _grid_sweep("repro", gc, tlb_backend)
            call_old, _ = _grid_sweep("repro_base", gc)
            t0 = time.perf_counter()
            call_new()
            t_new = time.perf_counter() - t0
            t0 = time.perf_counter()
            call_old()
            t_old = time.perf_counter() - t0
            ratios["grid"].append(t_old / t_new)
            rates["grid"]["new"].append(gsteps / t_new)
            rates["grid"]["old"].append(gsteps / t_old)
            print(f"# compare round {r + 1}/{rounds} done "
                  f"(grid cold: new {t_new:.1f}s old {t_old:.1f}s)",
                  flush=True)
            if devices and devices > 1:
                # sharded pair at a cycle count disjoint from the grid
                # round's range, so neither side reuses those compiles:
                # new = rows sharded over `devices`, old = the baseline
                # tree's single-device vmapped sweep
                gs = min(cycles, GRID_CYCLES) + 1_000 + r
                call_new, ssteps = _grid_sweep("repro", gs, tlb_backend,
                                               devices)
                call_old, _ = _grid_sweep("repro_base", gs)
                t0 = time.perf_counter()
                call_new()
                t_new = time.perf_counter() - t0
                t0 = time.perf_counter()
                call_old()
                t_old = time.perf_counter() - t0
                ratios["grid_sharded"].append(t_old / t_new)
                rates["grid_sharded"]["new"].append(ssteps / t_new)
                rates["grid_sharded"]["old"].append(ssteps / t_old)
                print(f"# compare round {r + 1}/{rounds} sharded "
                      f"(cold: new {t_new:.1f}s old {t_old:.1f}s)",
                      flush=True)

        result = _measure_report(design, cycles, rounds,
                                 {n: rates[n]["new"] for n in rates},
                                 tlb_backend=tlb_backend, devices=devices)
        result["compare"] = {
            "ref": ref,
            "sha": sha,
            "speedup": {n: float(np.median(v)) for n, v in ratios.items()},
            "ratio_samples": {n: [float(x) for x in v]
                              for n, v in ratios.items()},
            "baseline_steps_per_sec": {n: float(np.median(rates[n]["old"]))
                                       for n in rates},
            "baseline_signature_groups": _signature_groups("repro_base"),
            "grid_timing": "cold end-to-end sweep (compile + execute, "
                           "fresh cycle count per round)",
        }
        out_path.write_text(json.dumps(result, indent=2) + "\n")
        print(json.dumps(
            {"design": design, "cycles": cycles,
             "steps_per_sec": result["steps_per_sec"],
             "speedup_vs_" + sha[:8]: result["compare"]["speedup"]},
            indent=2))
        print(f"# wrote {out_path}")
        return result
    finally:
        if not keep_baseline:
            shutil.rmtree(COMPARE_DIR, ignore_errors=True)
            print(f"# removed {COMPARE_DIR} (use --keep-baseline to keep)",
                  flush=True)


def _measure_report(design, cycles, rounds, samples, tlb_backend="xla",
                    devices=0) -> dict:
    return {
        "design": design,
        "cycles": cycles,
        "rounds": rounds,
        "steps_per_sec": {n: float(np.median(v)) for n, v in samples.items()},
        "samples": {n: [float(x) for x in v] for n, v in samples.items()},
        "meta": {
            "jax": jax.__version__,
            "jax_version": jax.__version__,
            "platform": platform.platform(),
            "backend": jax.default_backend(),
            "device_kind": jax.devices()[0].device_kind,
            "device_count": jax.device_count(),
            "tlb_backend": tlb_backend,
            "devices": devices if devices and devices > 1 else 1,
            # compiled programs for the grid scenario's 8-design sweep
            "signature_groups": _signature_groups("repro"),
        },
    }


def run_bench(design: str = "mask", cycles: int = 8_000, rounds: int = 5,
              out_path: Path = OUT_PATH, tlb_backend: str = "xla",
              devices: int = 0) -> dict:
    scen = _scenarios(design, cycles, tlb_backend=tlb_backend,
                      devices=devices)
    for name, (call, _) in scen.items():   # compile + warm
        t0 = time.perf_counter()
        call()
        print(f"# warm {name}: {time.perf_counter() - t0:.1f}s", flush=True)

    samples = {name: [] for name in scen}
    for r in range(rounds):                # interleaved measurement
        for name, (call, steps) in scen.items():
            t0 = time.perf_counter()
            call()
            dt = time.perf_counter() - t0
            samples[name].append(steps / dt)
        print(f"# round {r + 1}/{rounds} done", flush=True)

    result = _measure_report(design, cycles, rounds, samples,
                             tlb_backend=tlb_backend, devices=devices)
    out_path.write_text(json.dumps(result, indent=2) + "\n")
    print(json.dumps({k: result[k] for k in ("design", "cycles",
                                             "steps_per_sec")}, indent=2))
    print(f"# wrote {out_path}")
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--design", default="mask")
    ap.add_argument("--cycles", type=int, default=8_000)
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--out", type=Path, default=OUT_PATH)
    ap.add_argument("--compare", metavar="GIT_REF", default=None,
                    help="interleave against the committed tree at GIT_REF "
                         "and report median new/old speedups")
    ap.add_argument("--keep-baseline", action="store_true",
                    help="keep the materialized .bench_compare/ baseline "
                         "tree after --compare (default: removed on exit)")
    ap.add_argument("--no-compile-cache", action="store_true",
                    help="disable the persistent JAX compilation cache "
                         "(default: cache compiles under .jax_cache/)")
    ap.add_argument("--devices", type=int, default=0,
                    help="shard the grid sweep's rows over N devices "
                         "(adds the grid_sharded scenario); on a CPU host "
                         "with fewer visible devices the benchmark "
                         "re-executes itself with "
                         "--xla_force_host_platform_device_count=N")
    ap.add_argument("--tlb-backend", default="xla",
                    choices=["xla", "pallas", "pallas-interpret"],
                    help="fused shared-round backend for the current tree "
                         "(baseline copies under --compare always run "
                         "their own default path)")
    args = ap.parse_args()
    if args.devices > 1 and jax.device_count() < args.devices:
        # the device-count flag must be set before the backend exists, so
        # re-exec into a child that sees the forced host devices
        env = dict(os.environ)
        env["XLA_FLAGS"] = (
            env.get("XLA_FLAGS", "") +
            f" --xla_force_host_platform_device_count={args.devices}"
        ).strip()
        print(f"# re-executing with {args.devices} forced host devices",
              flush=True)
        try:
            raise SystemExit(subprocess.call(
                [sys.executable, "-m", "benchmarks.perf", *sys.argv[1:]],
                env=env, cwd=REPO_ROOT, timeout=REEXEC_TIMEOUT_S))
        except subprocess.TimeoutExpired:
            # subprocess.call kills the child on expiry; surface it as
            # the conventional timeout exit code instead of hanging CI
            print(f"# re-executed benchmark exceeded {REEXEC_TIMEOUT_S}s "
                  "and was killed", file=sys.stderr, flush=True)
            raise SystemExit(124)
    if not args.no_compile_cache:
        enable_compilation_cache()
    if args.compare:
        run_compare(args.compare, args.design, args.cycles, args.rounds,
                    args.out, keep_baseline=args.keep_baseline,
                    tlb_backend=args.tlb_backend, devices=args.devices)
    else:
        run_bench(args.design, args.cycles, args.rounds, args.out,
                  tlb_backend=args.tlb_backend, devices=args.devices)


if __name__ == "__main__":
    main()
