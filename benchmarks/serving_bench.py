"""Serving fairness + overload benchmark -> BENCH_serving.json.

Drives seeded trace presets (repro.serving.stream) through the
multi-tenant engine under each placement policy and reports the
paper's fairness metrics at the serving layer:

  per-tenant slowdown  — shared mean latency / solo mean latency, the
                         solo run replaying the SAME seeded arrivals
                         restricted to that tenant (TraceSpec.only) —
                         the serving analogue of IPC_alone (paper §6)
  unfairness           — max per-tenant slowdown
  fairness error       — |predicted - achieved| / achieved, where the
                         prediction is the contention oracle's mean
                         predicted max-slowdown over its chosen
                         placements (only the "oracle" policy predicts;
                         recalibration feeds achieved slowdowns back,
                         so this error should SHRINK as the run ages)

plus TTFT, latency percentiles, SLO attainment (SLO = 3x the tenant's
solo mean latency), per-tenant throughput, per-rung degradation-ladder
attribution (how often each of normal/quota/preempt/freeze/safe_* fired
and why — `repro.serving.metrics.rung_counts`), preemption/recalibration
accounting, and a request-conservation audit.

The engine runs with admission DECOUPLED from decode capacity
(`max_running > max_batch`): up to `max_running` requests hold KV slots
while `max_batch` decode per step, which is what gives decode-quota
shaping and preemption purchase on saturating traces.

The overload section replays a seeded `ServingFaultPlan` (pool-
exhaustion spike + poisoned profile + oracle stall) on a small pool and
asserts the robustness laws: zero requests lost or duplicated, the
safe-mode fallback engages AND recovers, and the whole run is
bit-for-bit deterministic (two fresh engines, identical fingerprints).

Token compute is stubbed (`ServingEngine(forwards=stub_forwards())`):
latencies are measured in ENGINE STEPS, so the benchmark isolates
scheduling/admission behavior — which is what the policies differ on —
and stays fast enough for CI.

Run:            PYTHONPATH=src python benchmarks/serving_bench.py
Smoke:          PYTHONPATH=src python benchmarks/serving_bench.py --smoke
Overload smoke: PYTHONPATH=src python benchmarks/serving_bench.py \
                    --overload-smoke
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.memmgr import kv_cache as kvc                      # noqa: E402
from repro.serving import metrics as smet                     # noqa: E402
from repro.serving import stream as strm                      # noqa: E402
from repro.serving.engine import (EngineConfig, ServingEngine,  # noqa: E402
                                  stub_forwards, stub_model_config)
from repro.serving.oracle import (ContentionOracle,           # noqa: E402
                                  Recalibrator)
from repro.serving.placement import POLICIES, make_policy     # noqa: E402
from repro.sim.faults import (ServingFault,                   # noqa: E402
                              ServingFaultPlan)

POOL = kvc.PoolConfig(n_pages=256, page_size=8, n_kv=1, head_dim=4,
                      n_layers=1, max_seqs=16, pages_per_seq=8)
# deliberately tight pool for the overload/fault runs: a spike can
# actually exhaust it, so every ladder rung is reachable
OVERLOAD_POOL = kvc.PoolConfig(n_pages=64, page_size=8, n_kv=1, head_dim=4,
                               n_layers=1, max_seqs=16, pages_per_seq=8)
MAX_BATCH = 8
MAX_RUNNING = 12     # admission decoupled from decode capacity


def _oracle_for(trace: strm.TraceSpec, cycles: int) -> ContentionOracle:
    slots = min(max(len(trace.specs), 2), 4)
    return ContentionOracle(cycles=cycles, slots=slots, pad_rows=8)


def run_trace(trace: strm.TraceSpec, policy, solo_hint=None,
              drain_steps: int = 1200, pool: kvc.PoolConfig = POOL,
              fault_plan: ServingFaultPlan = None) -> ServingEngine:
    cfg = stub_model_config()
    eng = ServingEngine(cfg, None, None, pool,
                        EngineConfig(max_batch=MAX_BATCH,
                                     max_running=MAX_RUNNING,
                                     fault_plan=fault_plan),
                        placement=policy, profiles=trace.profiles(),
                        forwards=stub_forwards(), solo_hint=solo_hint)
    strm.drive(eng, trace, drain_steps=drain_steps)
    return eng


def solo_baselines(trace: strm.TraceSpec, pool: kvc.PoolConfig = POOL):
    solo_lat = {}
    for spec in trace.specs:
        e = run_trace(trace.only(spec.tenant), make_policy("none"),
                      pool=pool)
        solo_lat.update(smet.tenant_mean_latency(e.finished))
    return solo_lat


def bench_trace(trace: strm.TraceSpec, policies, cycles: int,
                epoch_steps: int, unfairness_cap: float):
    # solo baselines: same seeded arrivals, one tenant at a time
    solo_lat = solo_baselines(trace)
    out = {"steps": trace.steps, "seed": trace.seed,
           "tenants": {s.tenant: s.profile for s in trace.specs},
           "solo_mean_latency": {t: round(v, 3)
                                 for t, v in sorted(solo_lat.items())},
           "policies": {}}
    for pol in policies:
        oracle = _oracle_for(trace, cycles) if pol == "oracle" else None
        policy = make_policy(pol, profiles=trace.profiles(), oracle=oracle,
                             epoch_steps=epoch_steps,
                             **({"unfairness_cap": unfairness_cap}
                                if pol == "oracle" else {}))
        eng = run_trace(trace, policy, solo_hint=solo_lat)
        rep = smet.fairness_report(eng.finished, solo_lat, eng.decisions)
        slo = {t: 3.0 * solo_lat[t] for t in solo_lat}
        rec = {
            "finished": len(eng.finished),
            "engine_steps": eng.step_count,
            "tenant_slowdown": {t: round(v, 4)
                                for t, v in rep["tenant_slowdown"].items()},
            "unfairness": round(rep["unfairness"], 4),
            "predicted_max_slowdown": rep["predicted_max_slowdown"],
            "fairness_error": rep["fairness_error"],
            "starved_tenants": rep["starved_tenants"],
            "tenant_mean_latency": {
                t: round(v, 3)
                for t, v in sorted(smet.tenant_mean_latency(
                    eng.finished).items())},
            "tenant_ttft": {t: round(v, 3)
                            for t, v in sorted(smet.tenant_ttft(
                                eng.finished).items())},
            "latency_percentiles": smet.latency_percentiles(eng.finished),
            "slo_attainment": {
                t: round(sum(1 for r in eng.finished if r.tenant == t
                             and r.finish_step - r.submit_step <= slo[t])
                         / max(sum(1 for r in eng.finished
                                   if r.tenant == t), 1), 4)
                for t in sorted(solo_lat)},
            "tenant_throughput": {
                t: round(v, 4)
                for t, v in sorted(smet.tenant_throughput(
                    eng.finished, eng.step_count).items())},
            "decisions": smet.decision_summary(eng.decisions),
            "overload": smet.overload_summary(eng),
            "conservation": smet.conservation_report(eng),
        }
        if oracle is not None:
            rec["oracle"] = {"grid_calls": oracle.grid_calls,
                             "memo_size": oracle.memo_size,
                             "sim_failures": len(oracle.failures)}
        out["policies"][pol] = rec
        print(f"  {trace.name:<18} {pol:<7} unfair "
              f"{rec['unfairness']:<7} rungs "
              f"{rec['decisions']['rungs']} preempt "
              f"{rec['overload']['preemptions']}", flush=True)
    return out


# ------------------------------------------------------------- overload

def overload_plan(seed: int) -> ServingFaultPlan:
    """The acceptance scenario: an oracle stall, then a pool-exhaustion
    spike, then a poisoned profile — every rung of the ladder plus the
    safe-mode state machine, in one seeded plan."""
    return ServingFaultPlan(seed=seed, faults=(
        ServingFault("oracle_stall", step=16, duration=8),
        ServingFault("profile_poison", step=36, duration=36,
                     tenant=0, profile="interactive"),
        ServingFault("pool_spike", step=40, duration=32,
                     pages=OVERLOAD_POOL.n_pages),
    ))


def _fingerprint(eng: ServingEngine):
    """Bit-for-bit replay evidence: the full externally-visible history
    of one run."""
    return (
        tuple((r.rid, r.tenant, r.submit_step, r.first_token_step,
               r.finish_step, r.retries, r.wasted_tokens, len(r.out))
              for r in sorted(eng.finished, key=lambda r: r.rid)),
        tuple((d.step, d.rung, d.allowed, tuple(sorted(d.caps.items())),
               tuple(sorted(d.decode_quota.items())),
               tuple(sorted(d.preempt.items())))
              for d in eng.decisions),
        tuple(eng.preempt_log),
        tuple(eng.fault_log),
        tuple(getattr(eng.placement, "mode_log", [])),
    )


def overload_run(seed: int, cycles: int, epoch_steps: int,
                 policy_name: str = "oracle"):
    trace = strm.make_trace("flood_vs_trickle", seed=seed, steps=240)
    solo_lat = solo_baselines(trace, pool=OVERLOAD_POOL)
    plan = overload_plan(seed)

    def build():
        oracle = (_oracle_for(trace, cycles)
                  if policy_name == "oracle" else None)
        kw = {}
        if policy_name == "oracle":
            # sensitive safe-mode thresholds + a fast recalibrator: the
            # poisoned-profile window must demonstrably degrade AND
            # re-engage inside the run
            kw = {"degrade_error": 0.4, "reengage_error": 0.28,
                  "error_window": 2,
                  "recalibrator": Recalibrator(alpha=0.5)}
        policy = make_policy(policy_name, profiles=trace.profiles(),
                             oracle=oracle, epoch_steps=epoch_steps, **kw)
        return run_trace(trace, policy, solo_hint=solo_lat,
                         pool=OVERLOAD_POOL, fault_plan=plan,
                         drain_steps=2000)

    eng = build()
    cons = smet.conservation_report(eng)
    over = smet.overload_summary(eng)
    rep = smet.fairness_report(eng.finished, solo_lat, eng.decisions)
    fp_a = _fingerprint(eng)
    fp_b = _fingerprint(build())
    modes = [lvl for _, lvl, _ in over["safe_mode_log"]]
    engaged = any(lvl > 0 for lvl in modes)
    recovered = (engaged and over["safe_level_final"] <
                 max(modes)) if modes else False
    return {
        "trace": trace.name,
        "steps": trace.steps,
        "plan": [(f.kind, f.step, f.duration, f.tenant)
                 for f in plan.faults],
        "unfairness": round(rep["unfairness"], 4),
        "conservation": cons,
        "overload": over,
        "rungs": smet.rung_counts(eng.decisions),
        "deterministic": fp_a == fp_b,
        "safe_mode_engaged": engaged,
        "safe_mode_recovered": recovered,
    }


def overload_smoke(seed: int, cycles: int, epoch_steps: int) -> int:
    """CI gate: a saturating trace with injected pool-exhaustion faults.
    Asserts (a) zero lost/duplicated requests and (b) the protective
    policy's unfairness <= admit-all's under the SAME faults."""
    trace = strm.make_trace("flood_vs_trickle", seed=seed, steps=96)
    solo_lat = solo_baselines(trace, pool=OVERLOAD_POOL)
    plan = ServingFaultPlan(seed=seed, faults=(
        ServingFault("pool_spike", step=20, duration=24,
                     pages=OVERLOAD_POOL.n_pages),
        ServingFault("pool_spike", step=60, duration=16,
                     pages=OVERLOAD_POOL.n_pages // 2),
    ))
    unfair, ok = {}, True
    for pol in ("none", "oracle"):
        oracle = _oracle_for(trace, cycles) if pol == "oracle" else None
        policy = make_policy(pol, profiles=trace.profiles(), oracle=oracle,
                             epoch_steps=epoch_steps)
        eng = run_trace(trace, policy, solo_hint=solo_lat,
                        pool=OVERLOAD_POOL, fault_plan=plan,
                        drain_steps=2000)
        cons = smet.conservation_report(eng)
        rep = smet.fairness_report(eng.finished, solo_lat, eng.decisions)
        unfair[pol] = rep["unfairness"]
        print(f"overload-smoke {pol:<7} unfair {rep['unfairness']:.4f} "
              f"lost {cons['lost']} dup {cons['duplicated']} "
              f"preempt {eng.preemptions} "
              f"rungs {smet.rung_counts(eng.decisions)}", flush=True)
        if not cons["ok"]:
            print(f"FAIL: {pol} lost/duplicated requests: {cons}")
            ok = False
    if unfair["oracle"] > unfair["none"] + 1e-9:
        print(f"FAIL: protective unfairness {unfair['oracle']:.4f} > "
              f"admit-all {unfair['none']:.4f}")
        ok = False
    print(f"overload-smoke: {'PASS' if ok else 'FAIL'}")
    return 0 if ok else 1


PR8_FLOOD_FAIRNESS_ERROR = 0.17744839002002596  # uncorrected baseline


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_serving.json"))
    ap.add_argument("--traces", nargs="*",
                    default=["flood_vs_trickle", "churn", "heavy_tail",
                             "many_tenants"])
    ap.add_argument("--policies", nargs="*", default=list(POLICIES))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--steps", type=int, default=None,
                    help="override every trace's step count")
    ap.add_argument("--cycles", type=int, default=600,
                    help="simulator cycles per oracle prediction")
    ap.add_argument("--epoch-steps", type=int, default=8)
    ap.add_argument("--unfairness-cap", type=float, default=1.15)
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: one trace, short, fewer sim cycles")
    ap.add_argument("--overload-smoke", action="store_true",
                    help="CI mode: saturating trace + pool-exhaustion "
                         "faults; exit nonzero on lost requests or "
                         "protective unfairness > admit-all")
    ap.add_argument("--no-overload", action="store_true",
                    help="skip the overload fault-plan section")
    args = ap.parse_args()
    if args.overload_smoke:
        sys.exit(overload_smoke(args.seed, min(args.cycles, 300),
                                args.epoch_steps))
    if args.smoke:
        args.traces = ["flood_vs_trickle"]
        args.cycles = min(args.cycles, 300)
        args.no_overload = True

    results = {"seed": args.seed, "cycles": args.cycles,
               "epoch_steps": args.epoch_steps,
               "unfairness_cap": args.unfairness_cap,
               "max_batch": MAX_BATCH, "max_running": MAX_RUNNING,
               "policies": list(args.policies), "traces": {}}
    for name in args.traces:
        trace = strm.make_trace(name, seed=args.seed, steps=args.steps)
        print(f"{name} (steps={trace.steps}, seed={trace.seed}, "
              f"tenants={len(trace.specs)}):", flush=True)
        results["traces"][name] = bench_trace(
            trace, args.policies, args.cycles, args.epoch_steps,
            args.unfairness_cap)

    if not args.no_overload:
        print("overload fault-plan run:", flush=True)
        results["overload"] = overload_run(args.seed, min(args.cycles, 300),
                                           args.epoch_steps)
        o = results["overload"]
        print(f"  lost {o['conservation']['lost']} "
              f"dup {o['conservation']['duplicated']} "
              f"safe-mode engaged={o['safe_mode_engaged']} "
              f"recovered={o['safe_mode_recovered']} "
              f"deterministic={o['deterministic']}", flush=True)

    checks = {}
    tr = results["traces"]
    fv = tr.get("flood_vs_trickle", {}).get("policies", {})
    if "oracle" in fv and "none" in fv:
        checks["oracle_beats_none_flood_vs_trickle"] = bool(
            fv["oracle"]["unfairness"] < fv["none"]["unfairness"])
        err = fv["oracle"]["fairness_error"]
        checks["flood_fairness_error_improved_vs_pr8"] = bool(
            err is not None and err < PR8_FLOOD_FAIRNESS_ERROR)
    wins = 0
    presets3 = [n for n in ("flood_vs_trickle", "churn", "heavy_tail")
                if n in tr]
    for name in presets3:
        pols = tr[name]["policies"]
        if "oracle" in pols and "none" in pols and \
                pols["oracle"]["unfairness"] <= pols["none"]["unfairness"] \
                + 1e-9:
            wins += 1
    if presets3:
        checks["protective_leq_none_on_2_of_3"] = bool(
            wins >= min(2, len(presets3)))
    conserved = all(
        rec["conservation"]["ok"]
        for t in tr.values() for rec in t["policies"].values())
    checks["zero_lost_or_duplicated"] = bool(conserved)
    if "overload" in results:
        o = results["overload"]
        checks["overload_zero_lost"] = bool(o["conservation"]["ok"])
        checks["overload_safe_mode_engaged_and_recovered"] = bool(
            o["safe_mode_engaged"] and o["safe_mode_recovered"])
        checks["overload_deterministic"] = bool(o["deterministic"])
    results["checks"] = checks

    with open(args.out, "w") as f:
        json.dump(results, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {os.path.abspath(args.out)}")
    for k, v in checks.items():
        print(f"check {k}: {'PASS' if v else 'FAIL'}")
    if checks and not all(checks.values()):
        sys.exit(1)


if __name__ == "__main__":
    main()
