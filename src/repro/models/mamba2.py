"""Mamba2 (SSD — state-space duality) block, chunked scan + O(1) decode.

Training/prefill uses the chunked SSD algorithm [arXiv:2405.21060]:
intra-chunk quadratic part + inter-chunk state recurrence (lax.scan over
chunks). Decode is the O(1) recurrent update. The Pallas kernel
(repro.kernels.ssd_scan) implements the intra-chunk part for TPU; this
module is the XLA path and the oracle.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.params import Param


class SSMState(NamedTuple):
    h: jax.Array      # (B, nh, hd, d_state) fp32
    conv: jax.Array   # (B, conv_w - 1, conv_dim)


def mamba2_dims(cfg):
    d_in = cfg.ssm_expand * cfg.d_model
    nh = d_in // cfg.ssm_head_dim
    conv_dim = d_in + 2 * cfg.ssm_state
    return d_in, nh, conv_dim


def mamba2_params(cfg):
    d_in, nh, conv_dim = mamba2_dims(cfg)
    d = cfg.d_model
    return {
        "in_proj": Param((d, 2 * d_in + 2 * cfg.ssm_state + nh), ("embed", "ssm")),
        "conv_w": Param((cfg.ssm_conv_width, conv_dim), (None, "ssm")),
        "conv_b": Param((conv_dim,), ("ssm",), init="zeros"),
        "A_log": Param((nh,), (None,), dtype=jnp.float32, init="constant", const=0.0),
        "dt_bias": Param((nh,), (None,), dtype=jnp.float32, init="zeros"),
        "D": Param((nh,), (None,), dtype=jnp.float32, init="ones"),
        "norm_scale": Param((d_in,), ("ssm",), dtype=jnp.float32, init="ones"),
        "out_proj": Param((d_in, d), ("ssm", "embed")),
    }


def _split_proj(cfg, proj):
    d_in, nh, _ = mamba2_dims(cfg)
    zs = d_in
    xs = d_in
    bs = cfg.ssm_state
    cs = cfg.ssm_state
    z, xbc, dt = jnp.split(proj, [zs, zs + xs + bs + cs], axis=-1)
    return z, xbc, dt  # dt: (..., nh)


def _causal_conv(xbc, conv_w, conv_b, prev=None):
    """Depthwise causal conv, width W. xbc: (B,S,C); prev: (B,W-1,C) or None."""
    W = conv_w.shape[0]
    if prev is None:
        prev = jnp.zeros((xbc.shape[0], W - 1, xbc.shape[2]), xbc.dtype)
    xp = jnp.concatenate([prev, xbc], axis=1)
    out = jnp.zeros_like(xbc, dtype=jnp.float32)
    for i in range(W):
        out = out + xp[:, i : i + xbc.shape[1]].astype(jnp.float32) * conv_w[i]
    out = out + conv_b
    new_prev = xp[:, xp.shape[1] - (W - 1):]
    return jax.nn.silu(out).astype(xbc.dtype), new_prev


def _segsum(x):
    """x: (..., Q) -> (..., Q, Q) lower-triangular inclusive-exclusive segment
    sums: out[..., i, j] = sum_{k=j+1..i} x[..., k]  (NEG_INF above diagonal)."""
    Q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    ii = jnp.arange(Q)
    mask = ii[:, None] >= ii[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, chunk: int, h0=None, constrain=None):
    """Chunked SSD scan.

    x: (b, S, nh, hd)   dt: (b, S, nh)   A: (nh,) negative
    B, C: (b, S, ds)    returns y: (b, S, nh, hd), h_final (b, nh, hd, ds)
    """
    cb = constrain if constrain is not None else (lambda a, ax: a)
    b, S, nh, hd = x.shape
    ds = B.shape[-1]
    chunk = min(chunk, S)
    while S % chunk:          # static shapes: pick the largest divisor
        chunk -= 1
    nc = S // chunk
    xf = (x * dt[..., None]).astype(jnp.float32)       # discretized input
    dA = (dt * A[None, None, :]).astype(jnp.float32)    # (b,S,nh), negative

    # reshape into chunks (heads sharded over model: the big (Q,Q) decay
    # matrices must never replicate across the model axis)
    xc = cb(xf.reshape(b, nc, chunk, nh, hd),
            ("batch", None, None, "heads", None))
    dAc = cb(dA.reshape(b, nc, chunk, nh), ("batch", None, None, "heads"))
    Bc = B.reshape(b, nc, chunk, ds).astype(jnp.float32)
    Cc = C.reshape(b, nc, chunk, ds).astype(jnp.float32)

    # ---- intra-chunk (quadratic within chunk) ----
    L = cb(jnp.exp(_segsum(jnp.moveaxis(dAc, -1, -2))),
           ("batch", None, "heads", None, None))           # (b,nc,nh,Q,Q)
    G = jnp.einsum("bnqd,bnsd->bnqs", Cc, Bc)             # (b,nc,Q,Q)
    M = G[:, :, None] * L                                  # (b,nc,nh,Q,Q)
    y_intra = cb(jnp.einsum("bnhqs,bnshd->bnqhd", M, xc),
                 ("batch", None, None, "heads", None))

    # ---- chunk states ----
    dA_cum = jnp.cumsum(dAc, axis=2)                       # (b,nc,Q,nh)
    decay_to_end = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)  # (b,nc,Q,nh)
    S_chunk = jnp.einsum("bnsd,bnsh,bnshp->bnhpd", Bc, decay_to_end, xc)
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])             # (b,nc,nh)

    # ---- inter-chunk recurrence (scan over chunks) ----
    if h0 is None:
        h0 = jnp.zeros((b, nh, hd, ds), jnp.float32)

    def step(h, inp):
        s_c, decay_c = inp                                  # (b,nh,hd,ds), (b,nh)
        h_out = h                                            # state entering chunk
        h_new = h * decay_c[..., None, None] + s_c
        return h_new, h_out

    sc_t = jnp.moveaxis(S_chunk, 1, 0)                      # (nc,b,nh,hd,ds)
    dc_t = jnp.moveaxis(chunk_decay, 1, 0)                  # (nc,b,nh)
    h_final, h_enter = jax.lax.scan(step, h0, (sc_t, dc_t))
    h_enter = jnp.moveaxis(h_enter, 0, 1)                   # (b,nc,nh,hd,ds)

    # ---- inter-chunk contribution ----
    decay_from_start = jnp.exp(dA_cum)                      # (b,nc,Q,nh)
    y_inter = jnp.einsum("bnqd,bnqh,bnhpd->bnqhp",
                         Cc, decay_from_start, h_enter)

    y = (y_intra + y_inter).reshape(b, S, nh, hd)
    return y, h_final


def mamba2_forward(params, cfg, x, state: SSMState = None, constrain=None):
    """Full block (prefill/train). x: (B,S,d). Returns (y, new_state)."""
    cb = constrain if constrain is not None else (lambda a, ax: a)
    d_in, nh, conv_dim = mamba2_dims(cfg)
    proj = cb(jnp.einsum("bsd,dp->bsp", x, params["in_proj"]),
              ("batch", None, "ssm"))
    z, xbc, dt = _split_proj(cfg, proj)
    prev = state.conv if state is not None else None
    xbc, conv_state = _causal_conv(xbc, params["conv_w"], params["conv_b"], prev)
    xs, B, C = jnp.split(xbc, [d_in, d_in + cfg.ssm_state], axis=-1)
    xs = xs.reshape(*xs.shape[:2], nh, cfg.ssm_head_dim)
    dtp = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    h0 = state.h if state is not None else None
    y, h = ssd_chunked(xs, dtp, A, B, C, cfg.ssm_chunk, h0,
                       constrain=constrain)
    y = y + params["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(*y.shape[:2], d_in)
    # gated RMSNorm (mamba2 norm-before-out_proj)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + 1e-5) * params["norm_scale"]
    out = jnp.einsum("bsp,pd->bsd", y.astype(x.dtype), params["out_proj"])
    return out, SSMState(h=h, conv=conv_state)


def mamba2_decode(params, cfg, x, state: SSMState):
    """O(1) single-token update. x: (B,1,d)."""
    d_in, nh, conv_dim = mamba2_dims(cfg)
    proj = jnp.einsum("bsd,dp->bsp", x, params["in_proj"])
    z, xbc, dt = _split_proj(cfg, proj)
    # conv ring update
    xp = jnp.concatenate([state.conv, xbc], axis=1)         # (B, W, C)
    out = jnp.einsum("bwc,wc->bc", xp.astype(jnp.float32), params["conv_w"])
    out = jax.nn.silu(out + params["conv_b"])[:, None, :].astype(x.dtype)
    conv_state = xp[:, 1:]
    xs, B, C = jnp.split(out, [d_in, d_in + cfg.ssm_state], axis=-1)
    xs = xs.reshape(xs.shape[0], nh, cfg.ssm_head_dim)       # (B,nh,hd)
    dtp = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])  # (B,nh)
    A = -jnp.exp(params["A_log"])
    decay = jnp.exp(dtp * A[None, :])                        # (B,nh)
    Bv = B[:, 0].astype(jnp.float32)                         # (B,ds)
    Cv = C[:, 0].astype(jnp.float32)
    xin = (xs.astype(jnp.float32) * dtp[..., None])          # (B,nh,hd)
    h = state.h * decay[..., None, None] + jnp.einsum("bhp,bd->bhpd", xin, Bv)
    y = jnp.einsum("bhpd,bd->bhp", h, Cv)
    y = y + params["D"][None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(y.shape[0], 1, d_in)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + 1e-5) * params["norm_scale"]
    out = jnp.einsum("bsp,pd->bsd", y.astype(x.dtype), params["out_proj"])
    return out, SSMState(h=h, conv=conv_state)


def ssm_state_specs(cfg, batch: int):
    d_in, nh, conv_dim = mamba2_dims(cfg)
    return SSMState(
        h=jax.ShapeDtypeStruct((batch, nh, cfg.ssm_head_dim, cfg.ssm_state),
                               jnp.float32),
        conv=jax.ShapeDtypeStruct((batch, cfg.ssm_conv_width - 1, conv_dim),
                                  jnp.bfloat16),
    )
