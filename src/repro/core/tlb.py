"""Set-associative, ASID-tagged TLBs as pure-JAX state (batched probe/fill).

One structure covers the paper's three translation caches:

  * per-core L1 TLB  — 64-entry fully associative (n_sets=1), LRU
  * shared L2 TLB    — 512-entry 16-way, ASID-tagged, LRU
  * bypass cache     — 32-entry fully associative (MASK §5.2)

State is a NamedTuple of arrays so a bank of TLBs (one per core) is just a
leading axis + vmap — `init_bank` / `probe_bank` / `fill_bank` package that
pattern for the simulator's per-core L1 TLBs. Fills are batched; when
several requests map to the same set in one step, one fill wins per set
(ports/fill-bandwidth model — the paper's L2 TLB has 2 ports per memory
partition).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class TLBState(NamedTuple):
    tags: jax.Array      # (sets, ways) int32 vpn  (-1 invalid)
    asids: jax.Array     # (sets, ways) int32
    lru: jax.Array       # (sets, ways) int32 last-use time
    hits: jax.Array      # () int32 cumulative
    misses: jax.Array    # () int32


def init(n_entries: int, n_ways: int) -> TLBState:
    n_sets = max(n_entries // n_ways, 1)
    shape = (n_sets, n_ways)
    return TLBState(
        tags=jnp.full(shape, -1, jnp.int32),
        asids=jnp.full(shape, -1, jnp.int32),
        lru=jnp.zeros(shape, jnp.int32),
        hits=jnp.zeros((), jnp.int32),
        misses=jnp.zeros((), jnp.int32),
    )


def probe(state: TLBState, vpn, asid, active, time) -> Tuple[TLBState, jax.Array]:
    """Batched probe. vpn/asid/active: (N,). Returns (state', hit (N,) bool).

    LRU is updated for hits; hit/miss counters accumulate only active lanes.
    """
    n_sets, n_ways = state.tags.shape
    set_ix = jnp.where(n_sets > 1, vpn % n_sets, 0).astype(jnp.int32)
    t = state.tags[set_ix]                       # (N, ways)
    a = state.asids[set_ix]
    match = (t == vpn[:, None]) & (a == asid[:, None])
    hit = match.any(axis=1) & active
    way = jnp.argmax(match, axis=1)

    # LRU touch for hits only: non-hit lanes are routed out of bounds and
    # dropped, so they can never scatter a stale value over a hit's touch
    touch_set = jnp.where(hit, set_ix, n_sets)
    lru = state.lru.at[touch_set, way].set(time, mode="drop")
    hits = state.hits + hit.sum(dtype=jnp.int32)
    misses = state.misses + (active & ~hit).sum(dtype=jnp.int32)
    return state._replace(lru=lru, hits=hits, misses=misses), hit


def fill(state: TLBState, vpn, asid, do_fill, time) -> TLBState:
    """Batched fill with LRU victim selection. do_fill: (N,) bool.

    One fill per set per call (first lane wins) — models fill-port limits.
    """
    n_sets, n_ways = state.tags.shape
    set_ix = jnp.where(n_sets > 1, vpn % n_sets, 0).astype(jnp.int32)

    # first-wins per set: lane i is masked out if an earlier lane fills the
    # same set
    order = jnp.arange(vpn.shape[0])
    same_earlier = (set_ix[None, :] == set_ix[:, None]) & \
        (order[None, :] < order[:, None]) & do_fill[None, :]
    do_fill = do_fill & ~same_earlier.any(axis=1)

    victim = jnp.argmin(state.lru[set_ix], axis=1)       # (N,)
    # masked lanes are routed out of bounds and dropped — a plain masked
    # scatter would write the stale old value back and could clobber the
    # winning lane's fill on duplicate sets
    fill_set = jnp.where(do_fill, set_ix, n_sets)
    tags = state.tags.at[fill_set, victim].set(vpn, mode="drop")
    asids = state.asids.at[fill_set, victim].set(asid, mode="drop")
    lru = state.lru.at[fill_set, victim].set(time, mode="drop")
    return state._replace(tags=tags, asids=asids, lru=lru)


def init_bank(n_banks: int, n_entries: int, n_ways: int) -> TLBState:
    """A bank of identical TLBs: one TLBState with leading axis (n_banks,)."""
    single = init(n_entries, n_ways)
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (n_banks,) + x.shape), single)


def probe_bank(state: TLBState, vpn, asid, active, time
               ) -> Tuple[TLBState, jax.Array]:
    """Probe a bank of TLBs, one request per bank. vpn/asid/active: (B,).

    Direct (B, sets, ways) indexing — bit-for-bit equal to vmapping the
    general N-lane `probe` at N=1, without paying its per-lane dedup and
    set-gather machinery (this is the simulator's per-cycle L1 path).
    """
    B, n_sets, n_ways = state.tags.shape
    set_ix = (vpn % n_sets if n_sets > 1
              else jnp.zeros_like(vpn)).astype(jnp.int32)
    b = jnp.arange(B)
    t = state.tags[b, set_ix]                    # (B, ways)
    a = state.asids[b, set_ix]
    match = (t == vpn[:, None]) & (a == asid[:, None])
    hit = match.any(axis=1) & active
    way = jnp.argmax(match, axis=1)
    touch_set = jnp.where(hit, set_ix, n_sets)   # miss lanes dropped
    lru = state.lru.at[b, touch_set, way].set(time, mode="drop")
    hits = state.hits + hit.astype(jnp.int32)
    misses = state.misses + (active & ~hit).astype(jnp.int32)
    return state._replace(lru=lru, hits=hits, misses=misses), hit


def fill_bank(state: TLBState, vpn, asid, do_fill, time) -> TLBState:
    """Fill a bank of TLBs, one request per bank. vpn/asid/do_fill: (B,).

    Direct indexing (see `probe_bank`); one request per bank means the
    per-set fill port is trivially satisfied. Masked lanes are routed out
    of bounds and dropped (no stale write-back, same as `fill`).
    """
    B, n_sets, n_ways = state.tags.shape
    set_ix = (vpn % n_sets if n_sets > 1
              else jnp.zeros_like(vpn)).astype(jnp.int32)
    b = jnp.arange(B)
    victim = jnp.argmin(state.lru[b, set_ix], axis=1)    # (B,)
    fill_set = jnp.where(do_fill, set_ix, n_sets)
    tags = state.tags.at[b, fill_set, victim].set(vpn, mode="drop")
    asids = state.asids.at[b, fill_set, victim].set(asid, mode="drop")
    lru = state.lru.at[b, fill_set, victim].set(time, mode="drop")
    return state._replace(tags=tags, asids=asids, lru=lru)


def access_fused(state: TLBState, vpn, asid, active, may_fill, time,
                 n_waves: int = 1, track_asids: bool = True,
                 backend: str = "xla",
                 ) -> Tuple[TLBState, jax.Array, jax.Array]:
    """One-call probe+fill for a whole cycle's sub-accesses ("waves").

    The simulator's shared L2 data cache used to be accessed by 8 dependent
    probe/fill/DRAM rounds per cycle (4 page-walk levels + 4 divergent data
    lines). This kernel services all of them in one batch: the lanes are
    `n_waves` contiguous equal groups ("waves", the old rounds in order),
    and the cross-wave semantics that matter are kept:

      * fill port: one fill per set per WAVE — the first fill candidate
        (active & miss & may_fill) of a set within a wave wins, matching
        `fill`'s first-wins port model per round;
      * duplicate suppression: a lane whose line was already a fill
        candidate in an earlier wave of the same flat position's group
        (e.g. the same core's earlier sub-access) does not fill again;
      * forwarding: fills are applied before the final hit resolution, so
        a lane whose line was filled this cycle — by another wave, or by
        the lane that beat it to its own wave's port (MSHR-merge-like) —
        observes the fill and hits instead of going to DRAM;
      * victims chain like sequential LRU: the k-th winning wave in a set
        takes the k-th least-recently-used way (stable (lru, way) order).

    Everything is O(N·ways²) gathers/scatters and small per-wave blocks —
    deliberately NO (N, N) lane matrices and no sort: on XLA CPU those
    dominated the entire cycle (argsort of the LRU rows alone cost more
    than the eight sequential rounds it replaced).

    Known deviations from running the waves sequentially: victim choice
    uses start-of-cycle LRU (a way probe-hit this cycle can be evicted by
    a same-cycle fill of its set), forwarding is resolved from the final
    filled state (a later wave's fill can forward to an earlier wave when
    the earlier lane was fill-blocked, e.g. bypassed), and duplicate
    fills are suppressed per flat position group (same core), not
    globally — cross-core same-line duplicate fills in different waves
    leave a transient duplicate tag (hits still resolve to the first
    way). A set also accepts at most n_ways fills per cycle (relevant
    only when n_waves > n_ways): overflow winners go to DRAM unfilled.

    vpn/asid/active/may_fill: (N,) with N divisible by n_waves.
    `track_asids=False` skips the ASID plane entirely (tag-only caches
    like the line-addressed L2$, whose tags are already unique).
    Returns (state', hit (N,) bool, filled (N,) bool).

    `backend` selects the implementation of the round itself:
    "xla" (default) is the inline jnp path below; "pallas" lowers the
    `kernels/fused_tlb` Pallas kernel (TPU/GPU — raises elsewhere, no
    silent fallback); "pallas-interpret" runs the same kernel through the
    Pallas interpreter on any platform. The counter arithmetic is shared,
    and the kernel mirrors this function op for op, so all backends are
    bit-for-bit identical — `sim/config.py::SimConfig.tlb_backend`
    resolves the knob (env `REPRO_TLB_BACKEND`) and threads it here.
    """
    if backend not in (None, "xla"):
        # lazy import: the Pallas machinery stays off the default path
        from repro.kernels.fused_tlb.ops import fused_tlb_access
        tags, asids, lru, hit_i, filled_i = fused_tlb_access(
            state.tags, state.asids, state.lru, vpn,
            jnp.asarray(asid, jnp.int32), active, may_fill, time,
            n_waves=n_waves, track_asids=track_asids,
            interpret=True if backend == "pallas-interpret" else None)
        hit = hit_i != 0
        filled = filled_i != 0
        hits = state.hits + hit.sum(dtype=jnp.int32)
        misses = state.misses + (active & ~hit).sum(dtype=jnp.int32)
        return (state._replace(tags=tags, asids=asids, lru=lru,
                               hits=hits, misses=misses), hit, filled)
    n_sets, n_ways = state.tags.shape
    N = vpn.shape[0]
    W = n_waves
    C = N // W
    set_ix = (vpn % n_sets if n_sets > 1
              else jnp.zeros_like(vpn)).astype(jnp.int32)
    rows_t = state.tags[set_ix]                  # (N, ways)
    match = rows_t == vpn[:, None]
    if track_asids:
        match = match & (state.asids[set_ix] == asid[:, None])
    pre_hit = match.any(axis=1) & active
    way = jnp.argmax(match, axis=1)

    # ---- fill candidates --------------------------------------------------
    cand = active & ~pre_hit & may_fill
    if W > 1:
        # duplicate suppression per flat position (core): an earlier-wave
        # candidate with the same line makes later waves forward, not fill
        lines_wc = vpn.reshape(W, C)
        cand_wc = cand.reshape(W, C)
        tri_w = jnp.arange(W)[:, None, None] < jnp.arange(W)[None, :, None]
        dup = ((lines_wc[:, None, :] == lines_wc[None, :, :])
               & tri_w & cand_wc[:, None, :]).any(0).reshape(N)
        cand = cand & ~dup

    # ---- per-(set, wave) fill port via a scratch table --------------------
    # first candidate per (set, wave) wins; the occupied slots also give
    # every lane its same-set earlier-wave winner count (the LRU rank)
    wave = jnp.repeat(jnp.arange(W, dtype=jnp.int32), C)
    order = jnp.arange(N, dtype=jnp.int32)
    key = set_ix * W + wave
    scratch = jnp.full((n_sets * W,), jnp.int32(N), jnp.int32)
    scratch = scratch.at[jnp.where(cand, key, n_sets * W)].min(
        order, mode="drop")
    winner = cand & (scratch[key] == order)
    filled_sw = (scratch.reshape(n_sets, W) < N)[set_ix]        # (N, W)
    earlier_w = jnp.arange(W)[None, :] < wave[:, None]          # (N, W)
    rank = (filled_sw & earlier_w).sum(1)
    # a set holds at most n_ways fills per cycle: with more winning waves
    # than ways (only possible when n_waves > n_ways) the overflow lanes
    # lose their fill (straight to DRAM) instead of silently colliding on
    # the last victim way
    winner = winner & (rank < n_ways)

    # ---- victim = rank-th least-recently-used way -------------------------
    # pairwise (N, ways, ways) stable rank; XLA CPU sort is far slower
    lru_rows = state.lru[set_ix]                 # (N, ways)
    widx = jnp.arange(n_ways)
    lru_less = (lru_rows[:, None, :] < lru_rows[:, :, None]) | \
        ((lru_rows[:, None, :] == lru_rows[:, :, None])
         & (widx[None, None, :] < widx[None, :, None]))
    way_rank = lru_less.sum(-1)                  # (N, ways)
    victim = jnp.argmax(way_rank == jnp.minimum(rank, n_ways - 1)[:, None],
                        axis=1)

    # ---- one merged update pass per plane ---------------------------------
    # pre-hit lanes touch their way, winners fill their victim — both
    # write tag=vpn (a pre-hit lane's matched tag IS its vpn) and
    # lru=time, so each plane is ONE flat scatter; other lanes are routed
    # out of bounds and dropped
    flat = jnp.where(pre_hit, set_ix * n_ways + way,
                     jnp.where(winner, set_ix * n_ways + victim,
                               n_sets * n_ways))
    shape = state.tags.shape
    tags = state.tags.reshape(-1).at[flat].set(vpn, mode="drop").reshape(shape)
    lru = state.lru.reshape(-1).at[flat].set(time, mode="drop").reshape(shape)
    if track_asids:
        asids = state.asids.reshape(-1).at[flat].set(
            asid, mode="drop").reshape(shape)
    else:
        asids = state.asids

    # ---- final hit resolution (forwarding falls out of the fills) ---------
    post = tags[set_ix] == vpn[:, None]
    if track_asids:
        post = post & (asids[set_ix] == asid[:, None])
    hit = pre_hit | (active & ~winner & post.any(axis=1))
    hits = state.hits + hit.sum(dtype=jnp.int32)
    misses = state.misses + (active & ~hit).sum(dtype=jnp.int32)
    return (state._replace(tags=tags, asids=asids, lru=lru,
                           hits=hits, misses=misses), hit, winner)


def flush_asid(state: TLBState, asid: int) -> TLBState:
    """TLB shootdown for one address space (paper §5.1)."""
    kill = state.asids == asid
    return state._replace(
        tags=jnp.where(kill, -1, state.tags),
        asids=jnp.where(kill, -1, state.asids))


def occupancy_by_asid(state: TLBState, n_asids: int) -> jax.Array:
    """(n_asids,) live-entry counts — used by fairness diagnostics.

    One-hot sum over every entry axis; invalid entries (asid -1) one-hot
    to all-zeros, so no explicit valid mask interplay is needed beyond
    the tag check. Also works on banked states (extra leading axes).
    """
    valid = state.tags >= 0
    oh = jax.nn.one_hot(state.asids, n_asids, dtype=jnp.int32)
    return (oh * valid[..., None]).sum(axis=tuple(range(oh.ndim - 1)))
