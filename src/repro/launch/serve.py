"""Serving launcher: multi-tenant continuous batching on the reduced config.

Ad-hoc requests (legacy mode):

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --tenants 2 \
      --requests 8

Trace-driven with a placement policy (serving.stream presets; the
"oracle" policy consults the simulator-backed contention oracle and
walks the overload degradation ladder — quota -> preempt -> freeze ->
safe mode — under KV-pool pressure):

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b \
      --trace flood_vs_trickle --steps 24 --policy oracle

Overload drills inject a seeded serving-fault plan (pool-exhaustion
spikes, oracle stalls, poisoned profiles — repro.sim.faults):

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b \
      --trace flood_vs_trickle --policy oracle --faults --fault-rate 0.1
"""
from __future__ import annotations

import argparse
from typing import Mapping, Optional

import jax
import numpy as np

from repro.configs import get_model, reduced_model
from repro.configs.base import RunConfig, ShapeConfig
from repro.memmgr.kv_cache import PoolConfig
from repro.models import model as M
from repro.serving import metrics as smet
from repro.serving import stream as strm
from repro.serving.engine import EngineConfig, Request, ServingEngine
from repro.serving.placement import POLICIES, make_policy
from repro.sim.faults import random_serving_plan


def build_engine(arch: str, max_seqs: int = 16, policy: str = "none",
                 profiles: Optional[Mapping[int, str]] = None,
                 epoch_steps: int = 8, ecfg: Optional[EngineConfig] = None,
                 **policy_kw) -> ServingEngine:
    """Engine on the reduced model. `policy`/`profiles` select the
    admission placement layer (serving.placement); extra kwargs reach
    the policy factory (e.g. cycles=..., unfairness_cap=... for
    "oracle")."""
    cfg = reduced_model(get_model(arch))
    shape = ShapeConfig("serve", seq_len=64, global_batch=1, kind="decode")
    run = RunConfig(model=cfg, shape=shape, remat=False,
                    attn_block_q=16, attn_block_k=16)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    n_attn = sum(1 for k in cfg.layer_kinds() if k == "attn")
    pool = PoolConfig(
        n_pages=max_seqs * 8, page_size=cfg.kv_page_size,
        n_kv=max(cfg.n_kv_heads, 1), head_dim=cfg.head_dim if cfg.n_heads else 1,
        n_layers=max(n_attn, 1), max_seqs=max_seqs, pages_per_seq=8)
    placement = make_policy(policy, profiles=profiles,
                            epoch_steps=epoch_steps, **policy_kw)
    return ServingEngine(cfg, run, params, pool,
                         ecfg or EngineConfig(),
                         placement=placement, profiles=profiles)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--policy", default="none", choices=POLICIES)
    ap.add_argument("--trace", default=None,
                    help=f"trace preset {sorted(strm.PRESETS)}; omit for "
                         "ad-hoc --requests mode")
    ap.add_argument("--steps", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--epoch-steps", type=int, default=8)
    ap.add_argument("--cycles", type=int, default=300,
                    help="oracle: simulator cycles per prediction")
    ap.add_argument("--max-batch", type=int, default=8,
                    help="decode slots per engine step")
    ap.add_argument("--max-running", type=int, default=None,
                    help="admission bound (> max-batch gives decode "
                         "quotas/preemption a lever; default: coupled)")
    ap.add_argument("--faults", action="store_true",
                    help="inject a seeded random serving-fault plan "
                         "(pool spikes, oracle stalls, poisoned profiles)")
    ap.add_argument("--fault-rate", type=float, default=0.05)
    ap.add_argument("--tenants", type=int, default=2)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    ecfg = EngineConfig(max_batch=args.max_batch,
                        max_running=args.max_running,
                        backoff_seed=args.seed)
    if args.trace:
        trace = strm.make_trace(args.trace, seed=args.seed,
                                steps=args.steps)
        if args.faults:
            ecfg.fault_plan = random_serving_plan(
                args.seed, trace.steps,
                tuple(s.tenant for s in trace.specs),
                rate=args.fault_rate)
        kw = {"cycles": args.cycles} if args.policy == "oracle" else {}
        eng = build_engine(args.arch, policy=args.policy,
                           profiles=trace.profiles(),
                           epoch_steps=args.epoch_steps, ecfg=ecfg, **kw)
        finished = strm.drive(eng, trace)
    else:
        eng = build_engine(args.arch, policy=args.policy, ecfg=ecfg,
                           profiles={t: "batch"
                                     for t in range(args.tenants)})
        rng = np.random.RandomState(args.seed)
        for i in range(args.requests):
            eng.submit(Request(
                rid=i, tenant=i % args.tenants,
                prompt=rng.randint(0, eng.cfg.vocab_size, args.prompt_len),
                max_new=args.max_new))
        finished = eng.run_until_drained()

    tput = smet.tenant_throughput(finished, eng.step_count)
    print(f"policy={args.policy}: finished {len(finished)} requests "
          f"in {eng.step_count} steps "
          f"({len(eng.decisions)} placement decisions)")
    for t, v in sorted(tput.items()):
        print(f"  tenant {t}: {v:.2f} tok/step")
    print(f"mean latency {smet.mean_latency(finished):.1f} steps")
    cons = smet.conservation_report(eng)
    print(f"conservation: submitted {cons['submitted']} "
          f"finished {cons['finished']} lost {cons['lost']} "
          f"duplicated {cons['duplicated']}")
    if eng.decisions:
        summ = smet.decision_summary(eng.decisions)
        print(f"ladder rungs: {summ['rungs']}")
        if summ["predicted_max_slowdown_mean"] is not None:
            print(f"oracle predicted max slowdown (mean over epochs): "
                  f"{summ['predicted_max_slowdown_mean']:.3f}")
    if eng.preemptions or eng.fault_log:
        over = smet.overload_summary(eng)
        print(f"preemptions {over['preemptions']} "
              f"wasted tokens {over['wasted_tokens']} "
              f"faults {over['faults_injected']} "
              f"safe-mode log {over['safe_mode_log']}")


if __name__ == "__main__":
    main()
