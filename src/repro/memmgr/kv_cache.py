"""Physical paged KV pool + MASK-style translation caching for serving.

The pool is (n_pages, page_size, KV, dh) per layer-stack slice; tenants
(ASIDs) own disjoint page sets enforced by `block_table.translate`. A small
software translation cache (repro.core.tlb — same structure as the
hardware L2 TLB, ASID-tagged) fronts the two-level table; per-tenant fill
tokens (repro.core.tokens) throttle which decode streams may install
entries when tenants thrash it. This is the paper's mechanism transplanted
into the serving engine (DESIGN.md §2b).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tlb as tlb_mod
from repro.core import tokens as tok_mod
from repro.memmgr import block_table as bt_mod


@dataclasses.dataclass(frozen=True)
class PoolConfig:
    n_pages: int
    page_size: int
    n_kv: int
    head_dim: int
    n_layers: int
    max_seqs: int
    pages_per_seq: int
    max_tenants: int = 8
    seqs_per_tenant: int = 64
    tcache_entries: int = 256
    tcache_ways: int = 8


class KVPool(NamedTuple):
    k: jax.Array            # (L, n_pages, page, KV, dh) bf16
    v: jax.Array
    tables: bt_mod.BlockTables
    tcache: tlb_mod.TLBState        # translation cache over (seq,page) keys
    tokens: tok_mod.TokenState      # per-tenant fill tokens
    seq_lens: jax.Array             # (max_seqs,) int32
    seq_asid: jax.Array             # (max_seqs,) int32
    clock: jax.Array                # () int32 logical time for LRU


def init(cfg: PoolConfig) -> KVPool:
    shape = (cfg.n_layers, cfg.n_pages, cfg.page_size, cfg.n_kv, cfg.head_dim)
    return KVPool(
        k=jnp.zeros(shape, jnp.bfloat16),
        v=jnp.zeros(shape, jnp.bfloat16),
        tables=bt_mod.init(cfg.n_pages, cfg.max_seqs, cfg.pages_per_seq,
                           cfg.max_tenants, cfg.seqs_per_tenant),
        tcache=tlb_mod.init(cfg.tcache_entries, cfg.tcache_ways),
        tokens=tok_mod.init(cfg.max_tenants,
                            jnp.full((cfg.max_tenants,), cfg.max_seqs,
                                     jnp.int32)),
        seq_lens=jnp.zeros((cfg.max_seqs,), jnp.int32),
        seq_asid=jnp.full((cfg.max_seqs,), -1, jnp.int32),
        clock=jnp.zeros((), jnp.int32),
    )


def _tkey(cfg: PoolConfig, seq_slot, logical_page):
    return seq_slot * cfg.pages_per_seq + logical_page


def lookup(cfg: PoolConfig, pool: KVPool, seq_slot, logical_page
           ) -> Tuple[KVPool, jax.Array, jax.Array, jax.Array]:
    """Batched translation through the cache. Returns
    (pool', phys_page, fault, tcache_hit)."""
    asid = pool.seq_asid[seq_slot]
    key = _tkey(cfg, seq_slot, logical_page)
    active = jnp.ones(key.shape, bool)
    tc, hit = tlb_mod.probe(pool.tcache, key, asid, active, pool.clock)
    phys, fault = bt_mod.translate(pool.tables, seq_slot, logical_page, asid)
    tokens = tok_mod.record(pool.tokens, jnp.maximum(asid, 0), hit, active)
    # fill policy: misses fill only when the tenant holds tokens
    has_tok = tok_mod.has_token(tokens, jnp.maximum(asid, 0),
                                seq_slot % cfg.seqs_per_tenant)
    tc = tlb_mod.fill(tc, key, asid, ~hit & ~fault & has_tok, pool.clock)
    return pool._replace(tcache=tc, tokens=tokens,
                         clock=pool.clock + 1), phys, fault, hit


def admit_seq(cfg: PoolConfig, pool: KVPool, seq_slot, asid, prompt_len
              ) -> Tuple[KVPool, jax.Array]:
    """Admit a sequence: allocate pages for the prompt."""
    pages = (prompt_len + cfg.page_size - 1) // cfg.page_size
    tables, ok = bt_mod.alloc_pages(pool.tables, seq_slot, 0, pages, asid)
    pool = pool._replace(
        tables=tables,
        seq_lens=pool.seq_lens.at[seq_slot].set(
            jnp.where(ok, prompt_len, pool.seq_lens[seq_slot])),
        seq_asid=pool.seq_asid.at[seq_slot].set(
            jnp.where(ok, asid, pool.seq_asid[seq_slot])))
    return pool, ok


def append_token_alloc(cfg: PoolConfig, pool: KVPool, seq_slot
                       ) -> Tuple[KVPool, jax.Array]:
    """Grow a sequence by one token; allocates a new page on boundary."""
    ln = pool.seq_lens[seq_slot]
    need_page = (ln % cfg.page_size) == 0
    asid = pool.seq_asid[seq_slot]
    tables, ok = jax.lax.cond(
        need_page,
        lambda: bt_mod.alloc_pages(pool.tables, seq_slot,
                                   ln // cfg.page_size, 1, asid),
        lambda: (pool.tables, jnp.array(True)))
    pool = pool._replace(
        tables=tables,
        seq_lens=pool.seq_lens.at[seq_slot].set(jnp.where(ok, ln + 1, ln)))
    return pool, ok


def release_seq(cfg: PoolConfig, pool: KVPool, seq_slot) -> KVPool:
    tables = bt_mod.free_seq(pool.tables, seq_slot)
    asid = pool.seq_asid[seq_slot]
    # shootdown: evict this seq's translations (flush by tag range is
    # approximated by ASID flush when the tenant departs entirely)
    return pool._replace(
        tables=tables,
        seq_lens=pool.seq_lens.at[seq_slot].set(0),
        seq_asid=pool.seq_asid.at[seq_slot].set(-1))


def write_kv(cfg: PoolConfig, pool: KVPool, layer, seq_slots, k_new, v_new
             ) -> Tuple[KVPool, jax.Array]:
    """Write one new token's K/V for a batch of sequences at `layer`.

    k_new/v_new: (B, KV, dh). Returns (pool', fault)."""
    ln = pool.seq_lens[seq_slots] - 1          # position of the new token
    logical = ln // cfg.page_size
    offset = ln % cfg.page_size
    pool, phys, fault, _ = lookup(cfg, pool, seq_slots, logical)
    k = pool.k.at[layer, phys, offset].set(
        jnp.where(fault[:, None, None], pool.k[layer, phys, offset], k_new))
    v = pool.v.at[layer, phys, offset].set(
        jnp.where(fault[:, None, None], pool.v[layer, phys, offset], v_new))
    return pool._replace(k=k, v=v), fault


def gather_block_table(cfg: PoolConfig, pool: KVPool, seq_slots) -> jax.Array:
    """(B, pages_per_seq) physical page ids for the paged-attention kernel."""
    return jnp.maximum(pool.tables.leaf[seq_slots], 0)


# Jitted entry points for the serving engine's per-step pool mutations.
# Eager `lax.cond` (append_token_alloc) retraces and compiles a FRESH
# executable on every call — thousands of engine steps then exhaust the
# process's memory-map budget (vm.max_map_count) and crash LLVM. Static
# cfg (PoolConfig is frozen/hashable) keys one compile per pool shape.
admit_seq_jit = jax.jit(admit_seq, static_argnums=0)
append_token_alloc_jit = jax.jit(append_token_alloc, static_argnums=0)
release_seq_jit = jax.jit(release_seq, static_argnums=0)


class PoolPressure(NamedTuple):
    """Host-side occupancy snapshot for admission/placement decisions."""

    used_frac: float                  # fraction of physical pages in use
    free_pages: int
    free_seqs: int                    # unoccupied sequence slots
    pages_by_tenant: Dict[int, int]   # ASID -> pages held


# ASID reserved for fault-injected phantom sequences (pool-exhaustion
# spikes): far outside any tenant universe, filtered out of per-tenant
# page attribution but counted in used_frac — the spike IS the pressure.
PHANTOM_ASID = 1_000_003


def occupy_pages(cfg: PoolConfig, pool: KVPool, free_slots: list,
                 pages: int) -> Tuple[KVPool, list]:
    """Admit phantom sequences under `PHANTOM_ASID` occupying up to
    `pages` KV pages (a deterministic pool-exhaustion spike for fault
    injection). Consumes slots from `free_slots` (mutated in place, same
    discipline as the engine's slot list); stops early when the pool or
    the slot list runs out. Returns (pool', used_slots) — the caller
    releases each slot through `release_seq_jit` to end the spike."""
    used: list = []
    left = int(pages)
    while left > 0 and free_slots:
        take = min(left, cfg.pages_per_seq)
        slot = free_slots.pop()
        pool, ok = admit_seq_jit(cfg, pool, jnp.int32(slot),
                                 jnp.int32(PHANTOM_ASID),
                                 jnp.int32(take * cfg.page_size))
        if not bool(ok):
            free_slots.append(slot)
            break
        used.append(slot)
        left -= take
    return pool, used


def pool_pressure(cfg: PoolConfig, pool: KVPool) -> PoolPressure:
    """Surface KV-pool pressure to the placement layer (one small
    device->host transfer; called once per decision epoch)."""
    owner = np.asarray(pool.tables.owner)
    seq_asid = np.asarray(pool.seq_asid)
    free = int(cfg.n_pages - (owner >= 0).sum())
    live = owner[owner >= 0]
    by_tenant = {int(t): int((live == t).sum()) for t in np.unique(live)}
    return PoolPressure(
        used_frac=1.0 - free / max(cfg.n_pages, 1),
        free_pages=free,
        free_seqs=int((seq_asid < 0).sum()),
        pages_by_tenant=by_tenant)
