"""whisper-base [audio] — encoder-decoder, conv frontend stubbed. [arXiv:2212.04356]

6L(enc)+6L(dec) d_model=512 8H (MHA) d_ff=2048 vocab=51865.
``input_specs()`` provides precomputed frame embeddings (the conv frontend
is a stub per the assignment); enc_len is the standard 1500-frame window.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    n_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    n_enc_layers=6,
    enc_len=1500,
    rope_theta=10_000.0,   # backbone uses RoPE in this repo (frontend stubbed)
    tie_embeddings=True,
)
