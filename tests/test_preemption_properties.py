"""Hypothesis property tests for the preemption/overload invariants:
request conservation across evict/re-queue cycles, exactly-once KV page
release, and deterministic seeded backoff. Skips itself gracefully when
`hypothesis` is absent (same policy as test_core_tlb_properties.py);
the deterministic core versions always run in test_serving_overload.py.
"""
import dataclasses

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
import hypothesis.strategies as st  # noqa: E402
from hypothesis import given, settings  # noqa: E402

from repro.memmgr import kv_cache as kvc  # noqa: E402
from repro.serving import metrics as smet  # noqa: E402
from repro.serving.engine import (EngineConfig, Request,  # noqa: E402
                                  ServingEngine, backoff_steps,
                                  stub_forwards, stub_model_config)
from repro.serving.placement import PlacementPolicy  # noqa: E402
from repro.sim.faults import (ServingFault,  # noqa: E402
                              ServingFaultPlan)

POOL = kvc.PoolConfig(n_pages=64, page_size=8, n_kv=1, head_dim=4,
                      n_layers=1, max_seqs=8, pages_per_seq=4)


class RoundRobinPreempt(PlacementPolicy):
    """Adversarial policy: preempt one running request from a rotating
    tenant every epoch — maximal evict/re-queue churn."""

    name = "rr-preempt"

    def __init__(self, epoch_steps=2):
        super().__init__(epoch_steps)
        self._turn = 0

    def _decide(self, view):
        d = super()._decide(view)
        ts = sorted(view.running)
        if not ts:
            return d
        t = ts[self._turn % len(ts)]
        self._turn += 1
        return dataclasses.replace(d, preempt={t: 1}, rung="preempt")


def _run(seed, n_reqs, n_tenants, max_new, spike):
    rng = np.random.RandomState(seed)
    plan = None
    if spike:
        plan = ServingFaultPlan(seed=seed, faults=(
            ServingFault("pool_spike", step=3, duration=6,
                         pages=POOL.n_pages),))
    eng = ServingEngine(
        stub_model_config(), None, None, POOL,
        EngineConfig(max_batch=4, max_running=6, backoff_seed=seed,
                     fault_plan=plan),
        placement=RoundRobinPreempt(), forwards=stub_forwards())
    for i in range(n_reqs):
        eng.submit(Request(rid=i, tenant=int(rng.randint(n_tenants)),
                           prompt=rng.randint(0, 64, 8),
                           max_new=int(1 + rng.randint(max_new))))
        eng.step()
    eng.run_until_drained(max_steps=2000)
    return eng


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 12), st.integers(1, 4),
       st.integers(1, 20), st.booleans())
def test_property_conservation_and_exact_release(seed, n_reqs, n_tenants,
                                                 max_new, spike):
    """No request is ever lost or duplicated across preemption cycles,
    every request fully decodes, and every KV page is released exactly
    once (pool and slot list return to pristine after drain)."""
    eng = _run(seed, n_reqs, n_tenants, max_new, spike)
    cons = smet.conservation_report(eng)
    assert cons["ok"], cons
    assert cons["finished"] == n_reqs and cons["pending"] == 0
    for r in eng.finished:
        assert r.decoded == min(r.max_new, eng.ecfg.decode_len_cap)
    assert kvc.pool_pressure(POOL, eng.pool).free_pages == POOL.n_pages
    assert sorted(eng._free_slots) == list(range(POOL.max_seqs))


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 20), st.integers(0, 2 ** 20),
       st.integers(1, 8), st.integers(1, 8))
def test_property_backoff_deterministic_bounded(seed, rid, retries, base):
    a = backoff_steps(seed, rid, retries, base)
    assert a == backoff_steps(seed, rid, retries, base)
    lo = base * 2 ** max(retries - 1, 0)
    assert lo <= a < lo + max(base, 1)
