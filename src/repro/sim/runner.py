"""Simulation runner: N-app mixes, solo/pair wrappers, design sweeps,
metric extraction.

`run_mix(design, benches)` is the primary entry point: it co-runs
len(benches) applications (None entries are idle partners) and returns
per-app stats. `run_pair` / `run_solo` are thin 2-app wrappers kept for
the paper's pair-based experiments; `run_batch` vmaps many same-size
mixes through one compile.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mask import design
from repro.sim.config import SimConfig
from repro.sim.memsys import SimState, init_state, step
from repro.sim.workloads import app_matrix

jax.config.update("jax_enable_x64", False)


@functools.lru_cache(maxsize=64)
def _compiled_run(cfg: SimConfig):
    def run(params_mat):
        st = init_state(cfg)

        def body(s, _):
            return step(cfg, params_mat, s), None

        final, _ = jax.lax.scan(body, st, None, length=cfg.sim_cycles)
        return final

    return jax.jit(run)


@functools.lru_cache(maxsize=64)
def _compiled_batch_run(cfg: SimConfig):
    """vmapped over a leading batch of workload parameter matrices — one
    compile serves every mix/solo under a design."""
    return jax.jit(jax.vmap(_compiled_run(cfg)))


def _stats(cfg: SimConfig, st: SimState) -> Dict[str, np.ndarray]:
    na = cfg.n_apps
    warp_app = np.repeat(np.asarray(cfg.app_of_core), cfg.warps_per_core)
    instr = np.asarray(st.instr)
    ipc = np.array([instr[warp_app == a].sum() for a in range(na)]) \
        / float(st.t)
    s = st.stats
    g = lambda x: np.asarray(x, np.float64)  # noqa: E731
    l1p = g(s.s_l1_hit) + g(s.s_l1_miss)
    l2p = g(s.s_l2_hit) + g(s.s_l2_miss)
    return {
        "ipc": ipc,
        "l1_hit_rate": g(s.s_l1_hit) / np.maximum(l1p, 1),
        "l1_miss_rate": g(s.s_l1_miss) / np.maximum(l1p, 1),
        "l2_hit_rate": g(s.s_l2_hit) / np.maximum(l2p, 1),
        "l2_miss_rate": g(s.s_l2_miss) / np.maximum(l2p, 1),
        "byp_hit_rate": g(s.s_byp_hit) / np.maximum(g(s.s_byp_probe), 1),
        "walk_lat": g(s.s_walk_lat) / np.maximum(g(s.s_walks), 1),
        "walks": g(s.s_walks),
        "stalls_per_miss": g(s.s_stall_per_miss) / np.maximum(g(s.s_walks), 1),
        "dram_tlb_lat": g(s.s_dram_tlb_lat) / np.maximum(g(s.s_dram_tlb_n), 1),
        "dram_data_lat": g(s.s_dram_data_lat)
        / np.maximum(g(s.s_dram_data_n), 1),
        "dram_tlb_n": g(s.s_dram_tlb_n),
        "dram_data_n": g(s.s_dram_data_n),
        # L2 data-cache hit rate for TLB requests (Table 5)
        "l2c_tlb_hit_rate": (g(s.s_l2c_tlb_hit)
                             / max(g(s.s_l2c_tlb_probe), 1)),
        "l2c_data_hit_rate": (g(s.s_l2c_data_hit)
                              / max(g(s.s_l2c_data_probe), 1)),
        "tokens": np.asarray(st.tokens.tokens),
        "cycles": float(st.t),
    }


def _mix_matrix(benches: Sequence[Optional[str]]) -> np.ndarray:
    """(n_apps, N_FIELDS) parameter matrix; None entries are idle apps."""
    return app_matrix(list(benches))


def run_mix(design_name: str, benches: Sequence[Optional[str]],
            cycles: int = 60_000) -> Dict:
    """Co-run N apps under a design; returns per-app stats.

    `benches` may contain None for idle partners (the §6 `IPC_alone`
    emulation keeps the core split of the shared run but removes memory
    contention from the partner slots).
    """
    cfg = SimConfig(n_apps=len(benches), sim_cycles=cycles,
                    design=design(design_name))
    pm = jnp.asarray(_mix_matrix(benches))
    st = _compiled_run(cfg)(pm)
    return _stats(cfg, st)


def run_batch(design_name: str,
              bench_mixes: Sequence[Tuple[Optional[str], ...]],
              cycles: int = 60_000) -> List[Dict]:
    """Run many same-size workload mixes at once (vmap). An entry may
    contain None for a solo run (idle partner)."""
    sizes = {len(m) for m in bench_mixes}
    if len(sizes) != 1:
        raise ValueError(f"all mixes must have the same size, got {sizes}")
    cfg = SimConfig(n_apps=sizes.pop(), sim_cycles=cycles,
                    design=design(design_name))
    pm = jnp.asarray(np.stack([_mix_matrix(m) for m in bench_mixes]))
    final = _compiled_batch_run(cfg)(pm)
    out = []
    for i in range(len(bench_mixes)):
        sub = jax.tree_util.tree_map(lambda x: np.asarray(x)[i], final)
        out.append(_stats(cfg, sub))
    return out


def run_pair(design_name: str, bench_a: str, bench_b: str,
             cycles: int = 60_000) -> Dict:
    """Co-run two apps under a design; returns per-app stats."""
    return run_mix(design_name, [bench_a, bench_b], cycles)


def run_solo(design_name: str, bench: str, cycles: int = 60_000) -> Dict:
    """IPC_alone: same core count as in the shared run (paper §6),
    exclusive memory system — emulated by pairing with an idle app."""
    return run_mix(design_name, [bench, None], cycles)


def weighted_speedup(mix_stats, *solos) -> float:
    """Sum of per-app IPC / IPC_alone over the mix (any N)."""
    return float(sum(mix_stats["ipc"][i] / max(s["ipc"][0], 1e-9)
                     for i, s in enumerate(solos)))


def max_slowdown(mix_stats, *solos) -> float:
    """Unfairness: worst per-app IPC_alone / IPC over the mix (any N)."""
    return float(max(s["ipc"][0] / max(mix_stats["ipc"][i], 1e-9)
                     for i, s in enumerate(solos)))
