"""Flash attention Pallas TPU kernel (training/prefill hot path).

Canonical TPU tiling: grid (batch, q_heads, num_q_blocks, num_kv_blocks),
with the KV index innermost so the (m, l, acc) online-softmax state lives in
VMEM scratch across KV steps and the output block is written once on the
last step. GQA is handled in the BlockSpec index maps (q head h reads kv
head h // group). Causal + sliding-window masking is applied on the diagonal
tiles; fully-masked tiles are skipped via pl.when (no MXU work issued).

Block shapes are MXU-aligned: block_q x head_dim and block_k x head_dim
tiles with head_dim a multiple of 128 (dh=64 archs pad in ops.py).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            sm_scale: float, causal: bool, window: Optional[int],
            block_q: int, block_k: int, nk: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    # tile visibility (static per grid point only for causal; computed with
    # scalars so the branch is cheap when skipped)
    visible = True
    if causal:
        visible = k_start <= q_start + block_q - 1
    if window is not None:
        visible = jnp.logical_and(
            visible, k_start + block_k - 1 > q_start - window) \
            if causal else (k_start + block_k - 1 > q_start - window)

    @pl.when(visible)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, dh)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, dh)
        v = v_ref[0, 0]                               # (bk, dh)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale  # (bq, bk)

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask = mask & (kpos <= qpos)
        if window is not None:
            mask = mask & (kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                           # (bq,)
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal=True, window: Optional[int] = None,
                         block_q: int = 512, block_k: int = 512,
                         interpret: bool = False):
    """q: (B, H, Sq, dh); k, v: (B, KV, Sk, dh) -> (B, H, Sq, dh)."""
    B, H, Sq, dh = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    G = H // KV
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0
    nq, nk = Sq // block_q, Sk // block_k
    sm_scale = 1.0 / (dh ** 0.5)

    grid = (B, H, nq, nk)
    kern = functools.partial(
        _kernel, sm_scale=sm_scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, nk=nk)

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, dh), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, dh),
                         lambda b, h, qi, ki, _g=G: (b, h // _g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, dh),
                         lambda b, h, qi, ki, _g=G: (b, h // _g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, dh),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
