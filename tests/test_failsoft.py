"""Fail-soft sweeps + the stats NaN guard.

One deliberately-poisoned design point (l2_ways=0 -> ZeroDivisionError
at trace time, its own signature group) must cost exactly its own group:
every other design still returns a full ExperimentResult, and the poison
maps to a structured FailureRecord. Without fail_soft, behavior stays
raise-on-first-error. The `_stats` guard turns would-be NaN IPC into a
descriptive error instead of silently poisoning weighted_speedup.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.design import get_design
from repro.sim.runner import (Experiment, ExperimentResult, FailureRecord,
                              ZeroCycleError, run_grid, run_mix, sweep)

MIXES = [("3DS", "BLK"), ("MUM", "RED")]


def _poison():
    mask = get_design("mask")
    return dataclasses.replace(
        mask, name="poison",
        translation=dataclasses.replace(mask.translation, l2_ways=0))


def test_grid_sweep_completes_around_poisoned_design():
    out = sweep(["gpu-mmu", "mask", _poison()], MIXES, cycles=250,
                fail_soft=True)
    assert isinstance(out["gpu-mmu"], ExperimentResult)
    assert isinstance(out["mask"], ExperimentResult)
    rec = out["poison"]
    assert isinstance(rec, FailureRecord)
    assert rec.error_type == "ZeroDivisionError"
    assert rec.designs == ("poison",) and rec.n_apps == 2
    assert not rec and out["mask"]       # records are falsy, results truthy
    with pytest.raises(RuntimeError, match="poison"):
        rec.reraise()
    # healthy results are intact (not perturbed by the failure path)
    assert out["mask"].mean_weighted_speedup() > 0


def test_fail_soft_default_still_raises():
    with pytest.raises(ZeroDivisionError):
        sweep(["gpu-mmu", _poison()], MIXES, cycles=250)
    with pytest.raises(ZeroDivisionError):
        run_grid([_poison()], MIXES, cycles=250)


def test_run_grid_fail_soft_cells():
    out = run_grid(["mask", _poison()], MIXES, cycles=250, fail_soft=True)
    assert all(isinstance(c, dict) for c in out[0])
    assert all(isinstance(c, FailureRecord) for c in out[1])
    assert out[1][0].stage == "grid-chunk"
    assert np.isfinite(out[0][0]["ipc"]).all()


def test_experiment_fail_soft():
    exp = Experiment(_poison(), MIXES, cycles=250)
    with pytest.raises(ZeroDivisionError):
        exp.run()
    rec = exp.run(fail_soft=True)
    assert isinstance(rec, FailureRecord)
    assert rec.stage == "experiment-batch"
    # per-design loop path of sweep uses the same boundary
    out = sweep(["mask", _poison()], MIXES, cycles=250, grid=False,
                fail_soft=True)
    assert isinstance(out["mask"], ExperimentResult)
    assert isinstance(out["poison"], FailureRecord)


def test_zero_cycle_stats_guard():
    with pytest.raises(ZeroCycleError, match="IPC"):
        run_mix("gpu-mmu", ["3DS", "BLK"], cycles=0)


def test_zero_cycle_run_is_fail_soft_catchable():
    out = sweep(["gpu-mmu"], MIXES, cycles=0, fail_soft=True)
    rec = out["gpu-mmu"]
    assert isinstance(rec, FailureRecord)
    assert rec.error_type == "ZeroCycleError"
