"""jit-able step builders: train_step (grad-accum + optimizer), prefill_step,
decode_step. These are what the launcher jits and the dry-run lowers."""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.models import model as M
from repro.models.losses import cross_entropy
from repro.train import optimizer as opt_mod

AUX_WEIGHT = 1e-2


def _loss_mask(cfg: ModelConfig, labels):
    if cfg.n_patches:
        pos = jnp.arange(labels.shape[1])[None, :]
        return (pos >= cfg.n_patches).astype(jnp.float32)
    return None


def build_loss_fn(cfg: ModelConfig, run: RunConfig, constrain=None):
    constrain = constrain or (lambda x, axes: x)

    def loss_fn(params, batch):
        logits, aux = M.forward_train(cfg, run, params, batch, constrain)
        loss, metrics = cross_entropy(logits, batch["labels"],
                                      _loss_mask(cfg, batch["labels"]),
                                      real_vocab=cfg.vocab_size)
        total = loss + AUX_WEIGHT * aux
        metrics = dict(metrics, aux=aux)
        return total, metrics

    return loss_fn


def build_train_step(cfg: ModelConfig, run: RunConfig, opt_cfg: opt_mod.OptConfig,
                     constrain=None):
    """Returns train_step(params, opt_state, batch, rng) -> (params, opt_state,
    metrics). Grad accumulation over run.microbatches via lax.scan."""
    loss_fn = build_loss_fn(cfg, run, constrain)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    M_ = run.microbatches
    acc_dt = jnp.bfloat16 if run.bf16_moments else jnp.float32

    def split_micro(x):
        return x.reshape((M_, x.shape[0] // M_) + x.shape[1:])

    def train_step(params, opt_state, batch):
        if M_ == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            micro = jax.tree_util.tree_map(split_micro, batch)

            def body(acc, mb):
                g_acc, l_acc = acc
                (l, _), g = grad_fn(params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(acc_dt), g_acc, g)
                return (g_acc, l_acc + l), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, acc_dt), params)
            (grads, loss_sum), _ = jax.lax.scan(body, (g0, 0.0), micro)
            grads = jax.tree_util.tree_map(lambda g: g / M_, grads)
            loss = loss_sum / M_
            metrics = {"loss": loss}
        params, opt_state, opt_metrics = opt_mod.update(
            params, grads, opt_state, opt_cfg)
        metrics = dict(metrics, **opt_metrics)
        metrics = {k: v.astype(jnp.float32) if hasattr(v, "astype") else v
                   for k, v in metrics.items()}
        return params, opt_state, metrics

    return train_step


def build_prefill_step(cfg: ModelConfig, run: RunConfig, max_len: int,
                       constrain=None):
    constrain = constrain or (lambda x, axes: x)

    def prefill_step(params, batch):
        return M.forward_prefill(cfg, run, params, batch, max_len, constrain)

    return prefill_step


def build_decode_step(cfg: ModelConfig, run: RunConfig, constrain=None):
    constrain = constrain or (lambda x, axes: x)

    def decode_step(params, caches, batch):
        logits, new_caches = M.forward_decode(
            cfg, run, params, batch, caches, constrain=constrain)
        return logits, new_caches

    return decode_step
