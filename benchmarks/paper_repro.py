"""Paper-reproduction benchmarks: one entry per MASK table/figure.

Each function runs the vectorized simulator over the paper's workload
bundles and emits (metric rows, paper-claimed value) so EXPERIMENTS.md can
show ours vs. theirs side by side. Results cache to reports/sim/ as JSON.

  fig3   — shared-L2-TLB baseline vs page-walk-cache baseline vs ideal
  fig16  — weighted speedup: MASK vs GPU-MMU vs Static (headline +45.2%)
  fig17  — component stack: MASK-TLB / MASK-Cache / MASK-DRAM
  fig18  — unfairness (max slowdown) reduction (-22.4%)
  tab3   — shared L2 TLB hit rates (49.3% -> 73.9%)
  tab4   — bypass-cache hit rate (66.7%)
  tab5   — L2 data-cache hit rate for TLB requests (70.7% -> 98.3%)
  fig19  — DRAM latency for TLB vs data requests under MASK-DRAM
  fig20  — scalability with concurrent app count (2..4 via run_batch mixes)
"""
from __future__ import annotations

import json
from pathlib import Path

import numpy as np

from repro.sim.runner import Experiment, sweep
from repro.sim.workloads import hmr_class, mix_workloads, pair_workloads

REPORT_DIR = Path(__file__).resolve().parent.parent / "reports" / "sim"
CYCLES = 60_000
N_PAIRS = 20     # of the 35 sampled pairs (CPU-budget subset; --full for all)
# bump whenever simulator semantics change so stale JSON caches are not
# silently mixed with fresh results (v2: layered pipeline + gap/l1d
# field-index fix + TLB scatter fix; v3: lane-fused memory path — one
# batched L2$/DRAM round per cycle with forwarding/port/victim-chain
# emulation, see README "Performance")
CACHE_VERSION = 3


def _cache_path(name: str) -> Path:
    """The one place the cache file convention lives (dir + version)."""
    REPORT_DIR.mkdir(parents=True, exist_ok=True)
    return REPORT_DIR / f"{name}_v{CACHE_VERSION}.json"


def _cache(name: str, fn, force=False):
    f = _cache_path(name)
    if f.exists() and not force:
        return json.loads(f.read_text())
    out = fn()
    f.write_text(json.dumps(out, default=float))
    return out


def _pairs(n=None):
    return pair_workloads()[:N_PAIRS if n is None else n]


def _mix_row(r) -> dict:
    """One cached-JSON row from a typed MixResult (schema is pinned by the
    existing reports/sim caches — do not rename keys)."""
    return {
        "pair": "_".join(r.benches), "hmr": hmr_class(r.benches),
        "weighted_speedup": r.weighted_speedup(),
        "max_slowdown": r.unfairness(),
        "ipc": [a.ipc for a in r.apps],
        "l2_tlb_hit": [a.l2_tlb_hit_rate for a in r.apps],
        "bypass_hit": [a.bypass_hit_rate for a in r.apps],
        "l2c_tlb_hit": r.l2c_tlb_hit_rate,
        "walk_lat": [a.walk_lat for a in r.apps],
        "dram_tlb_lat": [a.dram_tlb_lat for a in r.apps],
        "dram_data_lat": [a.dram_data_lat for a in r.apps],
    }


def _result_rows(res) -> dict:
    """Cached-JSON payload for one design's ExperimentResult."""
    solo = {b: ipc for (b, _n), ipc in res.solo_ipc.items()}
    return {"solo": solo, "pairs": [_mix_row(r) for r in res]}


def _sweep(designs, n_pairs=None, cycles=None, force=False):
    """Per-design cached pair-sweep data, computed via the grid path.

    All uncached designs run as ONE `runner.sweep` call: designs are
    grouped by static signature, and each group's whole design x pair
    grid (solo baselines included) is a single compiled, vmapped device
    execution — the paper's 8-design grid compiles 2 programs instead
    of 8. Results are bit-for-bit equal to the per-design loop, so the
    per-design JSON cache files (and CACHE_VERSION) are unchanged.

    None defaults resolve to the module globals at CALL time, so
    `pr.CYCLES = 800; pr.N_PAIRS = 2` shrinks a smoke run in-process;
    non-default cycle counts get their own cache files so a shrunken
    smoke run can never serve (or be served) full-length results.
    """
    n_pairs = N_PAIRS if n_pairs is None else n_pairs
    cycles = CYCLES if cycles is None else cycles
    pairs = _pairs(n_pairs)
    tag = "" if cycles == 60_000 else f"_{cycles}c"
    files = {d: _cache_path(f"design_{d}_{n_pairs}p{tag}") for d in designs}
    missing = [d for d in designs if force or not files[d].exists()]
    if missing:
        res = sweep(missing, pairs, cycles)
        for d in missing:
            files[d].write_text(json.dumps(_result_rows(res[d]),
                                           default=float))
    return {d: json.loads(files[d].read_text()) for d in designs}


def _design_data(design: str, n_pairs=None, cycles=None, force=False):
    return _sweep([design], n_pairs, cycles, force)[design]


# ---------------------------------------------------------------- figures

def fig3(force=False):
    data = _sweep(["gpu-mmu", "pwc", "ideal"], force=force)
    ws = {d: np.mean([r["weighted_speedup"] for r in data[d]["pairs"]])
          for d in data}
    return {
        "ours": {d: float(v) for d, v in ws.items()},
        "ours_shared_vs_pwc_pct": float((ws["gpu-mmu"] / ws["pwc"] - 1) * 100),
        "paper": {"shared_l2_tlb_vs_pwc_pct": 13.8},
    }


def fig16(force=False):
    data = _sweep(["gpu-mmu", "mask", "static", "ideal"], force=force)
    ws = {d: np.mean([r["weighted_speedup"] for r in data[d]["pairs"]])
          for d in data}
    return {
        "ours": {d: float(v) for d, v in ws.items()},
        "ours_mask_vs_gpummu_pct": float((ws["mask"] / ws["gpu-mmu"] - 1) * 100),
        "ours_mask_vs_ideal_pct": float((ws["mask"] / ws["ideal"] - 1) * 100),
        "paper": {"mask_vs_gpummu_pct": 45.2, "mask_vs_ideal_pct": -23.0},
    }


def fig17(force=False):
    data = _sweep(["gpu-mmu", "mask-tlb", "mask-cache", "mask-dram", "mask"],
                  force=force)
    ws = {d: np.mean([r["weighted_speedup"] for r in data[d]["pairs"]])
          for d in data}
    base = ws["gpu-mmu"]
    return {
        "ours_pct_over_gpummu": {d: float((v / base - 1) * 100)
                                 for d, v in ws.items()},
        "paper": {"mask-cache_pct": 17.6, "mask-dram_pct": 0.83,
                  "mask_pct": 45.2},
    }


def fig18(force=False):
    data = _sweep(["gpu-mmu", "mask", "static"], force=force)
    ms = {d: np.mean([r["max_slowdown"] for r in data[d]["pairs"]])
          for d in data}
    return {
        "ours": {d: float(v) for d, v in ms.items()},
        "ours_mask_vs_gpummu_pct": float((1 - ms["mask"] / ms["gpu-mmu"]) * 100),
        "ours_mask_vs_static_pct": float((1 - ms["mask"] / ms["static"]) * 100),
        "paper": {"unfairness_reduction_vs_gpummu_pct": 22.4,
                  "unfairness_reduction_vs_static_pct": 30.7},
    }


def _hit_by_hmr(rows, key):
    out = {}
    for h in (0, 1, 2):
        vals = [v for r in rows if r["hmr"] == h for v in (
            r[key] if isinstance(r[key], list) else [r[key]])]
        if vals:
            out[f"{h}HMR"] = float(np.mean(vals))
    all_vals = [v for r in rows for v in (
        r[key] if isinstance(r[key], list) else [r[key]])]
    out["avg"] = float(np.mean(all_vals))
    return out


def tab3(force=False):
    data = _sweep(["gpu-mmu", "mask-tlb"], force=force)
    return {
        "ours": {d: _hit_by_hmr(data[d]["pairs"], "l2_tlb_hit") for d in data},
        "paper": {"gpu-mmu": {"avg": 0.493}, "mask-tlb": {"avg": 0.739}},
    }


def tab4(force=False):
    data = _sweep(["gpu-mmu", "mask-tlb"], force=force)
    return {
        "ours": _hit_by_hmr(data["mask-tlb"]["pairs"], "bypass_hit"),
        "paper": {"avg": 0.667},
    }


def tab5(force=False):
    data = _sweep(["gpu-mmu", "mask-cache"], force=force)
    return {
        "ours": {d: _hit_by_hmr(data[d]["pairs"], "l2c_tlb_hit") for d in data},
        "paper": {"gpu-mmu": {"avg": 0.707}, "mask-cache": {"avg": 0.983}},
    }


def fig19(force=False):
    data = _sweep(["gpu-mmu", "mask-dram"], force=force)
    out = {}
    for d in data:
        rows = data[d]["pairs"]
        out[d] = {
            "dram_tlb_lat": float(np.mean([np.mean(r["dram_tlb_lat"])
                                           for r in rows])),
            "dram_data_lat": float(np.mean([np.mean(r["dram_data_lat"])
                                            for r in rows])),
        }
    return {"ours": out,
            "paper": "TLB DRAM latency > data latency under FR-FCFS; "
                     "MASK-DRAM reduces TLB latency (up to 10.6%)"}


# N-app scalability bundles (paper Fig. 20 stops at 3; we extend to 4 to
# exercise arbitrary-N support). Mixes are drawn with the same seed/dedup
# policy as the 2-app sweep.
SCALE_MIXES = {
    3: mix_workloads(seed=7, n_mixes=2, n_apps=3),
    4: mix_workloads(seed=7, n_mixes=2, n_apps=4),
}


def fig20(force=False):
    """Scalability with concurrent app count: mean weighted speedup for
    N = 2 (main sweep) and N = 3, 4 (one Experiment over N-app mixes).

    IPC_alone is taken at the SAME 1/n core share (app + n-1 idle
    partners): a half-GPU solo would deflate every ratio by the
    core-share mismatch, not by memory contention — Experiment.run's
    solo baselines do exactly this."""

    def compute():
        out = {}
        mixes_3plus = [m for _, ms in sorted(SCALE_MIXES.items()) for m in ms]
        for d in ("gpu-mmu", "mask", "ideal"):
            data = _sweep([d])
            per_n = {"2": float(np.mean(
                [r["weighted_speedup"] for r in data[d]["pairs"]]))}
            res = Experiment(d, mixes_3plus, cycles=CYCLES).run()
            for n in sorted(SCALE_MIXES):
                ws = [r.weighted_speedup() for r in res
                      if len(r.benches) == n]
                per_n[str(n)] = float(np.mean(ws))
            out[d] = per_n
        return out

    return _cache("fig20", compute, force)


ALL = {"fig3": fig3, "fig16": fig16, "fig17": fig17, "fig18": fig18,
       "tab3": tab3, "tab4": tab4, "tab5": tab5, "fig19": fig19,
       "fig20": fig20}
