"""Multi-tenant continuous-batching serving engine.

Requests from multiple tenants (ASIDs) share one model + one paged KV pool.
Scheduling is the paper's three-class discipline (repro.core.dram_sched
semantics transplanted to request admission, §5.4):

  Golden — translation/metadata work (page allocation, table updates,
           admission) always runs before token work each step.
  Silver — one tenant at a time gets guaranteed decode slots, quota
           proportional to Concurrent_i * Stalled_i (Eq. 1 analogue:
           in-flight sequences x queue depth).
  Normal — remaining decode slots round-robin over other tenants.

Per-tenant throughput / weighted-speedup metrics mirror the paper's
evaluation (serving.metrics).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.memmgr import kv_cache as kvc
from repro.models import model as M


@dataclasses.dataclass
class Request:
    rid: int
    tenant: int
    prompt: np.ndarray
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    seq_slot: int = -1
    submit_step: int = 0
    finish_step: int = -1


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 8
    thres_max: int = 16          # silver quota scale
    decode_len_cap: int = 256


class ServingEngine:
    """CPU-scale reference engine (smoke/examples); the same scheduling laws
    drive the dry-run serve_step at production shapes."""

    def __init__(self, cfg: ModelConfig, run: RunConfig, params,
                 pool_cfg: kvc.PoolConfig, ecfg: EngineConfig = EngineConfig()):
        self.cfg = cfg
        self.run = run
        self.params = params
        self.pool_cfg = pool_cfg
        self.ecfg = ecfg
        self.pool = kvc.init(pool_cfg)
        self.queues: Dict[int, deque] = {}
        self.running: List[Request] = []
        self.finished: List[Request] = []
        self.step_count = 0
        self.silver_tenant = 0
        self.silver_left = 1
        self._free_slots = list(range(pool_cfg.max_seqs))
        self._decode = None
        self._prefill_cache: Dict[int, tuple] = {}

    # ------------------------------------------------------------- API
    def submit(self, req: Request):
        req.submit_step = self.step_count
        self.queues.setdefault(req.tenant, deque()).append(req)

    def _quota(self) -> Dict[int, int]:
        """Eq. (1) analogue over tenants with queued work."""
        w = {t: max(len(q), 1) * (1 + sum(1 for r in self.running
                                          if r.tenant == t))
             for t, q in self.queues.items() if q}
        tot = sum(w.values()) or 1
        return {t: max(self.ecfg.thres_max * v // tot, 1)
                for t, v in w.items()}

    # ------------------------------------------------------- scheduling
    def _admit(self):
        """Golden phase: admissions + page allocation first."""
        tenants = sorted(self.queues)
        # silver tenant first
        order = ([self.silver_tenant] +
                 [t for t in tenants if t != self.silver_tenant])
        for t in order:
            q = self.queues.get(t)
            while (q and len(self.running) < self.ecfg.max_batch
                   and self._free_slots):
                req = q.popleft()
                slot = self._free_slots.pop()
                self.pool, ok = kvc.admit_seq(
                    self.pool_cfg, self.pool, jnp.int32(slot),
                    jnp.int32(t), jnp.int32(len(req.prompt)))
                if not bool(ok):
                    self._free_slots.append(slot)
                    q.appendleft(req)
                    break
                req.seq_slot = slot
                self._prefill(req)
                self.running.append(req)

    def _prefill(self, req: Request):
        batch = {"tokens": jnp.asarray(req.prompt, jnp.int32)[None]}
        if self.cfg.n_patches:
            batch["patch_embeds"] = jnp.zeros(
                (1, self.cfg.n_patches, self.cfg.d_model), jnp.bfloat16)
        if self.cfg.is_enc_dec:
            batch["frames"] = jnp.zeros(
                (1, self.cfg.enc_len, self.cfg.d_model), jnp.bfloat16)
        logits, caches = M.forward_prefill(
            self.cfg, self.run, self.params, batch,
            max_len=self.pool_cfg.pages_per_seq * self.pool_cfg.page_size)
        tok = int(jnp.argmax(logits[0, -1]))
        req.out.append(tok)
        self._prefill_cache[req.rid] = caches

    def _select_decode_batch(self) -> List[Request]:
        quota = self._quota()
        silver = [r for r in self.running if r.tenant == self.silver_tenant]
        others = [r for r in self.running if r.tenant != self.silver_tenant]
        batch = silver[: max(self.silver_left, 0)] + others
        return batch[: self.ecfg.max_batch]

    def step(self):
        """One engine iteration: golden (admit/alloc) -> silver/normal decode."""
        self.step_count += 1
        self._admit()
        batch = self._select_decode_batch()
        if not batch:
            return
        done = []
        for req in batch:  # reference implementation decodes per-request
            caches = self._prefill_cache[req.rid]
            tok = jnp.asarray([[req.out[-1]]], jnp.int32)
            logits, caches = M.forward_decode(
                self.cfg, self.run, self.params, {"tokens": tok}, caches)
            self._prefill_cache[req.rid] = caches
            nxt = int(jnp.argmax(logits[0, -1]))
            req.out.append(nxt)
            self.pool, ok = kvc.append_token_alloc(
                self.pool_cfg, self.pool, jnp.int32(req.seq_slot))
            if len(req.out) >= min(req.max_new, self.ecfg.decode_len_cap):
                done.append(req)
        # silver rotation
        self.silver_left -= sum(1 for r in batch
                                if r.tenant == self.silver_tenant)
        if self.silver_left <= 0 and self.queues:
            tenants = sorted(set(list(self.queues) +
                                 [r.tenant for r in self.running]))
            if tenants:
                ix = (tenants.index(self.silver_tenant) + 1) % len(tenants) \
                    if self.silver_tenant in tenants else 0
                self.silver_tenant = tenants[ix]
                self.silver_left = self._quota().get(self.silver_tenant, 1)
        for req in done:
            req.finish_step = self.step_count
            self.running.remove(req)
            self.pool = kvc.release_seq(self.pool_cfg, self.pool,
                                        jnp.int32(req.seq_slot))
            self._free_slots.append(req.seq_slot)
            self._prefill_cache.pop(req.rid, None)
            self.finished.append(req)

    def run_until_drained(self, max_steps: int = 1000):
        for _ in range(max_steps):
            if not self.running and not any(self.queues.values()):
                break
            self.step()
        return self.finished
