"""Overload-tolerance laws (PR 10), deterministic tier-1 coverage:

* degradation ladder — quota / preempt / freeze rungs fire at their
  pool-pressure watermarks, in order, and freeze re-decides every step
* preemption — KV pages released exactly once, request conservation
  across evict/re-queue, seeded deterministic backoff, bounded retry
  budget (budget-exhausted requests become preemption-immune)
* safe mode — persistent prediction error degrades oracle -> static ->
  admit-all with hysteresis, and recovery re-engages
* serving fault injection — pool spikes occupy/release phantom pages,
  oracle stalls produce the "stalled" rung, poisoned profiles bust the
  oracle's tenant cache and restore afterwards
* churn staleness — a retired tenant leaves the oracle's memoized
  key-space immediately; a reused id re-resolves fresh
* many-tenant scale — the wide churn preset drives dozens of tenant
  lifecycles through one engine without losing a request

The property-based (hypothesis) versions of the conservation laws live
in test_preemption_properties.py; this module is the always-run core.
"""
import dataclasses

import numpy as np

from repro.memmgr import kv_cache as kvc
from repro.serving import metrics as smet
from repro.serving import stream as strm
from repro.serving.engine import (EngineConfig, Request, ServingEngine,
                                  backoff_steps, stub_forwards,
                                  stub_model_config)
from repro.serving.oracle import ContentionOracle, Recalibrator
from repro.serving.placement import (RUNGS, EngineView, OraclePlacement,
                                     PlacementPolicy)
from repro.sim.faults import (SERVING_FAULT_KINDS, ServingFault,
                              ServingFaultPlan, random_serving_plan)
from tests.test_serving_oracle import FakeOracle

POOL = kvc.PoolConfig(n_pages=64, page_size=8, n_kv=1, head_dim=4,
                      n_layers=1, max_seqs=8, pages_per_seq=4)


def _engine(ecfg=None, placement=None, profiles=None, pool=POOL,
            solo_hint=None):
    return ServingEngine(stub_model_config(), None, None, pool,
                         ecfg or EngineConfig(max_batch=4),
                         placement=placement, profiles=profiles,
                         forwards=stub_forwards(), solo_hint=solo_hint)


def _req(rid, tenant, plen=8, max_new=4):
    rng = np.random.RandomState(rid)
    return Request(rid=rid, tenant=tenant,
                   prompt=rng.randint(0, 64, plen), max_new=max_new)


def _view(step=8, queued=None, running=None, pressure=0.1,
          pages_by_tenant=None, max_batch=8, max_running=0,
          profiles=None):
    queued = queued or {}
    return EngineView(
        step=step, max_batch=max_batch, queued=queued,
        running=running or {}, waiting_since={t: 0 for t in queued},
        pool_used_frac=pressure, pool_free_seqs=8,
        profiles=profiles or {0: "heavy", 1: "interactive"},
        pages_by_tenant=pages_by_tenant or {},
        max_running=max_running)


FAIR = FakeOracle({frozenset({0}): 1.0, frozenset({1}): 1.0,
                   frozenset({0, 1}): 1.05})
UNFAIR = FakeOracle({frozenset({0}): 1.0, frozenset({1}): 1.0,
                     frozenset({0, 1}): 2.0})


# ------------------------------------------------------------- ladder
def test_rung_normal_below_watermarks():
    pol = OraclePlacement(FAIR)
    d = pol.refresh(_view(queued={0: 3, 1: 1}, pressure=0.2))
    assert d.rung == "normal" and not d.preempt


def test_rung_quota_tightens_decode_shares():
    pol = OraclePlacement(FAIR)
    lo = pol.refresh(_view(queued={0: 3, 1: 1}, pressure=0.2))
    pol2 = OraclePlacement(FAIR)
    hi = pol2.refresh(_view(queued={0: 3, 1: 1}, pressure=0.8))
    assert hi.rung == "quota"
    assert sum(hi.decode_quota.values()) <= sum(lo.decode_quota.values())
    assert all(q >= 1 for q in hi.decode_quota.values())


def test_rung_preempt_under_pressure_targets_page_heaviest():
    pol = OraclePlacement(FAIR)
    d = pol.refresh(_view(queued={0: 3, 1: 1}, running={0: 4, 1: 1},
                          pressure=0.93,
                          pages_by_tenant={0: 40, 1: 4}))
    assert d.rung == "preempt"
    assert d.preempt == {0: 1}            # page-heaviest tenant evicted


def test_rung_freeze_blocks_admission_and_redecides_every_step():
    pol = OraclePlacement(FAIR)
    d = pol.refresh(_view(queued={0: 3, 1: 1}, running={0: 4},
                          pressure=0.99, pages_by_tenant={0: 60}))
    assert d.rung == "freeze"
    assert d.allowed == () and d.default_cap == 0
    assert not pol.may_admit(0, 0)
    assert pol.due(pol._last_step + 1)    # frozen -> re-decide next step
    # pressure receded -> the very next refresh unfreezes
    d2 = pol.refresh(_view(step=9, queued={0: 3, 1: 1}, pressure=0.2))
    assert d2.rung != "freeze" and d2.allowed


def test_fairness_preemption_needs_full_running_set():
    """Fairness-triggered preemption (predicted slowdown over the
    threshold on the placement actually applied — the saturating-flood
    shape, where every candidate is bad and the pair is least-bad) only
    fires when admission caps can no longer help: running set full AND
    the victim has queued work."""
    saturated = FakeOracle({frozenset({0}): 2.5, frozenset({1}): 2.5,
                            frozenset({0, 1}): 2.0})
    pol = OraclePlacement(saturated, preempt_slowdown=1.6)
    # not full: caps handle it, no eviction
    d = pol.refresh(_view(queued={0: 3, 1: 1}, running={0: 2, 1: 1},
                          max_batch=8, pressure=0.2))
    assert d.chosen.tenants == (0, 1) and not d.preempt
    # full + victim queued: evict from the aggressor (min-slowdown side)
    d = pol.refresh(_view(step=30, queued={0: 3, 1: 2},
                          running={0: 7, 1: 1}, max_batch=8,
                          pressure=0.2))
    assert d.preempt == {0: 1} and d.rung == "preempt"


def test_ladder_rungs_are_declared():
    for d_rung in ("normal", "quota", "preempt", "freeze",
                   "stalled", "safe_static", "safe_open"):
        assert d_rung in RUNGS


# ------------------------------------------------------- preemption
def _preempting_policy(tenant=0, epoch_steps=2):
    class Force(PlacementPolicy):
        name = "force"

        def _decide(self, view):
            d = super()._decide(view)
            return dataclasses.replace(
                d, preempt={tenant: 1} if view.running.get(tenant) else {},
                rung="preempt" if view.running.get(tenant) else "normal")
    return Force(epoch_steps=epoch_steps)


def test_preemption_releases_pages_exactly_once():
    eng = _engine(placement=_preempting_policy(0))
    for i in range(3):
        eng.submit(_req(i, 0, max_new=30))
    free0 = kvc.pool_pressure(POOL, eng.pool).free_pages
    eng.run_until_drained(max_steps=400)
    assert eng.preemptions > 0
    assert eng.pending() == 0
    # every page came back exactly once: pool fully free after drain
    assert kvc.pool_pressure(POOL, eng.pool).free_pages == free0 == \
        POOL.n_pages
    assert len(eng._free_slots) == POOL.max_seqs
    cons = smet.conservation_report(eng)
    assert cons["ok"], cons


def test_preempted_request_conserved_and_reaccounted():
    eng = _engine(placement=_preempting_policy(0, epoch_steps=4))
    eng.submit(_req(0, 0, max_new=20))
    eng.run_until_drained(max_steps=400)
    (r,) = eng.finished
    assert r.retries > 0
    assert r.wasted_tokens > 0            # discarded work is accounted
    assert r.decoded == 20                # ...and fully redone
    assert r.first_token_step >= 0        # TTFT anchors the FIRST prefill


def test_retry_budget_grants_immunity_never_drops():
    eng = _engine(EngineConfig(max_batch=4, max_retries=2),
                  placement=_preempting_policy(0, epoch_steps=2))
    eng.submit(_req(0, 0, max_new=60))
    eng.run_until_drained(max_steps=600)
    (r,) = eng.finished
    assert r.retries == 2                 # stopped AT the budget
    assert r.decoded == 60


def test_backoff_deterministic_and_exponential():
    a = [backoff_steps(7, 3, k, base=2) for k in range(1, 6)]
    b = [backoff_steps(7, 3, k, base=2) for k in range(1, 6)]
    assert a == b                         # seeded: bit-identical
    base = [2 * 2 ** (k - 1) for k in range(1, 6)]
    assert all(bk <= ak < bk + 2 for ak, bk in zip(a, base))
    assert backoff_steps(8, 3, 1, 2) != backoff_steps(7, 3, 1, 2) or \
        backoff_steps(8, 4, 1, 2) != backoff_steps(7, 4, 1, 2)


def test_unparked_requests_rejoin_queue_front():
    eng = _engine()
    vic = _req(0, 0)
    vic.backoff_until = 0
    eng.parked.append(vic)
    eng.submit(_req(1, 0))
    eng._unpark()
    assert [r.rid for r in eng.queues[0]] == [0, 1]
    assert not eng.parked


# ---------------------------------------------------------- safe mode
def test_safe_mode_degrades_and_reengages_with_hysteresis():
    pol = OraclePlacement(UNFAIR, degrade_error=0.5, reengage_error=0.2,
                          error_window=2,
                          recalibrator=Recalibrator(alpha=0.01))
    view = _view(queued={0: 3, 1: 1}, pressure=0.2)
    pol.refresh(view)
    # two bad epochs (full window) -> level 1 (static caps)
    for _ in range(2):
        pol.observe({0: 8.0, 1: 8.0})
        pol.refresh(view)
    assert pol.safe_level == 1
    d = pol.decision
    assert d.rung == "safe_static"
    # two more -> level 2 (admit-all), rung safe_open
    for _ in range(2):
        pol.observe({0: 8.0, 1: 8.0})
        pol.refresh(view)
    assert pol.safe_level == 2
    assert pol.decision.rung == "safe_open"
    # shadow predictions still run: epochs matching the shadow
    # prediction (~1.0 here) re-engage one level at a time
    for _ in range(2):
        pol.observe({0: 1.0, 1: 1.0})
        pol.refresh(view)
    assert pol.safe_level == 1
    for _ in range(2):
        pol.observe({0: 1.0, 1: 1.0})
        pol.refresh(view)
    assert pol.safe_level == 0
    assert [lvl for _, lvl, _ in pol.mode_log] == [1, 2, 1, 0]


def test_safe_mode_requires_full_window():
    pol = OraclePlacement(UNFAIR, degrade_error=0.5, reengage_error=0.2,
                          error_window=3)
    view = _view(queued={0: 3, 1: 1})
    pol.refresh(view)
    pol.observe({0: 50.0, 1: 50.0})      # one horrible epoch
    assert pol.safe_level == 0           # ...is not enough evidence


def test_recalibrator_bounded_and_shrinks_error():
    rec = Recalibrator(alpha=0.5, bounds=(0.5, 4.0), max_step=1.5)
    for _ in range(40):
        rec.observe({0: 3.0}, {0: 1.0})  # oracle 3x optimistic
    assert rec.correction(0) <= 4.0      # range-clamped
    assert rec.correction(0) > 2.0       # ...but converging toward 3x
    rec.observe({0: float("nan")}, {0: 1.0})
    assert rec.rejected >= 1             # garbage feedback never lands


# ------------------------------------------------------ fault plans
def test_pool_spike_occupies_then_releases():
    plan = ServingFaultPlan(seed=0, faults=(
        ServingFault("pool_spike", step=2, duration=4,
                     pages=POOL.n_pages),))
    eng = _engine(EngineConfig(max_batch=4, fault_plan=plan))
    eng.submit(_req(0, 0, max_new=40))
    for _ in range(3):
        eng.step()
    spiked = kvc.pool_pressure(POOL, eng.pool)
    # the spike grabbed every free seq slot's worth of pages (slot-bound
    # on this geometry: 7 free slots x 4 pages on top of the live seq)
    assert spiked.pages_by_tenant.get(kvc.PHANTOM_ASID, 0) >= 24
    assert spiked.free_seqs == 0
    assert kvc.PHANTOM_ASID not in eng.view().pages_by_tenant
    eng.run_until_drained(max_steps=300)
    assert kvc.pool_pressure(POOL, eng.pool).free_pages == POOL.n_pages
    assert smet.conservation_report(eng)["ok"]
    assert ("pool_spike" in {k for _, k, _ in eng.fault_log})


def test_oracle_stall_fault_yields_stalled_rung():
    plan = ServingFaultPlan(seed=0, faults=(
        ServingFault("oracle_stall", step=2, duration=8),))
    pol = OraclePlacement(FAIR, epoch_steps=4)
    eng = _engine(EngineConfig(max_batch=4, fault_plan=plan),
                  placement=pol,
                  profiles={0: "heavy", 1: "interactive"})
    for i in range(4):
        eng.submit(_req(i, i % 2, max_new=12))
    for _ in range(16):
        eng.step()
    rungs = smet.rung_counts(eng.decisions)
    assert rungs.get("stalled", 0) >= 1
    eng.run_until_drained(max_steps=200)
    assert smet.conservation_report(eng)["ok"]


def test_profile_poison_swaps_then_restores():
    oracle = ContentionOracle(cycles=150, slots=2, pad_rows=8)
    plan = ServingFaultPlan(seed=0, faults=(
        ServingFault("profile_poison", step=3, duration=6, tenant=0,
                     profile="interactive"),))
    eng = _engine(EngineConfig(max_batch=4, fault_plan=plan),
                  placement=OraclePlacement(oracle, epoch_steps=4),
                  profiles={0: "heavy", 1: "interactive"})
    for i in range(4):
        eng.submit(_req(i, i % 2, max_new=16))
    for _ in range(5):
        eng.step()
    assert eng.profiles[0] == "interactive"          # poisoned
    assert oracle.tenant_benches().get(0) != "GUP"   # heavy's bench gone
    for _ in range(8):
        eng.step()
    assert eng.profiles[0] == "heavy"                # restored
    eng.run_until_drained(max_steps=200)
    assert smet.conservation_report(eng)["ok"]


def test_random_serving_plan_seeded_and_valid():
    a = random_serving_plan(3, n_steps=64, tenants=(0, 1, 2))
    b = random_serving_plan(3, n_steps=64, tenants=(0, 1, 2))
    assert a == b
    assert a != random_serving_plan(4, n_steps=64, tenants=(0, 1, 2))
    for f in a.faults:
        assert f.kind in SERVING_FAULT_KINDS
    a.validate((0, 1, 2))


def test_fault_run_bit_for_bit_deterministic():
    def run():
        plan = ServingFaultPlan(seed=1, faults=(
            ServingFault("pool_spike", step=4, duration=6, pages=40),
            ServingFault("oracle_stall", step=10, duration=4),))
        pol = OraclePlacement(FakeOracle(dict(FAIR.table)), epoch_steps=4)
        eng = _engine(EngineConfig(max_batch=4, max_running=6,
                                   fault_plan=plan), placement=pol,
                      profiles={0: "heavy", 1: "interactive"})
        for i in range(6):
            eng.submit(_req(i, i % 2, max_new=10))
        eng.run_until_drained(max_steps=300)
        return ([(r.rid, r.finish_step, r.retries) for r in eng.finished],
                [(d.step, d.rung, d.allowed) for d in eng.decisions],
                tuple(eng.fault_log), tuple(eng.preempt_log))
    assert run() == run()


# ------------------------------------------------- churn staleness
def test_retire_tenant_evicts_oracle_cache_immediately():
    oracle = ContentionOracle(cycles=150, slots=2, pad_rows=8)
    pol = OraclePlacement(oracle, epoch_steps=4)
    pol.refresh(_view(queued={0: 2, 1: 1},
                      profiles={0: "heavy", 1: "interactive"}))
    assert 0 in oracle.tenant_benches()
    pol.recalibrator._corr[0] = 2.0
    pol.retire(0)
    assert 0 not in oracle.tenant_benches()          # evicted NOW
    assert pol.recalibrator.correction(0) == 1.0     # calibration reset
    assert pol.stale((1,))                           # re-decide early
    # regression: the REUSED id re-resolves through its new profile
    pol.refresh(_view(step=20, queued={0: 2, 1: 1},
                      profiles={0: "batch", 1: "interactive"}))
    from repro.sim.profiles import bench_for_profile
    assert oracle.tenant_benches()[0] == bench_for_profile("batch")


def test_engine_retire_tenant_walks_through_placement():
    oracle = ContentionOracle(cycles=150, slots=2, pad_rows=8)
    eng = _engine(placement=OraclePlacement(oracle, epoch_steps=4),
                  profiles={0: "heavy", 1: "interactive"})
    eng.submit(_req(0, 0, max_new=4))
    eng.submit(_req(1, 1, max_new=4))
    eng.run_until_drained(max_steps=100)
    assert 0 in oracle.tenant_benches()
    eng.retire_tenant(0)
    assert 0 not in oracle.tenant_benches()
    assert 0 not in eng.profiles


# --------------------------------------------------------- streams
def test_many_tenants_preset_is_wide():
    tr = strm.make_trace("many_tenants", seed=0)
    assert len(tr.specs) >= 20            # "tens of tenants"
    assert len({s.tenant for s in tr.specs}) == len(tr.specs)


def test_churn_preset_shares_sim_timeline():
    from repro.sim.workloads import churn_schedule
    tr = strm.make_trace("churn", seed=3)
    sched = churn_schedule(seed=3, n_segments=6, n_slots=3,
                           arrival_rate=0.5, departure_rate=0.3)
    specs = strm.schedule_to_specs(sched, tr.steps // 6, rate=0.35,
                                   prompt_lens=(8,), max_new=6)
    assert tr.specs == specs              # one seeded timeline generator


def test_drive_retires_departed_tenants_and_conserves():
    tr = strm.make_trace("churn", seed=0, steps=60)
    oracle = ContentionOracle(cycles=150, slots=4, pad_rows=16)
    eng = _engine(EngineConfig(max_batch=4, max_running=6),
                  placement=OraclePlacement(oracle, epoch_steps=6),
                  profiles=tr.profiles(),
                  pool=kvc.PoolConfig(n_pages=128, page_size=8, n_kv=1,
                                      head_dim=4, n_layers=1, max_seqs=8,
                                      pages_per_seq=4))
    strm.drive(eng, tr, drain_steps=400)
    assert smet.conservation_report(eng)["ok"]
    # departed tenants (stop is not None and work drained) left the cache
    gone = [s.tenant for s in tr.specs if s.stop is not None
            and s.stop < eng.step_count]
    live = oracle.tenant_benches()
    assert gone and all(t not in live for t in gone)
