"""Vectorized memory-hierarchy simulator: a lane-fused one-cycle pipeline.

The cycle transition is composed of pure stages, each with its own state /
result NamedTuple so every layer is individually unit-testable:

  warp_sched           -- per-core GTO-like pick (oldest-ready-first): one
                          ready warp per core issues one memory instruction.
  translation_probe    -- per-core L1 TLB bank -> shared L2 TLB (+ bypass
                          cache) probes/fills, MSHR-style merging of
                          concurrent walks to the same (ASID, VPN) (Fig. 4's
                          multi-warp stalls), PWC lookups, and generation of
                          the page-walk PTE lanes.
  datapath_front       -- L1D hit draw + the DATA_WIDTH divergent line
                          addresses of the translated access.
  shared_memory_access -- ONE lane-flattened L2$ + DRAM round for ALL of a
                          cycle's sub-accesses: the walk_levels PTE lanes
                          and the DATA_WIDTH data lanes, (C*(L+K),) flat.
                          This used to be 8 back-to-back probe/fill/DRAM
                          pipelines per cycle; `tlb.access_fused` keeps the
                          cross-round semantics (later waves observing
                          earlier fills, per-(set, wave) fill ports, LRU
                          victim chains) inside the single batched call.
  translation_commit   -- walk latencies, walk-table install, translation
                          latency resolution.
  accumulate_stats     -- per-app counters behind the paper's tables and
                          figures, packed into one int32 plane + one
                          float32 plane + a 4-vector of shared counters,
                          each updated by a single segment-sum.

`step` is a thin composition of those stages plus warp retire and epoch
maintenance. Every design point (ideal / PWC / GPU-MMU / Static /
MASK±components, plus any user-registered composition) is this same
pipeline, dispatched on the design's two planes (`repro.core.design`):

  * the STATIC SIGNATURE (`cfg.design` — sizing, walk depth/table, epoch
    length, ideal-vs-not) picks the traced program structure; `cfg` is
    expected to carry the signature group's canonical design;
  * the traced `DesignParams` plane (`dp` — policy booleans, token
    budgets, DRAM quota ceiling) is selected on with `jnp.where` and
    masked probes/fills, never Python branches, so ONE compiled program
    serves every design in a signature group and a whole design x mix
    grid can be vmapped through it.

`n_apps` is arbitrary: the paper's 2-app pairs are just N=2.

All translation caches (L1 bank, L2 TLB, bypass cache, PWC, and the
line-addressed L2 data cache) share `core/tlb.py`'s probe/fill machinery;
the L1 bank is a TLBState with a leading (n_cores,) axis driven by the
direct bank kernels.

All state lives in `SimState` arrays -> the whole run is one lax.scan.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import bypass as bp_mod
from repro.core import dram_sched
from repro.core import page_table as pt_mod
from repro.core import tlb as tlb_mod
from repro.core import tokens as tok_mod
from repro.core.design import DesignParams
from repro.core.mask import static_partition_index
from repro.core.page_table import _mix
from repro.sim.config import SimConfig
from repro.sim.workloads import FIELD, gen_vpn

DATA_WIDTH = 4           # divergent cache lines per memory instruction
BIG = jnp.int32(1 << 30)
# the concurrent-page-walk table size (Table 1: 64) comes from
# cfg.design.translation.max_concurrent_walks

# packed walk-table columns: TransState.walk is (max_concurrent_walks, 4)
WVPN, WASID, WDONE, WMERGED = range(4)

# packed per-app int32 counter plane: StatState.ints is (n_apps, N_INT)
(I_L1_HIT, I_L1_MISS, I_L2_HIT, I_L2_MISS, I_BYP_HIT, I_BYP_PROBE,
 I_WALKS, I_DRAM_TLB_N, I_DRAM_DATA_N) = range(9)
N_INT = 9
# packed per-app float32 plane: StatState.floats is (n_apps, N_FLOAT)
F_WALK_LAT, F_STALL_PER_MISS, F_DRAM_TLB_LAT, F_DRAM_DATA_LAT = range(4)
N_FLOAT = 4
# shared (not per-app) counters: StatState.scalars is (N_SCALAR,)
S_L2C_TLB_HIT, S_L2C_TLB_PROBE, S_L2C_DATA_HIT, S_L2C_DATA_PROBE = range(4)
N_SCALAR = 4


# ---------------------------------------------------------------------------
# layered state
# ---------------------------------------------------------------------------

class TransState(NamedTuple):
    """Translation layer: TLB hierarchy + in-flight page-walk table."""
    l1: tlb_mod.TLBState         # per-core bank, leading axis (n_cores,)
    l2tlb: tlb_mod.TLBState
    bypass_tlb: tlb_mod.TLBState
    pwc: tlb_mod.TLBState        # page-walk cache (PTE lines)
    walk: jax.Array              # (max_concurrent_walks, 4) int32 packed
    #                              columns: WVPN, WASID, WDONE, WMERGED

    @property
    def walk_vpn(self) -> jax.Array:
        return self.walk[..., WVPN]

    @property
    def walk_asid(self) -> jax.Array:
        return self.walk[..., WASID]

    @property
    def walk_done(self) -> jax.Array:
        return self.walk[..., WDONE]

    @property
    def walk_merged(self) -> jax.Array:
        return self.walk[..., WMERGED]


class DataState(NamedTuple):
    """Shared data path: L2 data cache, DRAM, bypass accounting."""
    l2c: tlb_mod.TLBState        # line-addressed, reuses TLB machinery
    dram: dram_sched.DramState
    bypass: bp_mod.BypassState


class StatState(NamedTuple):
    """Cumulative counters, packed into three planes.

    `ints` / `floats` have the app axis first and the counter index last
    (the I_* / F_* constants), so one segment-sum over the per-core lane
    outcomes updates a whole plane; `scalars` holds the shared
    (non-per-app) L2$ counters (S_* constants). The legacy `s_*` names are
    kept as read-only views so stats consumers and tests are unchanged.
    """
    ints: jax.Array              # (n_apps, N_INT) int32
    floats: jax.Array            # (n_apps, N_FLOAT) float32
    scalars: jax.Array           # (N_SCALAR,) int32

    s_l1_hit = property(lambda s: s.ints[..., I_L1_HIT])
    s_l1_miss = property(lambda s: s.ints[..., I_L1_MISS])
    s_l2_hit = property(lambda s: s.ints[..., I_L2_HIT])
    s_l2_miss = property(lambda s: s.ints[..., I_L2_MISS])
    s_byp_hit = property(lambda s: s.ints[..., I_BYP_HIT])
    s_byp_probe = property(lambda s: s.ints[..., I_BYP_PROBE])
    s_walks = property(lambda s: s.ints[..., I_WALKS])
    s_dram_tlb_n = property(lambda s: s.ints[..., I_DRAM_TLB_N])
    s_dram_data_n = property(lambda s: s.ints[..., I_DRAM_DATA_N])
    s_walk_lat = property(lambda s: s.floats[..., F_WALK_LAT])
    s_stall_per_miss = property(lambda s: s.floats[..., F_STALL_PER_MISS])
    s_dram_tlb_lat = property(lambda s: s.floats[..., F_DRAM_TLB_LAT])
    s_dram_data_lat = property(lambda s: s.floats[..., F_DRAM_DATA_LAT])
    s_l2c_tlb_hit = property(lambda s: s.scalars[..., S_L2C_TLB_HIT])
    s_l2c_tlb_probe = property(lambda s: s.scalars[..., S_L2C_TLB_PROBE])
    s_l2c_data_hit = property(lambda s: s.scalars[..., S_L2C_DATA_HIT])
    s_l2c_data_probe = property(lambda s: s.scalars[..., S_L2C_DATA_PROBE])


class SimState(NamedTuple):
    t: jax.Array                 # () int32
    stall_until: jax.Array       # (W,) int32
    instr: jax.Array             # (W,) float32 retired instructions
    pos: jax.Array               # (W,) int32 stream position
    trans: TransState
    data: DataState
    tokens: tok_mod.TokenState
    stats: StatState
    # (n_apps,) int32 live ASID per application SLOT. Fixed mixes keep the
    # identity map (asid == slot); the segmented trace runner bumps a
    # slot's ASID by n_apps on every membership change, so an arriving
    # app gets a FRESH address space (its translations can never alias a
    # predecessor's) and a departed app's ASID is dead forever. Slot
    # recovery is always `asid % n_apps`.
    asid_of_app: jax.Array


def init_trans(cfg: SimConfig) -> TransState:
    tr = cfg.design.translation
    tok = cfg.design.tokens
    wt = tr.max_concurrent_walks
    return TransState(
        l1=tlb_mod.init_bank(cfg.n_cores, tr.l1_entries, tr.l1_entries),
        l2tlb=tlb_mod.init(tr.l2_entries, tr.l2_ways),
        bypass_tlb=tlb_mod.init(tok.bypass_cache_entries,
                                tok.bypass_cache_entries),
        pwc=tlb_mod.init(cfg.pwc_entries, cfg.pwc_ways),
        walk=jnp.tile(jnp.asarray([-1, -1, 0, 0], jnp.int32), (wt, 1)),
    )


def init_data(cfg: SimConfig) -> DataState:
    return DataState(
        l2c=tlb_mod.init(cfg.l2_sets * cfg.l2_ways, cfg.l2_ways),
        dram=dram_sched.init(cfg.n_channels, cfg.n_banks, cfg.n_apps),
        bypass=bp_mod.init(),
    )


def init_stats(n_apps: int) -> StatState:
    return StatState(
        ints=jnp.zeros((n_apps, N_INT), jnp.int32),
        floats=jnp.zeros((n_apps, N_FLOAT), jnp.float32),
        scalars=jnp.zeros((N_SCALAR,), jnp.int32),
    )


def init_state(cfg: SimConfig, dp: DesignParams) -> SimState:
    W = cfg.total_warps
    return SimState(
        t=jnp.zeros((), jnp.int32),
        stall_until=jnp.zeros((W,), jnp.int32),
        instr=jnp.zeros((W,), jnp.float32),
        pos=jnp.zeros((W,), jnp.int32),
        trans=init_trans(cfg),
        data=init_data(cfg),
        tokens=tok_mod.init(cfg.n_apps,
                            jnp.asarray(cfg.warps_per_app, jnp.int32),
                            dp.initial_frac),
        stats=init_stats(cfg.n_apps),
        asid_of_app=jnp.arange(cfg.n_apps, dtype=jnp.int32),
    )


# ---------------------------------------------------------------------------
# stage 1: warp scheduling
# ---------------------------------------------------------------------------

class SchedOut(NamedTuple):
    """One candidate memory instruction per core, all arrays (n_cores,)."""
    picked_warp: jax.Array       # global warp id
    slot: jax.Array              # warp slot within its core
    active: jax.Array            # bool: core found a ready warp
    app: jax.Array
    asid: jax.Array
    vpn: jax.Array
    pos: jax.Array               # stream position of the picked warp


def warp_sched(cfg: SimConfig, params_mat, stall_until, pos, t,
               asid_of_app=None) -> SchedOut:
    """GTO-like pick: per core, the ready warp that has waited longest.

    `asid_of_app` is the (n_apps,) live-ASID map carried in `SimState`;
    None means the identity map (asid == app slot), which is exactly what
    fixed-mix runs use — the gather then returns the slot ids bit-for-bit.
    """
    C, wpc = cfg.n_cores, cfg.warps_per_core
    ready = stall_until <= t
    waiting = jnp.where(ready, t - stall_until, -1)
    wait_grid = waiting.reshape(C, wpc)
    pick = jnp.argmax(wait_grid, axis=1)                  # (C,)
    picked_warp = jnp.arange(C) * wpc + pick
    active = wait_grid[jnp.arange(C), pick] >= 0          # (C,)

    app = jnp.asarray(cfg.app_of_core, jnp.int32)         # oracle split (§6)
    p = pos[picked_warp]
    vpn = gen_vpn(params_mat[app], app, picked_warp, p, t)
    # one address space per application slot occupancy (see SimState)
    asid = app if asid_of_app is None else asid_of_app[app]
    return SchedOut(picked_warp=picked_warp, slot=pick, active=active,
                    app=app, asid=asid, vpn=vpn, pos=p)


# ---------------------------------------------------------------------------
# stage 2a: translation probes (L1 TLB bank -> L2 TLB/bypass -> walk setup)
# ---------------------------------------------------------------------------

class TransProbe(NamedTuple):
    """Front half of translation: everything before the shared L2$/DRAM.

    Per-core arrays are (C,); the walk lanes are flattened wave-major
    ((walk_levels * C,), level slowest) so the shared memory stage can
    service them in one batched call. For the "ideal" design the walk
    machinery traces out entirely and the lane arrays are empty.
    """
    l1_hit: jax.Array
    l1_miss: jax.Array
    l2_hit: jax.Array
    byp_hit: jax.Array
    l2_hit_eff: jax.Array        # L2 or bypass-cache hit
    need_walk: jax.Array
    merged: jax.Array            # joined an in-flight walk
    merge_done: jax.Array        # completion time of the joined walk
    first_match: jax.Array       # walk-table slot of the joined walk
    new_walk: jax.Array          # started a fresh walk
    queue_pen: jax.Array         # finite-walker-thread queue penalty
    pwc_lat: jax.Array           # (C,) summed 5-cycle PWC-hit latencies
    walk_lines: jax.Array        # (L*C,) PTE line ids, wave-major
    walk_go: jax.Array           # (L*C,) bool: lanes that access the L2$
    walk_tags: jax.Array         # (L*C,) page-walk depth tags (§5.3)


def translation_probe(cfg: SimConfig, dp: DesignParams, trans: TransState,
                      tokens: tok_mod.TokenState, sched: SchedOut, t
                      ) -> Tuple[TransState, TransProbe]:
    """TLB hierarchy probes/fills + page-walk lane generation.

    Structural dispatch (ideal-vs-not) is by the static signature carried
    in `cfg.design`; every policy knob below that — shared-L2-TLB vs PWC
    vs walk-only organization, tokens on/off — is a traced `dp` flag
    selected with masked probes/fills (a probe or fill whose active mask
    is all-False is a state no-op), so all non-ideal designs share one
    compiled pipeline."""
    tr = cfg.design.translation
    ideal = tr.kind == "ideal"
    C = cfg.n_cores
    vpn, asid, active = sched.vpn, sched.asid, sched.active

    # ---------------- L1 TLB bank --------------------------------------
    l1, l1_hit = tlb_mod.probe_bank(trans.l1, vpn, asid, active, t)
    if ideal:
        l1_hit = active
    l1_miss = active & ~l1_hit

    zb = jnp.zeros((C,), bool)
    zi = jnp.zeros((C,), jnp.int32)
    if ideal:
        l2_hit = jnp.zeros_like(l1_miss)
        need_walk = l1_miss          # identically False (l1_hit == active)
        # need_walk is identically False: the walk lanes, MSHR table, and
        # walker queue model all trace out of the compiled graph
        return (TransState(l1=l1, l2tlb=trans.l2tlb,
                           bypass_tlb=trans.bypass_tlb,
                           pwc=trans.pwc, walk=trans.walk),
                TransProbe(l1_hit=l1_hit, l1_miss=l1_miss, l2_hit=l2_hit,
                           byp_hit=jnp.zeros_like(l2_hit),
                           l2_hit_eff=l2_hit,
                           need_walk=need_walk, merged=zb, merge_done=zi,
                           first_match=zi, new_walk=zb, queue_pen=zi,
                           pwc_lat=zi,
                           walk_lines=jnp.zeros((0,), jnp.int32),
                           walk_go=jnp.zeros((0,), bool),
                           walk_tags=jnp.zeros((0,), jnp.int32)))

    # ---------------- shared L2 TLB + bypass cache ---------------------
    # organization selectors are traced: non-participating caches are
    # probed/filled with an all-False mask (a state no-op yielding
    # all-False hits) — identical to skipping them. The bypass cache is
    # additionally wrapped in a lax.cond so token-less designs skip its
    # work at runtime (under a design-batched vmap the cond becomes a
    # select, which computes both branches but picks identical values)
    use_l2 = dp.use_l2_tlb
    l2tlb, l2_hit = tlb_mod.probe(trans.l2tlb, vpn, asid,
                                  l1_miss & use_l2, t)
    byp_tlb, byp_hit = jax.lax.cond(
        dp.tokens_on & use_l2,
        lambda st: tlb_mod.probe(st, vpn, asid, l1_miss & ~l2_hit, t),
        lambda st: (st, jnp.zeros_like(l1_miss)),
        trans.bypass_tlb)
    l2_hit_eff = l2_hit | byp_hit
    need_walk = l1_miss & ~l2_hit_eff

    # ---------------- TLB fills on walk return -------------------------
    # (independent of the walk's memory latency, so they live here).
    # Tokens are distributed round-robin over the app's cores in warpID
    # order: per-core allowance = tokens / cores_per_app. With tokens off
    # the gate is identically True (every walk may fill the L2 TLB).
    cores_per_app = jnp.asarray(cfg.cores_per_app, jnp.int32)
    tok_per_core = tokens.tokens[sched.app] // cores_per_app[sched.app]
    has_tok = sched.slot < tok_per_core
    gate = jnp.where(dp.tokens_on,
                     (has_tok & ~tokens.first_epoch) | tokens.first_epoch,
                     True)
    fill_l2 = need_walk & use_l2 & gate
    fill_byp = need_walk & use_l2 & ~gate    # ~gate implies tokens_on
    byp_tlb = jax.lax.cond(
        dp.tokens_on & use_l2,
        lambda st: tlb_mod.fill(st, vpn, asid, fill_byp, t),
        lambda st: st, byp_tlb)
    l2tlb = tlb_mod.fill(l2tlb, vpn, asid, fill_l2, t)

    l1 = tlb_mod.fill_bank(l1, vpn, asid, l1_miss, t)

    # ---------------- MSHR merge: outstanding walk for same (vpn, asid)?
    walk_vpn, walk_asid, walk_done = (trans.walk[:, WVPN],
                                      trans.walk[:, WASID],
                                      trans.walk[:, WDONE])
    wmatch = (walk_vpn[None, :] == vpn[:, None]) & \
             (walk_asid[None, :] == asid[:, None]) & \
             (walk_done[None, :] > t)
    merged = wmatch.any(axis=1) & need_walk
    merge_done = jnp.where(
        merged, jnp.max(jnp.where(wmatch, walk_done[None, :], 0), axis=1), 0)
    first_match = jnp.argmax(wmatch, axis=1)

    new_walk = need_walk & ~merged
    n_live = (walk_done > t).sum()
    # walker occupancy queue penalty (finite walker threads)
    wt = tr.max_concurrent_walks
    over = jnp.maximum(n_live + jnp.cumsum(new_walk) - wt, 0)
    queue_pen = over * 30

    # ---------------- page-walk lanes (walk_levels dependent PTE lines)
    L = tr.walk_levels
    pte_lines = pt_mod.pte_line_addresses(
        pt_mod.PageTableConfig(levels=L), asid, vpn)      # (C, L)
    walk_lines = pte_lines.T.reshape(L * C)               # wave-major
    walk_active = jnp.tile(new_walk, L)
    walk_tags = jnp.repeat(jnp.asarray(
        [pt_mod.walk_depth_tag(lv) for lv in range(L)], jnp.int32), C)

    # fused probe+fill with per-(set, level) fill ports — PTE lines are
    # unique across levels, so the PWC is tag-only too. The organization
    # selector is a lax.cond so non-PWC designs skip the whole PWC round
    # at runtime (pwc_hit all-False makes the lines below reduce to
    # walk_go = walk_active, pwc_lat = 0); under a design-batched vmap
    # the cond lowers to a select over identical per-design values.
    pwc, pwc_hit = jax.lax.cond(
        dp.use_pwc,
        lambda st: tlb_mod.access_fused(
            st, walk_lines, jnp.zeros_like(walk_lines), walk_active,
            jnp.ones((L * C,), bool), t, n_waves=L, track_asids=False,
            backend=cfg.tlb_backend)[:2],
        lambda st: (st, jnp.zeros((L * C,), bool)),
        trans.pwc)
    walk_go = walk_active & ~pwc_hit
    pwc_lat = 5 * (walk_active & pwc_hit).reshape(L, C) \
        .sum(0, dtype=jnp.int32)

    return (TransState(l1=l1, l2tlb=l2tlb, bypass_tlb=byp_tlb, pwc=pwc,
                       walk=trans.walk),
            TransProbe(l1_hit=l1_hit, l1_miss=l1_miss, l2_hit=l2_hit,
                       byp_hit=byp_hit, l2_hit_eff=l2_hit_eff,
                       need_walk=need_walk, merged=merged,
                       merge_done=merge_done, first_match=first_match,
                       new_walk=new_walk, queue_pen=queue_pen,
                       pwc_lat=pwc_lat, walk_lines=walk_lines,
                       walk_go=walk_go, walk_tags=walk_tags))


# ---------------------------------------------------------------------------
# stage 2b: data-path front (L1D draw + divergent line generation)
# ---------------------------------------------------------------------------

class DataFront(NamedTuple):
    """L1D outcome + the data lanes headed for the shared L2$."""
    l1d_hit: jax.Array           # (C,) bool
    go_l2d: jax.Array            # (C,) bool: reached the shared L2$
    lines: jax.Array             # (DATA_WIDTH*C,) line ids, wave-major


def datapath_front(cfg: SimConfig, params_mat, sched: SchedOut, t
                   ) -> DataFront:
    """Draw the L1D outcome and generate the divergent line addresses."""
    pfn = pt_mod.translate(pt_mod.PageTableConfig(), sched.asid, sched.vpn)
    r = _mix(pfn.astype(jnp.uint32) + sched.pos.astype(jnp.uint32))
    l1d_hit = (r % jnp.uint32(1024)).astype(jnp.int32) \
        < params_mat[sched.app, FIELD["l1d_hit_milli"]]
    # warp-wide (divergent) data access: one memory instruction touches
    # DATA_WIDTH cache lines, serviced in parallel (latency = max). This is
    # what gives data traffic its realistic flooding pressure on the shared
    # L2 relative to page-walk traffic.
    go_l2d = sched.active & ~l1d_hit
    lines = []
    for k in range(DATA_WIDTH):
        r3 = _mix(r + jnp.uint32((0x85EBCA6B + 0x9E3779B9 * k) & 0xFFFFFFFF))
        lines.append(pfn * 32 + (r3 % jnp.uint32(32)).astype(jnp.int32))
    return DataFront(l1d_hit=l1d_hit, go_l2d=go_l2d,
                     lines=jnp.stack(lines).reshape(DATA_WIDTH * pfn.shape[0]))


# ---------------------------------------------------------------------------
# stage 3: the ONE shared L2$ + DRAM round for all of a cycle's lanes
# ---------------------------------------------------------------------------

class MemOut(NamedTuple):
    """Per-core splits of the fused round (walk part + data part)."""
    walk_lat: jax.Array          # (C,) summed walk-level L2$/DRAM latency
    dram_tlb_lat: jax.Array      # (C,) float32 DRAM latency on walk path
    dram_tlb_n: jax.Array        # (C,) int32
    l2c_tlb_hit: jax.Array       # () walk-request hits in the L2$
    l2c_tlb_probe: jax.Array     # () walk-request probes of the L2$
    dlat: jax.Array              # (C,) max-over-lines data latency
    l2d_hit: jax.Array           # (C,) bool: any data line hit the L2$


def shared_memory_access(cfg: SimConfig, dp: DesignParams, data: DataState,
                         app, walk_lines, walk_go, walk_tags,
                         data_lines, go_l2d, t) -> Tuple[DataState, MemOut]:
    """Shared L2 data cache + DRAM for ALL of a cycle's sub-accesses.

    Lanes are flattened wave-major (walk level 0..L-1, then data line
    0..K-1, each wave C cores wide) so lane order equals the sequential
    model's program order: `tlb.access_fused` resolves cross-wave fills /
    forwarding inside one call, and `dram_sched.access`'s in-batch ranking
    gives walk (golden-class) requests priority over the same cycle's data
    requests. Either lane group may be empty (stage unit tests).
    """
    C = app.shape[0]
    nw = walk_lines.shape[0]
    nd = data_lines.shape[0]
    L, K = nw // C, nd // C

    lines = jnp.concatenate([walk_lines, data_lines])
    go = jnp.concatenate([walk_go, jnp.tile(go_l2d, K)])
    apps = jnp.tile(app, L + K)
    is_tlb = jnp.concatenate([jnp.ones((nw,), bool), jnp.zeros((nd,), bool)])
    depth = jnp.concatenate([walk_tags, jnp.zeros((nd,), jnp.int32)])

    l2c, dram, bp_state = data.l2c, data.dram, data.bypass
    # depth 0 (data) always fills, so one decision covers every lane;
    # with bypass off every lane may fill
    may_fill = jnp.where(dp.bypass_on,
                         bp_mod.should_fill(bp_state, depth), True)

    # `Static` gives each app an equal slice of the sets/channels by
    # restricting its index range; the selector is traced, so one program
    # serves both partitionings (both index computations are a handful of
    # integer lane ops)
    key = jnp.where(
        dp.static_part,
        static_partition_index(lines, cfg.l2_sets, cfg.n_apps, apps),
        lines % cfg.l2_sets)
    channel = jnp.where(
        dp.static_part,
        static_partition_index(lines, cfg.n_channels, cfg.n_apps, apps),
        lines % cfg.n_channels).astype(jnp.int32)

    # reuse TLB machinery: tag = full line id (unique, so the line cache
    # is tag-only and the ASID plane is skipped entirely)
    l2c, hit, _ = tlb_mod.access_fused(
        l2c, lines * cfg.l2_sets + key, jnp.zeros_like(lines), go,
        may_fill, t, n_waves=max(L + K, 1), track_asids=False,
        backend=cfg.tlb_backend)
    lat = jnp.where(hit, cfg.lat_l2_cache, 0)
    miss = go & ~hit

    bank = ((lines // cfg.n_channels) % cfg.n_banks).astype(jnp.int32)
    row = (lines // (cfg.n_channels * cfg.n_banks * 32)).astype(jnp.int32)
    dram, dram_lat = dram_sched.access(
        dram, channel, bank, row, apps, is_tlb, miss,
        mask_enabled=dp.dram_on, thres_max=dp.thres_max,
        waves=max(L + K, 1))
    lat = lat + jnp.where(miss, cfg.lat_l2_cache + dram_lat, 0)
    bp_state = bp_mod.record(bp_state, depth, hit, go)

    # ---------------- split back per core ------------------------------
    zi = jnp.zeros((C,), jnp.int32)
    zs = jnp.zeros((), jnp.int32)
    if nw:
        lat_w = lat[:nw].reshape(L, C)
        went = walk_go.reshape(L, C) & ~hit[:nw].reshape(L, C)
        walk_lat = lat_w.sum(0)          # inactive lanes contribute 0
        dram_tlb_lat = jnp.where(went, lat_w, 0).sum(0).astype(jnp.float32)
        dram_tlb_n = went.sum(0, dtype=jnp.int32)
        l2c_tlb_hit = (hit[:nw] & walk_go).sum(dtype=jnp.int32)
        l2c_tlb_probe = walk_go.sum(dtype=jnp.int32)
    else:
        walk_lat, dram_tlb_n, l2c_tlb_hit, l2c_tlb_probe = zi, zi, zs, zs
        dram_tlb_lat = jnp.zeros((C,), jnp.float32)
    if nd:
        dlat = lat[nw:].reshape(K, C).max(0)
        l2d_hit = hit[nw:].reshape(K, C).any(0)
    else:
        dlat = zi
        l2d_hit = jnp.zeros((C,), bool)

    return (DataState(l2c=l2c, dram=dram, bypass=bp_state),
            MemOut(walk_lat=walk_lat, dram_tlb_lat=dram_tlb_lat,
                   dram_tlb_n=dram_tlb_n, l2c_tlb_hit=l2c_tlb_hit,
                   l2c_tlb_probe=l2c_tlb_probe, dlat=dlat,
                   l2d_hit=l2d_hit))


# ---------------------------------------------------------------------------
# stage 4: translation commit (walk latency, walk-table install)
# ---------------------------------------------------------------------------

class TransOut(NamedTuple):
    """Per-core translation results + walk-level L2$ counters."""
    trans_lat: jax.Array         # (C,) translation latency
    l1_hit: jax.Array            # (C,) bool
    l1_miss: jax.Array
    l2_hit: jax.Array
    byp_hit: jax.Array
    l2_hit_eff: jax.Array        # L2 or bypass-cache hit
    need_walk: jax.Array
    merged: jax.Array            # joined an in-flight walk
    new_walk: jax.Array          # started a fresh walk
    walk_done_new: jax.Array     # (C,) completion time of fresh walks
    dram_tlb_lat: jax.Array      # (C,) float32 DRAM latency on walk path
    dram_tlb_n: jax.Array        # (C,) int32
    l2c_hit: jax.Array           # () walk-request hits in the L2$
    l2c_probe: jax.Array         # () walk-request probes of the L2$


def translation_commit(cfg: SimConfig, trans: TransState, probe: TransProbe,
                       mem: MemOut, sched: SchedOut, t
                       ) -> Tuple[TransState, TransOut]:
    """Resolve walk latencies, install fresh walks, settle trans latency."""
    des = cfg.design
    tr = des.translation
    C = cfg.n_cores

    if tr.kind == "ideal":
        trans_lat = jnp.where(sched.active, cfg.lat_l1_tlb, 0)
        zi = jnp.zeros((C,), jnp.int32)
        return trans, TransOut(
            trans_lat=trans_lat, l1_hit=probe.l1_hit, l1_miss=probe.l1_miss,
            l2_hit=probe.l2_hit, byp_hit=probe.byp_hit,
            l2_hit_eff=probe.l2_hit_eff, need_walk=probe.need_walk,
            merged=probe.merged, new_walk=probe.new_walk, walk_done_new=zi,
            dram_tlb_lat=jnp.zeros((C,), jnp.float32), dram_tlb_n=zi,
            l2c_hit=jnp.zeros((), jnp.int32),
            l2c_probe=jnp.zeros((), jnp.int32))

    walk_lat = mem.walk_lat + probe.pwc_lat + probe.queue_pen
    walk_done_new = t + cfg.lat_l2_tlb + walk_lat

    # install new walks into free slots (expired entries are free); lanes
    # that install nothing are routed out of bounds and dropped
    wt = tr.max_concurrent_walks
    free = trans.walk[:, WDONE] <= t
    order_slots = jnp.cumsum(probe.new_walk) - 1
    free_idx = jnp.where(free, jnp.arange(wt), BIG)
    free_sorted = jnp.sort(free_idx)
    slot_for = jnp.where(probe.new_walk,
                         free_sorted[jnp.clip(order_slots, 0, wt - 1)],
                         BIG)
    inst = probe.new_walk & (slot_for < wt)
    slot = jnp.where(inst, slot_for, wt).astype(jnp.int32)
    rows = jnp.stack([sched.vpn, sched.asid, walk_done_new,
                      jnp.ones((C,), jnp.int32)], axis=1)      # (C, 4)
    walk = trans.walk.at[slot].set(rows, mode="drop")
    # bump merge counters on the joined in-flight walks
    walk = walk.at[probe.first_match, WMERGED].add(
        jnp.where(probe.merged, 1, 0))

    # ---------------- translation latency ------------------------------
    trans_lat = jnp.where(
        probe.l1_hit, cfg.lat_l1_tlb,
        jnp.where(probe.l2_hit_eff, cfg.lat_l2_tlb,
                  jnp.where(probe.merged,
                            jnp.maximum(probe.merge_done - t, 1),
                            jnp.maximum(walk_done_new - t, 1))))

    return (trans._replace(walk=walk),
            TransOut(trans_lat=trans_lat, l1_hit=probe.l1_hit,
                     l1_miss=probe.l1_miss, l2_hit=probe.l2_hit,
                     byp_hit=probe.byp_hit, l2_hit_eff=probe.l2_hit_eff,
                     need_walk=probe.need_walk, merged=probe.merged,
                     new_walk=probe.new_walk, walk_done_new=walk_done_new,
                     dram_tlb_lat=mem.dram_tlb_lat,
                     dram_tlb_n=mem.dram_tlb_n, l2c_hit=mem.l2c_tlb_hit,
                     l2c_probe=mem.l2c_tlb_probe))


# ---------------------------------------------------------------------------
# data-path result assembly
# ---------------------------------------------------------------------------

class DataOut(NamedTuple):
    """Per-core data-access results, all arrays (n_cores,)."""
    data_lat: jax.Array
    l1d_hit: jax.Array
    go_l2d: jax.Array            # bool: reached the shared L2$
    dlat: jax.Array              # L2$/DRAM part of the latency
    l2d_hit: jax.Array           # bool: any of the lines hit the L2$


def _data_out(cfg: SimConfig, front: DataFront, mem: MemOut) -> DataOut:
    """Assemble the data-path result from the shared-round split."""
    data_lat = jnp.where(front.l1d_hit, cfg.lat_l1_data,
                         cfg.lat_l1_data + mem.dlat)
    return DataOut(data_lat=data_lat, l1d_hit=front.l1d_hit,
                   go_l2d=front.go_l2d, dlat=mem.dlat, l2d_hit=mem.l2d_hit)


# ---------------------------------------------------------------------------
# stage 5: statistics accumulation (packed planes, one segment-sum each)
# ---------------------------------------------------------------------------

def accumulate_stats(stats: StatState, n_apps: int, sched: SchedOut,
                     tout: TransOut, dout: DataOut, t) -> StatState:
    """Fold one cycle's per-core outcomes into the packed stat planes."""
    act = sched.active
    i32 = lambda x: x.astype(jnp.int32)  # noqa: E731
    ints_rows = jnp.stack([
        i32(tout.l1_hit), i32(tout.l1_miss), i32(tout.l2_hit),
        i32(tout.need_walk), i32(tout.byp_hit),
        i32(tout.l1_miss & ~tout.l2_hit), i32(tout.new_walk),
        tout.dram_tlb_n, i32(dout.go_l2d),
    ], axis=1) * act[:, None].astype(jnp.int32)
    floats_rows = jnp.stack([
        jnp.where(tout.new_walk,
                  (tout.walk_done_new - t).astype(jnp.float32), 0.0),
        tout.merged.astype(jnp.float32),
        tout.dram_tlb_lat,
        jnp.where(dout.go_l2d, dout.dlat, 0).astype(jnp.float32),
    ], axis=1) * act[:, None].astype(jnp.float32)
    return StatState(
        ints=stats.ints + jax.ops.segment_sum(ints_rows, sched.app,
                                              num_segments=n_apps),
        floats=stats.floats + jax.ops.segment_sum(floats_rows, sched.app,
                                                  num_segments=n_apps),
        scalars=stats.scalars + jnp.stack([
            tout.l2c_hit, tout.l2c_probe,
            (dout.go_l2d & dout.l2d_hit).sum(dtype=jnp.int32),
            dout.go_l2d.sum(dtype=jnp.int32)]),
    )


# ---------------------------------------------------------------------------
# retire + epoch maintenance
# ---------------------------------------------------------------------------

def retire(stall_until, instr, pos, sched: SchedOut, total_lat, gap, t):
    """Stall issued warps until their latency resolves; credit instrs."""
    w = sched.picked_warp
    stall_until = stall_until.at[w].set(
        jnp.where(sched.active, t + total_lat, stall_until[w]))
    instr = instr.at[w].add(
        jnp.where(sched.active, (1 + gap).astype(jnp.float32), 0.0))
    pos = pos.at[w].add(jnp.where(sched.active, 1, 0))
    return stall_until, instr, pos


def epoch_maintenance(cfg: SimConfig, dp: DesignParams, trans: TransState,
                      tokens: tok_mod.TokenState, data: DataState, t
                      ) -> Tuple[tok_mod.TokenState, DataState]:
    """Every epoch_cycles: token hill-climb, DRAM pressure, bypass latch.

    `trans` must be the PRE-update translation state: the walk table is
    sampled before this cycle's installs, matching the paper's epoch-end
    census of in-flight walks. The epoch length is static (signature);
    whether any adaptive mechanism is live is a traced `dp` predicate
    (under a design-batched vmap the cond becomes a select, which is fine
    — `do_epoch` is pure)."""
    na = cfg.n_apps

    def do_epoch(args):
        tokens, dram, bp = args
        warps_per_app = jnp.asarray(cfg.warps_per_app, jnp.int32)
        live = (trans.walk[:, WDONE] > t).astype(jnp.int32)
        census = jnp.stack([live, trans.walk[:, WMERGED] * live], axis=1)
        # slot recovery: ASIDs are slot + k*n_apps after churn (see
        # SimState.asid_of_app). Invalid rows (asid -1) land on slot
        # n_apps-1 but carry live=0, so they contribute nothing — same
        # sums as the pre-churn clip-to-0 routing, bit-for-bit.
        census = jax.ops.segment_sum(
            census, trans.walk[:, WASID] % na, num_segments=na)
        dram = dram_sched.update_pressure(dram, census[:, 0], census[:, 1])
        return (tok_mod.epoch_update(tokens, warps_per_app,
                                     step_frac=dp.step_frac), dram,
                bp_mod.epoch_update(bp))

    any_adaptive = dp.tokens_on | dp.dram_on | dp.bypass_on
    is_epoch = (t % cfg.design.epoch_cycles) == 0
    tokens, dram, bp_state = jax.lax.cond(
        is_epoch & any_adaptive,
        do_epoch, lambda args: args, (tokens, data.dram, data.bypass))
    return tokens, data._replace(dram=dram, bypass=bp_state)


# ---------------------------------------------------------------------------
# one-cycle transition: thin composition of the stages
# ---------------------------------------------------------------------------

def step(cfg: SimConfig, dp: DesignParams, params_mat,
         state: SimState) -> SimState:
    """One cycle. params_mat: (n_apps, N_FIELDS) int32 workload params;
    dp: the design's traced knob plane (see `repro.core.design`)."""
    t = state.t + 1
    sched = warp_sched(cfg, params_mat, state.stall_until, state.pos, t,
                       asid_of_app=state.asid_of_app)
    trans_st, probe = translation_probe(cfg, dp, state.trans, state.tokens,
                                        sched, t)
    dfront = datapath_front(cfg, params_mat, sched, t)
    data_st, mem = shared_memory_access(
        cfg, dp, state.data, sched.app, probe.walk_lines, probe.walk_go,
        probe.walk_tags, dfront.lines, dfront.go_l2d, t)
    trans_st, tout = translation_commit(cfg, trans_st, probe, mem, sched, t)
    dout = _data_out(cfg, dfront, mem)

    gap = params_mat[sched.app, FIELD["gap"]]
    total_lat = tout.trans_lat + dout.data_lat + gap
    stall_until, instr, pos = retire(
        state.stall_until, state.instr, state.pos, sched, total_lat, gap, t)

    tokens = tok_mod.record(state.tokens, sched.app, tout.l2_hit_eff,
                            tout.l1_miss)
    stats = accumulate_stats(state.stats, cfg.n_apps, sched, tout, dout, t)
    tokens, data_st = epoch_maintenance(cfg, dp, state.trans, tokens,
                                        data_st, t)

    return SimState(t=t, stall_until=stall_until, instr=instr, pos=pos,
                    trans=trans_st, data=data_st, tokens=tokens, stats=stats,
                    asid_of_app=state.asid_of_app)


# ---------------------------------------------------------------------------
# app churn: membership-change teardown at a segment boundary
# ---------------------------------------------------------------------------

def _flush_slots(st: tlb_mod.TLBState, change, n_apps: int
                 ) -> tlb_mod.TLBState:
    """ASID shootdown for every changed SLOT of an asid-tagged cache.

    Entries store generation-bumped ASIDs (slot + k*n_apps, see
    SimState.asid_of_app), so the kill predicate recovers the slot with
    `% n_apps`. Works on banked states too (extra leading axes). With an
    all-False change mask this is the identity, bit for bit.
    """
    slot = st.asids % n_apps
    kill = (st.asids >= 0) & change[slot]
    return st._replace(tags=jnp.where(kill, -1, st.tags),
                       asids=jnp.where(kill, -1, st.asids))


def apply_membership_change(cfg: SimConfig, dp: DesignParams,
                            state: SimState, change) -> SimState:
    """Teardown + cold-start for the slots flagged in `change` ((n_apps,)
    bool): the departing app's state is torn down and the slot is handed
    to its successor with a FRESH address space.

    Per paper §5.1 shootdown semantics plus the resource release MASK's
    mechanisms need:

      * L1 TLB bank / shared L2 TLB / bypass cache: every entry whose
        ASID maps to a changed slot is invalidated (no stale translations
        can survive — the departed generation's ASID is never reissued);
      * PWC: tag-only (no ASID plane), so it gets a conservative FULL
        flush whenever any slot changes — PTE lines of the dead address
        space are unidentifiable, and a real shootdown invalidates
        page-walk caches along with the TLBs;
      * walk table: in-flight walks of changed slots are cancelled;
      * tokens: changed rows release their TLB-fill tokens and restart
        from the InitialTokens state (fresh hill-climb); the shared
        `first_epoch` warm-up latch is deliberately left alone — it is
        a global bypass gate and re-arming it would perturb the apps
        that did NOT change;
      * DRAM pressure: the changed slots' Concurrent_i / WrpStalled_i
        inputs to the silver-quota Eq. (1) are zeroed until the next
        epoch census; the shared queues/open rows stay (they drain on
        their own and are not address-space state);
      * warps of changed slots rewind to a cold stream (pos 0, no
        retired instructions, ready immediately);
      * stat planes of changed slots reset — the arriving app starts
        with clean counters (the L2 data cache and the shared scalar
        counters are NOT per-address-space state and are untouched).

    Everything is a `jnp.where` on the change mask (plus one `change.any()`
    select for the PWC), so an all-False mask returns `state` bitwise
    unchanged — which is what makes constant-membership segmented runs
    float-hex identical to monolithic ones.
    """
    na = cfg.n_apps
    change = jnp.asarray(change, bool)
    any_c = change.any()

    trans = state.trans
    pwc = trans.pwc._replace(
        tags=jnp.where(any_c, jnp.full_like(trans.pwc.tags, -1),
                       trans.pwc.tags))
    walk_slot = trans.walk[:, WASID] % na
    walk_kill = (trans.walk[:, WASID] >= 0) & change[walk_slot]
    empty_row = jnp.asarray([-1, -1, 0, 0], jnp.int32)
    walk = jnp.where(walk_kill[:, None], empty_row[None, :], trans.walk)
    trans = trans._replace(
        l1=_flush_slots(trans.l1, change, na),
        l2tlb=_flush_slots(trans.l2tlb, change, na),
        bypass_tlb=_flush_slots(trans.bypass_tlb, change, na),
        pwc=pwc, walk=walk)

    fresh_tok = tok_mod.init(na, jnp.asarray(cfg.warps_per_app, jnp.int32),
                             dp.initial_frac)
    tok = state.tokens
    tok = tok._replace(
        tokens=jnp.where(change, fresh_tok.tokens, tok.tokens),
        direction=jnp.where(change, fresh_tok.direction, tok.direction),
        prev_miss_rate=jnp.where(change, fresh_tok.prev_miss_rate,
                                 tok.prev_miss_rate),
        epoch_hits=jnp.where(change, 0, tok.epoch_hits),
        epoch_misses=jnp.where(change, 0, tok.epoch_misses))

    dram = state.data.dram
    dram = dram._replace(
        conc_walks=jnp.where(change, 0, dram.conc_walks),
        warps_stalled=jnp.where(change, 0, dram.warps_stalled))

    warp_change = change[jnp.repeat(
        jnp.asarray(cfg.app_of_core, jnp.int32), cfg.warps_per_core)]
    stall_until = jnp.where(warp_change, state.t, state.stall_until)
    instr = jnp.where(warp_change, 0.0, state.instr)
    pos = jnp.where(warp_change, 0, state.pos)

    stats = state.stats._replace(
        ints=jnp.where(change[:, None], 0, state.stats.ints),
        floats=jnp.where(change[:, None], 0.0, state.stats.floats))

    return state._replace(
        stall_until=stall_until, instr=instr, pos=pos, trans=trans,
        data=state.data._replace(dram=dram), tokens=tok, stats=stats,
        asid_of_app=jnp.where(change, state.asid_of_app + na,
                              state.asid_of_app))
