"""Multi-tenant continuous-batching serving engine.

Requests from multiple tenants (ASIDs) share one model + one paged KV pool.
Scheduling is the paper's three-class discipline (repro.core.dram_sched
semantics transplanted to request admission, §5.4):

  Golden — translation/metadata work (page allocation, table updates,
           admission) always runs before token work each step.
  Silver — one tenant at a time gets guaranteed decode slots, quota
           proportional to Concurrent_i * Stalled_i (Eq. 1 analogue:
           in-flight sequences x queue depth).
  Normal — remaining decode slots round-robin over other tenants.

Admission is additionally gated by a pluggable placement policy
(serving.placement): once per decision epoch the policy — possibly
consulting the simulator-backed contention oracle (serving.oracle) —
decides which tenants may co-run and each tenant's admission cap;
decisions are recorded on `self.decisions` for the serving benchmark's
predicted-vs-achieved fairness accounting.

Overload tolerance (PR 10):

* Admission capacity and decode capacity are decoupled: up to
  `EngineConfig.max_running` requests may hold KV sequence slots while
  only `max_batch` decode per step (`max_running=None` keeps the legacy
  coupling). Decisions' per-tenant *decode quotas* then shape who gets
  the decode batch, enforced work-conservingly: a quota-throttled
  request still runs when slots would otherwise idle.
* Decisions may carry a *preemption directive*: the engine evicts a
  running victim — KV pages released through the jitted pool entry
  points exactly once, generated tokens discarded (and counted on
  `Request.wasted_tokens`: the re-prefill is honest re-accounting, not
  free work), and the request re-queued with seeded exponential backoff
  under a bounded retry budget. A request that exhausts its budget
  becomes immune to further preemption; nothing is ever dropped.
* Achieved per-tenant slowdowns for each closing decision epoch feed
  `placement.observe(...)` — the oracle policy's recalibration +
  safe-mode loop runs on exactly this signal.
* `EngineConfig.fault_plan` (`repro.sim.faults.ServingFaultPlan`)
  injects seeded overload faults at step boundaries: pool-exhaustion
  spikes (phantom KV sequences), oracle-latency stalls, poisoned tenant
  profiles. Deterministic and replayable bit-for-bit.

Per-tenant throughput / weighted-speedup metrics mirror the paper's
evaluation (serving.metrics).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, RunConfig
from repro.memmgr import kv_cache as kvc
from repro.models import model as M
from repro.serving.placement import (EngineView, PlacementDecision,
                                     PlacementPolicy)
from repro.sim.faults import ServingFaultPlan


@dataclasses.dataclass
class Request:
    rid: int
    tenant: int
    prompt: np.ndarray
    max_new: int                 # decode steps (prefill token not counted)
    out: List[int] = dataclasses.field(default_factory=list)
    seq_slot: int = -1
    submit_step: int = 0
    first_token_step: int = -1   # FIRST prefill emission step (TTFT anchor;
    #                              preserved across preemptions)
    finish_step: int = -1
    retries: int = 0             # times preempted so far
    backoff_until: int = 0       # parked until this engine step
    wasted_tokens: int = 0       # tokens discarded by preemptions

    @property
    def decoded(self) -> int:
        """Tokens produced by DECODE steps. `out` also holds the token
        the prefill emitted, so completion/throughput accounting uses
        this, not len(out) — a request runs exactly
        min(max_new, decode_len_cap) decode steps."""
        return max(len(self.out) - 1, 0)


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 8           # decode slots per step
    thres_max: int = 16          # silver quota scale
    decode_len_cap: int = 256
    # -- overload tolerance (PR 10) ------------------------------------
    max_running: Optional[int] = None   # admission bound (None: max_batch,
    #                                     the legacy coupled behavior)
    max_retries: int = 4         # preemptions allowed per request before
    #                              it becomes preemption-immune
    backoff_base: int = 2        # steps; backoff = base * 2^(retries-1) + jitter
    backoff_seed: int = 0        # seeds the deterministic backoff jitter
    fault_plan: Optional[ServingFaultPlan] = None


def stub_forwards():
    """Canonical token-compute stubs for the `forwards` seam: constant
    logits (argmax -> token 0), no KV tensors. Scheduling behavior —
    admission, silver rotation, placement, completion — is identical to
    a real model's; only the token values differ. Used by the serving
    benchmark and the engine scheduling-law tests."""
    def prefill(cfg, run, params, batch, max_len=None):
        return jnp.zeros((1, batch["tokens"].shape[1], 8)), {}

    def decode(cfg, run, params, batch, caches):
        return jnp.zeros((1, 1, 8)), caches
    return prefill, decode


def stub_model_config(vocab_size: int = 64):
    """Minimal cfg satisfying the engine's host-side checks (no real
    model fields needed when `forwards` is stubbed)."""
    import types
    return types.SimpleNamespace(n_patches=0, is_enc_dec=False,
                                 vocab_size=vocab_size)


def backoff_steps(seed: int, rid: int, retries: int, base: int) -> int:
    """Deterministic exponential backoff with seeded per-(request, retry)
    jitter: `base * 2^(retries-1) + jitter`, jitter in [0, base). Same
    (seed, rid, retries) -> same delay, bit for bit."""
    rng = np.random.RandomState(
        (seed * 1_000_003 + rid * 7_919 + retries) % (2 ** 31))
    return base * 2 ** max(retries - 1, 0) + int(rng.randint(0, max(base, 1)))


class ServingEngine:
    """CPU-scale reference engine (smoke/examples); the same scheduling laws
    drive the dry-run serve_step at production shapes."""

    def __init__(self, cfg: ModelConfig, run: RunConfig, params,
                 pool_cfg: kvc.PoolConfig, ecfg: EngineConfig = EngineConfig(),
                 placement: Optional[PlacementPolicy] = None,
                 profiles: Optional[Mapping[int, str]] = None,
                 forwards: Optional[Tuple] = None,
                 solo_hint: Optional[Mapping[int, float]] = None):
        self.cfg = cfg
        self.run = run
        self.params = params
        self.pool_cfg = pool_cfg
        self.ecfg = ecfg
        self.pool = kvc.init(pool_cfg)
        self.queues: Dict[int, deque] = {}
        self.running: List[Request] = []
        self.parked: List[Request] = []     # preempted, in backoff
        self.finished: List[Request] = []
        self.step_count = 0
        self.silver_tenant = 0
        self.silver_left = 1
        self.placement = placement if placement is not None \
            else PlacementPolicy()
        self.profiles: Dict[int, str] = dict(profiles or {})
        self.decisions: List[PlacementDecision] = []
        # mean solo latency per tenant (steps): the achieved-slowdown
        # anchor fed back to the policy; without it an intrinsic proxy
        # (decode length) is used
        self.solo_hint: Dict[int, float] = dict(solo_hint or {})
        self._free_slots = list(range(pool_cfg.max_seqs))
        self._decode = None
        self._prefill_cache: Dict[int, tuple] = {}
        self._silver_quota_used = 0
        # overload accounting / fault state
        self.submitted = 0
        self.preemptions = 0
        self.preempt_log: List[Tuple[int, int, int]] = []  # (step, tenant, rid)
        self.fault_log: List[Tuple[int, str, int]] = []    # (step, kind, tenant)
        self._phantoms: List[Tuple[int, int]] = []         # (slot, release_step)
        self._poisons: List[Tuple[int, int, str]] = []     # (restore, t, orig)
        self._epoch_finished: List[Request] = []
        # (prefill_fn, decode_fn) seam: benchmarks/tests that measure
        # SCHEDULING (steps, not wall-clock) stub the token compute
        self._fwd_prefill, self._fwd_decode = (
            forwards if forwards is not None
            else (M.forward_prefill, M.forward_decode))

    @property
    def max_running(self) -> int:
        """Admission bound: sequences that may hold KV slots at once
        (decode capacity stays `max_batch` per step)."""
        return self.ecfg.max_running or self.ecfg.max_batch

    # ------------------------------------------------------------- API
    def submit(self, req: Request):
        req.submit_step = self.step_count
        self.submitted += 1
        self.queues.setdefault(req.tenant, deque()).append(req)

    def retire_tenant(self, tenant: int):
        """The tenant departed for good (stream churn): the placement
        layer must never place it again, and its profile resolution
        leaves the oracle's memoized key-space immediately."""
        self.profiles.pop(tenant, None)
        self.solo_hint.pop(tenant, None)
        if not self.queues.get(tenant):
            self.queues.pop(tenant, None)
        self.placement.retire(tenant)

    def pending(self) -> int:
        """Requests not yet finished: queued + running + parked.
        (The conservation invariant: submitted == pending + finished.)"""
        return (len(self.running) + len(self.parked)
                + sum(len(q) for q in self.queues.values()))

    def _running_count(self, tenant: int) -> int:
        return sum(1 for r in self.running if r.tenant == tenant)

    def view(self) -> EngineView:
        """Host-side snapshot the placement policy decides from.
        Parked (preempted, backing off) requests count as queued — they
        are waiting work the policy must plan for. Phantom fault
        sequences inflate pool pressure (that is the fault) but are not
        attributed to any tenant."""
        pressure = kvc.pool_pressure(self.pool_cfg, self.pool)
        queued = {t: len(q) for t, q in self.queues.items()}
        waiting = {t: q[0].submit_step
                   for t, q in self.queues.items() if q}
        for r in self.parked:
            queued[r.tenant] = queued.get(r.tenant, 0) + 1
            waiting[r.tenant] = min(waiting.get(r.tenant, r.submit_step),
                                    r.submit_step)
        return EngineView(
            step=self.step_count,
            max_batch=self.ecfg.max_batch,
            queued=queued,
            running={t: self._running_count(t)
                     for t in {r.tenant for r in self.running}},
            waiting_since=waiting,
            pool_used_frac=pressure.used_frac,
            pool_free_seqs=pressure.free_seqs,
            profiles=self.profiles,
            pool_free_pages=pressure.free_pages,
            pages_by_tenant={t: n for t, n in pressure.pages_by_tenant.items()
                             if t != kvc.PHANTOM_ASID},
            max_running=self.max_running)

    def _quota(self) -> Dict[int, int]:
        """Eq. (1) analogue over tenants with queued work."""
        w = {t: max(len(q), 1) * (1 + sum(1 for r in self.running
                                          if r.tenant == t))
             for t, q in self.queues.items() if q}
        tot = sum(w.values()) or 1
        return {t: max(self.ecfg.thres_max * v // tot, 1)
                for t, v in w.items()}

    # ------------------------------------------------------- scheduling
    def _unpark(self):
        """Parked requests whose backoff expired rejoin the FRONT of
        their tenant queue (they were already admitted once)."""
        due = [r for r in self.parked if r.backoff_until <= self.step_count]
        for r in reversed(due):
            self.queues.setdefault(r.tenant, deque()).appendleft(r)
        for r in due:
            self.parked.remove(r)

    def _admit(self):
        """Golden phase: admissions + page allocation first. The
        placement decision gates every admission: a tenant outside the
        epoch's allowed set, or at its admission cap, keeps queueing
        (its running requests still decode — caps are admission-only)."""
        self._unpark()
        tenants = sorted(self.queues)
        # silver tenant first
        order = ([self.silver_tenant] +
                 [t for t in tenants if t != self.silver_tenant])
        for t in order:
            q = self.queues.get(t)
            while (q and len(self.running) < self.max_running
                   and self._free_slots
                   and self.placement.may_admit(t, self._running_count(t))):
                req = q.popleft()
                slot = self._free_slots.pop()
                self.pool, ok = kvc.admit_seq_jit(
                    self.pool_cfg, self.pool, jnp.int32(slot),
                    jnp.int32(t), jnp.int32(len(req.prompt)))
                if not bool(ok):
                    self._free_slots.append(slot)
                    q.appendleft(req)
                    break
                req.seq_slot = slot
                self._prefill(req)
                self.running.append(req)

    def _prefill(self, req: Request):
        batch = {"tokens": jnp.asarray(req.prompt, jnp.int32)[None]}
        if self.cfg.n_patches:
            batch["patch_embeds"] = jnp.zeros(
                (1, self.cfg.n_patches, self.cfg.d_model), jnp.bfloat16)
        if self.cfg.is_enc_dec:
            batch["frames"] = jnp.zeros(
                (1, self.cfg.enc_len, self.cfg.d_model), jnp.bfloat16)
        logits, caches = self._fwd_prefill(
            self.cfg, self.run, self.params, batch,
            max_len=self.pool_cfg.pages_per_seq * self.pool_cfg.page_size)
        tok = int(jnp.argmax(logits[0, -1]))
        req.out.append(tok)
        if req.first_token_step < 0:    # TTFT anchors to the FIRST prefill
            req.first_token_step = self.step_count
        self._prefill_cache[req.rid] = caches

    # ------------------------------------------------------- preemption
    def _preempt_one(self, tenant: int) -> bool:
        """Evict one of `tenant`'s running requests: KV pages released
        exactly once through the jitted pool entry point, generated
        tokens discarded (counted as wasted — the later re-prefill is
        honest re-accounting), request parked under seeded exponential
        backoff. Requests that exhausted the retry budget are immune;
        returns False when no victim is eligible."""
        cands = [r for r in self.running
                 if r.tenant == tenant and r.retries < self.ecfg.max_retries]
        if not cands:
            return False
        # least progress lost: evict the request with the fewest decoded
        # tokens (deterministic tie-break on submit order, then rid)
        req = min(cands, key=lambda r: (r.decoded, -r.submit_step, r.rid))
        self.running.remove(req)
        self.pool = kvc.release_seq_jit(self.pool_cfg, self.pool,
                                        jnp.int32(req.seq_slot))
        self._free_slots.append(req.seq_slot)
        self._prefill_cache.pop(req.rid, None)
        req.wasted_tokens += len(req.out)
        req.out.clear()
        req.seq_slot = -1
        req.retries += 1
        req.backoff_until = self.step_count + backoff_steps(
            self.ecfg.backoff_seed, req.rid, req.retries,
            self.ecfg.backoff_base)
        self.parked.append(req)
        self.preemptions += 1
        self.preempt_log.append((self.step_count, tenant, req.rid))
        return True

    def _execute_preemptions(self, decision: PlacementDecision):
        for t, k in sorted(decision.preempt.items()):
            for _ in range(k):
                if not self._preempt_one(t):
                    break

    # ------------------------------------------------- epoch feedback
    def _observe_epoch(self):
        """Achieved per-tenant slowdowns over the closing epoch's
        finished requests, fed to the placement policy (recalibration +
        safe-mode input). Slowdown anchor: `solo_hint` mean solo latency
        when known, else the request's intrinsic decode length (its
        un-contended latency is ~1 token/step)."""
        fin, self._epoch_finished = self._epoch_finished, []
        if not fin:
            return
        lat: Dict[int, List[Request]] = {}
        for r in fin:
            lat.setdefault(r.tenant, []).append(r)
        achieved: Dict[int, float] = {}
        for t, rs in lat.items():
            mean = sum(r.finish_step - r.submit_step + 1
                       for r in rs) / len(rs)
            solo = self.solo_hint.get(t)
            if not solo or solo <= 0:
                solo = max(sum(min(r.max_new, self.ecfg.decode_len_cap)
                               for r in rs) / len(rs), 1.0)
            achieved[t] = mean / solo
        self.placement.observe(achieved)

    # --------------------------------------------------- fault injection
    def _apply_faults(self):
        """Expire standing serving faults, then fire this step's
        (seeded plan on `EngineConfig.fault_plan`)."""
        for slot, rel in list(self._phantoms):
            if rel <= self.step_count:
                self.pool = kvc.release_seq_jit(self.pool_cfg, self.pool,
                                                jnp.int32(slot))
                self._free_slots.append(slot)
                self._phantoms.remove((slot, rel))
        for rel, t, orig in list(self._poisons):
            if rel <= self.step_count:
                self.profiles[t] = orig
                self._evict_profile(t)
                self._poisons.remove((rel, t, orig))
        plan = self.ecfg.fault_plan
        if plan is None:
            return
        for f in plan.at_step(self.step_count):
            self.fault_log.append((self.step_count, f.kind, f.tenant))
            if f.kind == "oracle_stall":
                self.placement.stall_until = self.step_count + f.duration
                self.placement.invalidate()   # re-decide into the stall now
            elif f.kind == "profile_poison":
                orig = self.profiles.get(f.tenant, "batch")
                self._poisons.append(
                    (self.step_count + f.duration, f.tenant, orig))
                self.profiles[f.tenant] = f.profile
                self._evict_profile(f.tenant)
            elif f.kind == "pool_spike":
                pages = f.pages or self.pool_cfg.n_pages // 2
                self.pool, slots = kvc.occupy_pages(
                    self.pool_cfg, self.pool, self._free_slots, pages)
                rel = self.step_count + f.duration
                self._phantoms.extend((s, rel) for s in slots)

    def _evict_profile(self, tenant: int):
        """Bust the oracle's tenant->bench resolution for `tenant` (its
        declared profile changed) and force an early re-decision."""
        oracle = getattr(self.placement, "oracle", None)
        if oracle is not None:
            oracle.evict_tenant(tenant)
        self.placement.invalidate()

    # ----------------------------------------------------------- decode
    def _select_decode_batch(self) -> List[Request]:
        """Silver quota first, then normal-class round over the rest.
        Silver requests beyond the quota backfill as NORMAL class: they
        run only when slots would otherwise go unused and do not burn
        silver quota (`_silver_quota_used` counts only the quota-class
        head of the batch).

        Placement decode quotas shape the batch work-conservingly in two
        passes: pass 1 respects each tenant's quota, pass 2 backfills
        idle decode slots with throttled requests — shaping only ever
        redistributes a CONTENDED batch, never idles a slot."""
        silver = [r for r in self.running if r.tenant == self.silver_tenant]
        others = [r for r in self.running if r.tenant != self.silver_tenant]
        quota_n = min(len(silver), max(self.silver_left, 0))
        ordered = silver[:quota_n] + others + silver[quota_n:]
        d = self.placement.decision
        dq = dict(d.decode_quota) if d is not None and d.decode_quota else {}
        if not dq:
            batch = ordered[: self.ecfg.max_batch]
        else:
            batch, used = [], {}
            for r in ordered:                      # pass 1: quota-respecting
                if len(batch) >= self.ecfg.max_batch:
                    break
                cap = dq.get(r.tenant)
                if cap is None or used.get(r.tenant, 0) < cap:
                    batch.append(r)
                    used[r.tenant] = used.get(r.tenant, 0) + 1
            if len(batch) < self.ecfg.max_batch:   # pass 2: backfill
                taken = {id(r) for r in batch}
                for r in ordered:
                    if len(batch) >= self.ecfg.max_batch:
                        break
                    if id(r) not in taken:
                        batch.append(r)
        head_ids = {id(r) for r in silver[:quota_n]}
        self._silver_quota_used = sum(1 for r in batch if id(r) in head_ids)
        return batch

    def step(self):
        """One engine iteration: faults -> placement epoch (feedback,
        re-decision, preemptions) -> golden (admit/alloc) -> silver/
        normal decode under quotas."""
        self.step_count += 1
        self._apply_faults()
        active = tuple(sorted({t for t, q in self.queues.items() if q}
                              | {r.tenant for r in self.running}
                              | {r.tenant for r in self.parked}))
        if self.placement.due(self.step_count) or self.placement.stale(active):
            self._observe_epoch()
            decision = self.placement.refresh(self.view())
            self.decisions.append(decision)
            if decision.preempt:
                self._execute_preemptions(decision)
        self._admit()
        batch = self._select_decode_batch()
        if not batch:
            return
        done = []
        for req in batch:  # reference implementation decodes per-request
            caches = self._prefill_cache[req.rid]
            tok = jnp.asarray([[req.out[-1]]], jnp.int32)
            logits, caches = self._fwd_decode(
                self.cfg, self.run, self.params, {"tokens": tok}, caches)
            self._prefill_cache[req.rid] = caches
            nxt = int(jnp.argmax(logits[0, -1]))
            req.out.append(nxt)
            self.pool, ok = kvc.append_token_alloc_jit(
                self.pool_cfg, self.pool, jnp.int32(req.seq_slot))
            if req.decoded >= min(req.max_new, self.ecfg.decode_len_cap):
                done.append(req)
        # silver rotation: only quota-class decodes burn quota (backfilled
        # silver requests ran as normal class)
        self.silver_left -= self._silver_quota_used
        if self.silver_left <= 0 and self.queues:
            tenants = sorted(set(list(self.queues) +
                                 [r.tenant for r in self.running]))
            if tenants:
                ix = (tenants.index(self.silver_tenant) + 1) % len(tenants) \
                    if self.silver_tenant in tenants else 0
                self.silver_tenant = tenants[ix]
                self.silver_left = self._quota().get(self.silver_tenant, 1)
        for req in done:
            req.finish_step = self.step_count
            self.running.remove(req)
            self.pool = kvc.release_seq_jit(self.pool_cfg, self.pool,
                                            jnp.int32(req.seq_slot))
            self._free_slots.append(req.seq_slot)
            self._prefill_cache.pop(req.rid, None)
            self.finished.append(req)
            self._epoch_finished.append(req)

    def run_until_drained(self, max_steps: int = 1000):
        for _ in range(max_steps):
            if self.pending() == 0:
                break
            self.step()
        return self.finished
