"""Vectorized memory-hierarchy simulator: one-cycle transition function.

Per cycle: each shader core's scheduler (GTO-like: oldest-ready-first) picks
one ready warp, which issues one memory instruction. The request flows
through: per-core L1 TLB -> shared L2 TLB (+ bypass cache) -> page walker
(4 dependent PTE accesses through the shared L2 data cache / DRAM) -> data
access (L1D -> shared L2 -> DRAM). Warps stall until their latency resolves;
concurrent walks to the same (ASID, VPN) merge MSHR-style (Fig. 4's
multi-warp stalls). Every design point of the paper (ideal / PWC / GPU-MMU /
Static / MASK±components) is this same function with different switches.

All state lives in `SimState` arrays -> the whole run is one lax.scan.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import bypass as bp_mod
from repro.core import dram_sched
from repro.core import page_table as pt_mod
from repro.core import tlb as tlb_mod
from repro.core import tokens as tok_mod
from repro.core.page_table import _mix
from repro.sim.config import SimConfig
from repro.sim.workloads import N_FIELDS, gen_vpn

WALK_TABLE = 64          # concurrent page walks (Table 1)
BIG = jnp.int32(1 << 30)


class SimState(NamedTuple):
    t: jax.Array                 # () int32
    stall_until: jax.Array       # (W,) int32
    instr: jax.Array             # (W,) int64-ish float32 retired instructions
    pos: jax.Array               # (W,) int32 stream position
    l1_tags: jax.Array           # (cores, L1E) int32 vpn
    l1_asid: jax.Array           # (cores, L1E) int32
    l1_lru: jax.Array            # (cores, L1E) int32
    l2tlb: tlb_mod.TLBState
    bypass_tlb: tlb_mod.TLBState
    pwc: tlb_mod.TLBState        # page-walk cache (PTE lines)
    l2c: tlb_mod.TLBState        # shared L2 data cache (line-addressed)
    tokens: tok_mod.TokenState
    bypass: bp_mod.BypassState
    dram: dram_sched.DramState
    walk_vpn: jax.Array          # (WALK_TABLE,) int32
    walk_asid: jax.Array         # (WALK_TABLE,)
    walk_done: jax.Array         # (WALK_TABLE,) int32 completion time
    walk_merged: jax.Array       # (WALK_TABLE,) int32 warps merged onto walk
    # statistics
    s_l1_hit: jax.Array          # (n_apps,)
    s_l1_miss: jax.Array
    s_l2_hit: jax.Array
    s_l2_miss: jax.Array
    s_byp_hit: jax.Array         # bypass-cache hits
    s_byp_probe: jax.Array       # bypass-cache probes
    s_walk_lat: jax.Array        # (n_apps,) float32 summed walk latency
    s_walks: jax.Array           # (n_apps,)
    s_stall_per_miss: jax.Array  # accumulated merged-warp counts
    s_dram_tlb_lat: jax.Array    # (n_apps,) float32
    s_dram_tlb_n: jax.Array
    s_dram_data_lat: jax.Array
    s_dram_data_n: jax.Array
    s_l2c_tlb_hit: jax.Array     # () cumulative L2$ hits for walk requests
    s_l2c_tlb_probe: jax.Array
    s_l2c_data_hit: jax.Array
    s_l2c_data_probe: jax.Array


def init_state(cfg: SimConfig) -> SimState:
    W = cfg.total_warps
    m = cfg.design.mask
    na = cfg.n_apps
    z = lambda *s: jnp.zeros(s, jnp.int32)  # noqa: E731
    zf = lambda *s: jnp.zeros(s, jnp.float32)  # noqa: E731
    warps_per_app = jnp.full((na,), W // na, jnp.int32)
    return SimState(
        t=jnp.zeros((), jnp.int32),
        stall_until=z(W),
        instr=zf(W),
        pos=z(W),
        l1_tags=jnp.full((cfg.n_cores, m.l1_tlb_entries), -1, jnp.int32),
        l1_asid=jnp.full((cfg.n_cores, m.l1_tlb_entries), -1, jnp.int32),
        l1_lru=z(cfg.n_cores, m.l1_tlb_entries),
        l2tlb=tlb_mod.init(m.l2_tlb_entries, m.l2_tlb_ways),
        bypass_tlb=tlb_mod.init(m.bypass_cache_entries,
                                m.bypass_cache_entries),
        pwc=tlb_mod.init(cfg.pwc_entries, cfg.pwc_ways),
        l2c=tlb_mod.init(cfg.l2_sets * cfg.l2_ways, cfg.l2_ways),
        tokens=tok_mod.init(na, warps_per_app, m.initial_token_frac),
        bypass=bp_mod.init(),
        dram=dram_sched.init(cfg.n_channels, cfg.n_banks, na),
        walk_vpn=jnp.full((WALK_TABLE,), -1, jnp.int32),
        walk_asid=jnp.full((WALK_TABLE,), -1, jnp.int32),
        walk_done=z(WALK_TABLE),
        walk_merged=z(WALK_TABLE),
        s_l1_hit=z(na), s_l1_miss=z(na), s_l2_hit=z(na), s_l2_miss=z(na),
        s_byp_hit=z(na), s_byp_probe=z(na),
        s_walk_lat=zf(na), s_walks=z(na), s_stall_per_miss=zf(na),
        s_dram_tlb_lat=zf(na), s_dram_tlb_n=z(na),
        s_dram_data_lat=zf(na), s_dram_data_n=z(na),
        s_l2c_tlb_hit=z(), s_l2c_tlb_probe=z(),
        s_l2c_data_hit=z(), s_l2c_data_probe=z(),
    )


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _per_core_l1_probe(tags, asids, lru, vpn, asid, t):
    """FA L1 TLB probe+LRU for one request per core. tags: (C, E)."""
    match = (tags == vpn[:, None]) & (asids == asid[:, None])
    hit = match.any(axis=1)
    way = jnp.argmax(match, axis=1)
    cidx = jnp.arange(tags.shape[0])
    lru = lru.at[cidx, way].set(jnp.where(hit, t, lru[cidx, way]))
    return hit, lru


def _per_core_l1_fill(tags, asids, lru, vpn, asid, do_fill, t):
    victim = jnp.argmin(lru, axis=1)
    cidx = jnp.arange(tags.shape[0])
    sel = lambda new, old: jnp.where(do_fill, new, old)  # noqa: E731
    tags = tags.at[cidx, victim].set(sel(vpn, tags[cidx, victim]))
    asids = asids.at[cidx, victim].set(sel(asid, asids[cidx, victim]))
    lru = lru.at[cidx, victim].set(sel(t, lru[cidx, victim]))
    return tags, asids, lru


def _l2_cache_access(cfg: SimConfig, l2c, dram, line, app, is_tlb, depth_tag,
                     may_fill, active, t, static_split):
    """Shared L2 data cache + DRAM for a batch of line addresses.

    Returns (l2c', dram', latency, l2_hit). `may_fill` implements the MASK
    L2 bypass decision; `static_split` gives each app half the ways by
    restricting its set index range (Static design)."""
    m = cfg.design.mask
    key = jnp.where(static_split,
                    (line % (cfg.l2_sets // cfg.n_apps))
                    + app * (cfg.l2_sets // cfg.n_apps),
                    line % cfg.l2_sets)
    # reuse TLB machinery: tag = full line id, "asid" field = 0
    zero = jnp.zeros_like(line)
    tagged = key * 0 + line  # probe on line id within the selected set
    l2c, hit = tlb_mod.probe(l2c._replace(), tagged * cfg.l2_sets + key,
                             zero, active, t)
    lat = jnp.where(hit, cfg.lat_l2_cache, 0)
    miss = active & ~hit

    channel = (line % cfg.n_channels).astype(jnp.int32)
    channel = jnp.where(static_split,
                        (line % (cfg.n_channels // cfg.n_apps))
                        + app * (cfg.n_channels // cfg.n_apps), channel)
    bank = ((line // cfg.n_channels) % cfg.n_banks).astype(jnp.int32)
    row = (line // (cfg.n_channels * cfg.n_banks * 32)).astype(jnp.int32)
    dram, dlat = dram_sched.access(
        dram, channel, bank, row, app, is_tlb, miss,
        mask_enabled=m.dram_sched, thres_max=m.thres_max)
    lat = lat + jnp.where(miss, cfg.lat_l2_cache + dlat, 0)
    l2c = tlb_mod.fill(l2c, tagged * cfg.l2_sets + key, zero,
                       miss & may_fill, t)
    return l2c, dram, lat, hit


def step(cfg: SimConfig, params_mat, state: SimState):
    """One cycle. params_mat: (n_apps, N_FIELDS) int32 workload params."""
    m = cfg.design.mask
    W, C, na = cfg.total_warps, cfg.n_cores, cfg.n_apps
    warps_per_core = cfg.warps_per_core
    t = state.t + 1

    # ---------------- warp selection (oldest-ready per core) -------------
    warp_id = jnp.arange(W)
    core_of = warp_id // warps_per_core
    slot_of = warp_id % warps_per_core
    # cores are partitioned evenly between apps (oracle split, §6)
    app_of_core = (jnp.arange(C) * na) // C
    app_of = app_of_core[core_of]

    ready = state.stall_until <= t
    waiting = jnp.where(ready, t - state.stall_until, -1)
    wait_grid = waiting.reshape(C, warps_per_core)
    pick = jnp.argmax(wait_grid, axis=1)                  # (C,)
    picked_warp = jnp.arange(C) * warps_per_core + pick
    active = wait_grid[jnp.arange(C), pick] >= 0          # (C,)

    app = app_of[picked_warp]
    pos = state.pos[picked_warp]
    vpn = gen_vpn(params_mat[app], app, picked_warp, pos, t)
    asid = app  # one address space per application

    # ---------------- L1 TLB ------------------------------------------
    l1_hit, l1_lru = _per_core_l1_probe(
        state.l1_tags, state.l1_asid, state.l1_lru, vpn, asid, t)
    l1_hit = l1_hit & active
    if cfg.design.ideal_tlb:
        l1_hit = active

    l1_miss = active & ~l1_hit

    # ---------------- shared L2 TLB + bypass cache ---------------------
    use_l2tlb = cfg.design.use_l2_tlb and not cfg.design.ideal_tlb
    l2tlb, byp_tlb = state.l2tlb, state.bypass_tlb
    if use_l2tlb:
        l2tlb, l2_hit = tlb_mod.probe(l2tlb, vpn, asid, l1_miss, t)
        if m.tlb_tokens:
            byp_tlb, byp_hit = tlb_mod.probe(byp_tlb, vpn, asid,
                                             l1_miss & ~l2_hit, t)
            l2_hit_eff = l2_hit | byp_hit
        else:
            byp_hit = jnp.zeros_like(l2_hit)
            l2_hit_eff = l2_hit
    else:
        l2_hit = jnp.zeros_like(l1_miss)
        byp_hit = jnp.zeros_like(l1_miss)
        l2_hit_eff = l2_hit

    need_walk = l1_miss & ~l2_hit_eff

    # ---------------- page walk (4 dependent PTE accesses) -------------
    # MSHR merge: outstanding walk for same (vpn, asid)?
    wmatch = (state.walk_vpn[None, :] == vpn[:, None]) & \
             (state.walk_asid[None, :] == asid[:, None]) & \
             (state.walk_done[None, :] > t)
    merged = wmatch.any(axis=1) & need_walk
    merge_done = jnp.where(
        merged, jnp.max(jnp.where(wmatch, state.walk_done[None, :], 0),
                        axis=1), 0)

    new_walk = need_walk & ~merged
    n_live = (state.walk_done > t).sum()
    # walker occupancy queue penalty (64 walker threads)
    over = jnp.maximum(n_live + jnp.cumsum(new_walk) - WALK_TABLE, 0)
    queue_pen = over * 30

    pte_lines = pt_mod.pte_line_addresses(
        pt_mod.PageTableConfig(levels=m.walk_levels), asid, vpn)  # (C, L)

    walk_lat = jnp.zeros((C,), jnp.int32)
    dram_tlb_lat = jnp.zeros((C,), jnp.float32)
    dram_tlb_n = jnp.zeros((C,), jnp.int32)
    l2c, dram, bp_state = state.l2c, state.dram, state.bypass
    pwc = state.pwc
    static = jnp.asarray(cfg.design.static_partition)
    for lvl in range(m.walk_levels):
        line = pte_lines[:, lvl]
        lvl_active = new_walk
        depth_tag = jnp.full((C,), pt_mod.walk_depth_tag(lvl), jnp.int32)
        if cfg.design.use_pwc:
            pwc, pwc_hit = tlb_mod.probe(pwc, line, asid * 0, lvl_active, t)
            pwc = tlb_mod.fill(pwc, line, asid * 0, lvl_active & ~pwc_hit, t)
            go_l2 = lvl_active & ~pwc_hit
            walk_lat = walk_lat + jnp.where(lvl_active & pwc_hit, 5, 0)
        else:
            go_l2 = lvl_active
        if m.l2_bypass:
            may_fill = bp_mod.should_fill(bp_state, depth_tag)
        else:
            may_fill = jnp.ones((C,), bool)
        l2c, dram, lat, l2hit = _l2_cache_access(
            cfg, l2c, dram, line, app, jnp.ones((C,), bool), depth_tag,
            may_fill, go_l2, t, static)
        bp_state = bp_mod.record(bp_state, depth_tag, l2hit, go_l2)
        walk_lat = walk_lat + jnp.where(go_l2, lat, 0)
        went_dram = go_l2 & ~l2hit
        dram_tlb_lat = dram_tlb_lat + jnp.where(went_dram, lat, 0)
        dram_tlb_n = dram_tlb_n + went_dram.astype(jnp.int32)
        c_tlb_hit = (go_l2 & l2hit).sum(dtype=jnp.int32)
        c_tlb_probe = go_l2.sum(dtype=jnp.int32)
        if lvl == 0:
            cum_tlb_hit, cum_tlb_probe = c_tlb_hit, c_tlb_probe
        else:
            cum_tlb_hit = cum_tlb_hit + c_tlb_hit
            cum_tlb_probe = cum_tlb_probe + c_tlb_probe

    walk_lat = walk_lat + queue_pen
    walk_done_new = t + cfg.lat_l2_tlb + walk_lat

    # install new walks into free slots (expired entries are free)
    free = state.walk_done <= t
    order_slots = jnp.cumsum(new_walk) - 1
    free_idx = jnp.where(free, jnp.arange(WALK_TABLE), BIG)
    free_sorted = jnp.sort(free_idx)
    slot_for = jnp.where(new_walk,
                         free_sorted[jnp.clip(order_slots, 0, WALK_TABLE - 1)],
                         BIG)
    can_install = slot_for < WALK_TABLE
    slot_safe = jnp.clip(slot_for, 0, WALK_TABLE - 1).astype(jnp.int32)
    inst = new_walk & can_install
    walk_vpn = state.walk_vpn.at[slot_safe].set(
        jnp.where(inst, vpn, state.walk_vpn[slot_safe]))
    walk_asid = state.walk_asid.at[slot_safe].set(
        jnp.where(inst, asid, state.walk_asid[slot_safe]))
    walk_done = state.walk_done.at[slot_safe].set(
        jnp.where(inst, walk_done_new, state.walk_done[slot_safe]))
    walk_merged_arr = state.walk_merged.at[slot_safe].set(
        jnp.where(inst, 1, state.walk_merged[slot_safe]))
    # bump merge counters
    first_match = jnp.argmax(wmatch, axis=1)
    walk_merged_arr = walk_merged_arr.at[first_match].add(
        jnp.where(merged, 1, 0))

    # ---------------- translation latency ------------------------------
    trans_lat = jnp.where(
        l1_hit, cfg.lat_l1_tlb,
        jnp.where(l2_hit_eff, cfg.lat_l2_tlb,
                  jnp.where(merged, jnp.maximum(merge_done - t, 1),
                            jnp.maximum(walk_done_new - t, 1))))
    if cfg.design.ideal_tlb:
        trans_lat = jnp.where(active, cfg.lat_l1_tlb, 0)

    # ---------------- TLB fills on walk return -------------------------
    if use_l2tlb:
        if m.tlb_tokens:
            # tokens are distributed round-robin over the app's cores in
            # warpID order: per-core allowance = tokens / cores_per_app
            cores_per_app = C // na
            tok_per_core = state.tokens.tokens[app] // cores_per_app
            has_tok = slot_of[picked_warp] < tok_per_core
            fill_l2 = need_walk & has_tok & ~state.tokens.first_epoch
            fill_l2 = fill_l2 | (need_walk & state.tokens.first_epoch)
            fill_byp = need_walk & ~fill_l2
            byp_tlb = tlb_mod.fill(byp_tlb, vpn, asid, fill_byp, t)
        else:
            fill_l2 = need_walk
        l2tlb = tlb_mod.fill(l2tlb, vpn, asid, fill_l2, t)
    l1_tags, l1_asid_arr, l1_lru = _per_core_l1_fill(
        state.l1_tags, state.l1_asid, l1_lru, vpn, asid, l1_miss, t)

    # ---------------- data access --------------------------------------
    pfn = pt_mod.translate(pt_mod.PageTableConfig(), asid, vpn)
    r = _mix(pfn.astype(jnp.uint32) + pos.astype(jnp.uint32))
    l1d_hit = (r % jnp.uint32(1024)).astype(jnp.int32) \
        < params_mat[app, 6]
    # warp-wide (divergent) data access: one memory instruction touches
    # DATA_WIDTH cache lines, serviced in parallel (latency = max). This is
    # what gives data traffic its realistic flooding pressure on the shared
    # L2 relative to page-walk traffic.
    DATA_WIDTH = 4
    go_l2d = active & ~l1d_hit
    dlat = jnp.zeros((C,), jnp.int32)
    l2d_hit_any = jnp.zeros((C,), bool)
    for k in range(DATA_WIDTH):
        r3 = _mix(r + jnp.uint32((0x85EBCA6B + 0x9E3779B9 * k) & 0xFFFFFFFF))
        data_line = pfn * 32 + (r3 % jnp.uint32(32)).astype(jnp.int32)
        l2c, dram, dlat_k, l2d_hit = _l2_cache_access(
            cfg, l2c, dram, data_line, app, jnp.zeros((C,), bool),
            jnp.zeros((C,), jnp.int32), jnp.ones((C,), bool), go_l2d, t,
            static)
        dlat = jnp.maximum(dlat, dlat_k)
        l2d_hit_any = l2d_hit_any | l2d_hit
        bp_state = bp_mod.record(bp_state, jnp.zeros((C,), jnp.int32),
                                 l2d_hit, go_l2d)
    l2d_hit = l2d_hit_any
    data_lat = jnp.where(l1d_hit, cfg.lat_l1_data, cfg.lat_l1_data + dlat)

    # ---------------- retire / stall ------------------------------------
    gap = params_mat[app, 5]
    total_lat = trans_lat + data_lat + gap
    stall_until = state.stall_until.at[picked_warp].set(
        jnp.where(active, t + total_lat, state.stall_until[picked_warp]))
    instr = state.instr.at[picked_warp].add(
        jnp.where(active, (1 + gap).astype(jnp.float32), 0.0))
    pos_new = state.pos.at[picked_warp].add(jnp.where(active, 1, 0))

    # ---------------- statistics ----------------------------------------
    oh = jax.nn.one_hot(app, na, dtype=jnp.int32) * active[:, None]
    ohf = oh.astype(jnp.float32)
    tokens = tok_mod.record(state.tokens, app, l2_hit_eff, l1_miss)
    st = dict(
        s_l1_hit=state.s_l1_hit + (oh * l1_hit[:, None]).sum(0),
        s_l1_miss=state.s_l1_miss + (oh * l1_miss[:, None]).sum(0),
        s_l2_hit=state.s_l2_hit + (oh * l2_hit[:, None]).sum(0),
        s_l2_miss=state.s_l2_miss + (oh * need_walk[:, None]).sum(0),
        s_byp_hit=state.s_byp_hit + (oh * byp_hit[:, None]).sum(0),
        s_byp_probe=state.s_byp_probe + (oh * (l1_miss & ~l2_hit)[:, None]).sum(0),
        s_walk_lat=state.s_walk_lat
        + (ohf * jnp.where(new_walk, walk_done_new - t, 0)[:, None]).sum(0),
        s_walks=state.s_walks + (oh * new_walk[:, None]).sum(0),
        s_stall_per_miss=state.s_stall_per_miss
        + (ohf * merged[:, None]).sum(0),
    )

    # ---------------- epoch maintenance ---------------------------------
    def do_epoch(args):
        tokens, dram, bp = args
        warps_per_app = jnp.full((na,), W // na, jnp.int32)
        conc = jnp.zeros((na,), jnp.int32).at[
            jnp.clip(state.walk_asid, 0, na - 1)].add(
            (state.walk_done > t).astype(jnp.int32))
        stalled = jnp.zeros((na,), jnp.int32).at[
            jnp.clip(state.walk_asid, 0, na - 1)].add(
            state.walk_merged * (state.walk_done > t))
        dram = dram_sched.update_pressure(dram, conc, stalled)
        return (tok_mod.epoch_update(tokens, warps_per_app,
                                     step_frac=m.token_step_frac), dram,
                bp_mod.epoch_update(bp))

    is_epoch = (t % m.epoch_cycles) == 0
    tokens, dram, bp_state = jax.lax.cond(
        is_epoch & jnp.asarray(m.tlb_tokens or m.dram_sched or m.l2_bypass),
        do_epoch, lambda args: args, (tokens, dram, bp_state))

    return SimState(
        t=t, stall_until=stall_until, instr=instr, pos=pos_new,
        l1_tags=l1_tags, l1_asid=l1_asid_arr, l1_lru=l1_lru,
        l2tlb=l2tlb, bypass_tlb=byp_tlb, pwc=pwc, l2c=l2c,
        tokens=tokens, bypass=bp_state, dram=dram,
        walk_vpn=walk_vpn, walk_asid=walk_asid, walk_done=walk_done,
        walk_merged=walk_merged_arr,
        s_dram_tlb_lat=state.s_dram_tlb_lat + (ohf * dram_tlb_lat[:, None]).sum(0),
        s_dram_tlb_n=state.s_dram_tlb_n + (oh * dram_tlb_n[:, None]).sum(0),
        s_dram_data_lat=state.s_dram_data_lat
        + (ohf * jnp.where(go_l2d, dlat, 0)[:, None]).sum(0),
        s_dram_data_n=state.s_dram_data_n + (oh * go_l2d[:, None]).sum(0),
        s_l2c_tlb_hit=state.s_l2c_tlb_hit + cum_tlb_hit,
        s_l2c_tlb_probe=state.s_l2c_tlb_probe + cum_tlb_probe,
        s_l2c_data_hit=state.s_l2c_data_hit
        + (go_l2d & l2d_hit).sum(dtype=jnp.int32),
        s_l2c_data_probe=state.s_l2c_data_probe + go_l2d.sum(dtype=jnp.int32),
        **st,
    )
