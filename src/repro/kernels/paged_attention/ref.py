"""Pure-jnp oracle for paged decode attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def paged_attention_ref(q, k_pages, v_pages, block_table, seq_lens):
    """q: (B, H, dh); pages: (P, page, KV, dh); block_table: (B, n) int32."""
    B, H, dh = q.shape
    _, page, KV, _ = k_pages.shape
    n = block_table.shape[1]
    G = H // KV
    # gather logical KV: (B, n*page, KV, dh)
    k = k_pages[block_table].reshape(B, n * page, KV, dh)
    v = v_pages[block_table].reshape(B, n * page, KV, dh)
    qg = q.reshape(B, KV, G, dh)
    s = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / (dh ** 0.5)
    pos = jnp.arange(n * page)[None, None, None, :]
    s = jnp.where(pos < seq_lens[:, None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p.astype(v.dtype), v)
    return o.reshape(B, H, dh)
