"""Simulator configuration (paper Table 1, Maxwell-class).

`n_apps` is arbitrary (1 <= n_apps <= n_cores): cores are split between
apps by the oracle partition of §6 (app a owns a contiguous core range),
and the per-app core/warp counts exposed here are the single source of
truth for the scheduler, token distribution, and stats attribution.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

from repro.core.design import Design, as_design, get_design


@dataclasses.dataclass(frozen=True)
class SimConfig:
    n_cores: int = 30
    warps_per_core: int = 32
    n_apps: int = 2
    # L2 data cache: 2MB, 16-way, 128B lines -> 1024 sets
    l2_sets: int = 1024
    l2_ways: int = 16
    # page-walk cache (Fig. 2a design): 16-way, 1024 entries (§3 fn. 2)
    pwc_entries: int = 1024
    pwc_ways: int = 16
    # DRAM: 8 channels x 8 banks
    n_channels: int = 8
    n_banks: int = 8
    # latencies (cycles)
    lat_l1_tlb: int = 1
    lat_l2_tlb: int = 10
    lat_l2_cache: int = 10
    lat_l1_data: int = 1
    sim_cycles: int = 60_000
    # a repro.core.design.Design; a name or legacy DesignPoint is coerced
    design: Design = dataclasses.field(
        default_factory=lambda: get_design("gpu-mmu"))

    def __post_init__(self):
        if not 1 <= self.n_apps <= self.n_cores:
            raise ValueError(
                f"n_apps must be in [1, n_cores={self.n_cores}], "
                f"got {self.n_apps}")
        if not isinstance(self.design, Design):
            object.__setattr__(self, "design", as_design(self.design))

    @property
    def total_warps(self) -> int:
        return self.n_cores * self.warps_per_core

    @property
    def app_of_core(self) -> Tuple[int, ...]:
        """(n_cores,) oracle core split (§6): contiguous, near-equal ranges."""
        return tuple((c * self.n_apps) // self.n_cores
                     for c in range(self.n_cores))

    @property
    def cores_per_app(self) -> Tuple[int, ...]:
        """(n_apps,) core counts under the oracle split."""
        counts = [0] * self.n_apps
        for a in self.app_of_core:
            counts[a] += 1
        return tuple(counts)

    @property
    def warps_per_app(self) -> Tuple[int, ...]:
        """(n_apps,) warp counts — token budgets and IPC denominators."""
        return tuple(c * self.warps_per_core for c in self.cores_per_app)
