"""Serving fairness benchmark -> BENCH_serving.json.

Drives seeded trace presets (repro.serving.stream) through the
multi-tenant engine under each placement policy and reports the
paper's fairness metrics at the serving layer:

  per-tenant slowdown  — shared mean latency / solo mean latency, the
                         solo run replaying the SAME seeded arrivals
                         restricted to that tenant (TraceSpec.only) —
                         the serving analogue of IPC_alone (paper §6)
  unfairness           — max per-tenant slowdown
  fairness error       — |predicted - achieved| / achieved, where the
                         prediction is the contention oracle's mean
                         predicted max-slowdown over its chosen
                         placements (only the "oracle" policy predicts)

plus TTFT, latency percentiles, SLO attainment (SLO = 3x the tenant's
solo mean latency) and per-tenant throughput. Token compute is stubbed
(`ServingEngine(forwards=stub_forwards())`): latencies are measured in
ENGINE STEPS, so the benchmark isolates scheduling/admission behavior
— which is what the policies differ on — and stays fast enough for CI.

The headline check (also asserted by tests/test_serving_oracle.py):
on flood_vs_trickle the oracle policy must STRICTLY improve
unfairness over the admit-all "none" baseline.

Run:   PYTHONPATH=src python benchmarks/serving_bench.py
Smoke: PYTHONPATH=src python benchmarks/serving_bench.py --smoke
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.memmgr import kv_cache as kvc                      # noqa: E402
from repro.serving import metrics as smet                     # noqa: E402
from repro.serving import stream as strm                      # noqa: E402
from repro.serving.engine import (EngineConfig, ServingEngine,  # noqa: E402
                                  stub_forwards, stub_model_config)
from repro.serving.oracle import ContentionOracle             # noqa: E402
from repro.serving.placement import POLICIES, make_policy     # noqa: E402

POOL = kvc.PoolConfig(n_pages=256, page_size=8, n_kv=1, head_dim=4,
                      n_layers=1, max_seqs=16, pages_per_seq=8)


def run_trace(trace: strm.TraceSpec, policy, max_batch: int = 8,
              drain_steps: int = 800):
    cfg = stub_model_config()
    eng = ServingEngine(cfg, None, None, POOL,
                        EngineConfig(max_batch=max_batch),
                        placement=policy, profiles=trace.profiles(),
                        forwards=stub_forwards())
    for step_reqs in strm.arrivals(trace, cfg.vocab_size):
        for r in step_reqs:
            eng.submit(r)
        eng.step()
    eng.run_until_drained(max_steps=drain_steps)
    return eng


def bench_trace(trace: strm.TraceSpec, policies, cycles: int,
                epoch_steps: int, unfairness_cap: float):
    # solo baselines: same seeded arrivals, one tenant at a time
    solo_lat = {}
    for spec in trace.specs:
        e = run_trace(trace.only(spec.tenant), make_policy("none"))
        solo_lat.update(smet.tenant_mean_latency(e.finished))
    out = {"steps": trace.steps, "seed": trace.seed,
           "tenants": {s.tenant: s.profile for s in trace.specs},
           "solo_mean_latency": {t: round(v, 3)
                                 for t, v in sorted(solo_lat.items())},
           "policies": {}}
    for pol in policies:
        oracle = None
        if pol == "oracle":
            oracle = ContentionOracle(cycles=cycles,
                                      slots=max(len(trace.specs), 2),
                                      pad_rows=8)
        policy = make_policy(pol, profiles=trace.profiles(), oracle=oracle,
                             epoch_steps=epoch_steps,
                             **({"unfairness_cap": unfairness_cap}
                                if pol == "oracle" else {}))
        eng = run_trace(trace, policy)
        rep = smet.fairness_report(eng.finished, solo_lat, eng.decisions)
        slo = {t: 3.0 * solo_lat[t] for t in solo_lat}
        rec = {
            "finished": len(eng.finished),
            "engine_steps": eng.step_count,
            "tenant_slowdown": {t: round(v, 4)
                                for t, v in rep["tenant_slowdown"].items()},
            "unfairness": round(rep["unfairness"], 4),
            "predicted_max_slowdown": rep["predicted_max_slowdown"],
            "fairness_error": rep["fairness_error"],
            "starved_tenants": rep["starved_tenants"],
            "tenant_mean_latency": {
                t: round(v, 3)
                for t, v in sorted(smet.tenant_mean_latency(
                    eng.finished).items())},
            "tenant_ttft": {t: round(v, 3)
                            for t, v in sorted(smet.tenant_ttft(
                                eng.finished).items())},
            "latency_percentiles": smet.latency_percentiles(eng.finished),
            "slo_attainment": {
                t: round(sum(1 for r in eng.finished if r.tenant == t
                             and r.finish_step - r.submit_step <= slo[t])
                         / max(sum(1 for r in eng.finished
                                   if r.tenant == t), 1), 4)
                for t in sorted(solo_lat)},
            "tenant_throughput": {
                t: round(v, 4)
                for t, v in sorted(smet.tenant_throughput(
                    eng.finished, eng.step_count).items())},
            "decisions": smet.decision_summary(eng.decisions),
        }
        if oracle is not None:
            rec["oracle"] = {"grid_calls": oracle.grid_calls,
                             "memo_size": oracle.memo_size,
                             "sim_failures": len(oracle.failures)}
        out["policies"][pol] = rec
        print(f"  {trace.name:<18} {pol:<7} unfair "
              f"{rec['unfairness']:<7} slowdown "
              f"{rec['tenant_slowdown']}", flush=True)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_serving.json"))
    ap.add_argument("--traces", nargs="*",
                    default=["flood_vs_trickle", "churn", "heavy_tail"])
    ap.add_argument("--policies", nargs="*", default=list(POLICIES))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--steps", type=int, default=None,
                    help="override every trace's step count")
    ap.add_argument("--cycles", type=int, default=600,
                    help="simulator cycles per oracle prediction")
    ap.add_argument("--epoch-steps", type=int, default=8)
    ap.add_argument("--unfairness-cap", type=float, default=1.15)
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: one trace, short, fewer sim cycles")
    args = ap.parse_args()
    if args.smoke:
        args.traces = ["flood_vs_trickle"]
        args.cycles = min(args.cycles, 300)

    results = {"seed": args.seed, "cycles": args.cycles,
               "epoch_steps": args.epoch_steps,
               "unfairness_cap": args.unfairness_cap,
               "policies": list(args.policies), "traces": {}}
    for name in args.traces:
        trace = strm.make_trace(name, seed=args.seed, steps=args.steps)
        print(f"{name} (steps={trace.steps}, seed={trace.seed}):",
              flush=True)
        results["traces"][name] = bench_trace(
            trace, args.policies, args.cycles, args.epoch_steps,
            args.unfairness_cap)

    checks = {}
    fv = results["traces"].get("flood_vs_trickle", {}).get("policies", {})
    if "oracle" in fv and "none" in fv:
        checks["oracle_beats_none_flood_vs_trickle"] = bool(
            fv["oracle"]["unfairness"] < fv["none"]["unfairness"])
    results["checks"] = checks

    with open(args.out, "w") as f:
        json.dump(results, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {os.path.abspath(args.out)}")
    for k, v in checks.items():
        print(f"check {k}: {'PASS' if v else 'FAIL'}")
    if checks and not all(checks.values()):
        sys.exit(1)


if __name__ == "__main__":
    main()
