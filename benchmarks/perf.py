"""Simulator throughput microbenchmark -> BENCH_sim.json.

Measures steps/sec of the compiled one-cycle pipeline in three shapes:

  2app    — one 2-app mix (the paper's pair setting)
  4app    — one 4-app mix (N-way sharing)
  batch8  — eight 2-app mixes vmapped through one executable

The three scenarios are interleaved round-robin inside ONE process and
the median per-scenario rate is reported: this box's absolute throughput
drifts with neighbor load, so sequential before/after blocks are not
comparable — interleaving keeps the scenarios under the same drift, and
the recorded JSON gives future PRs a perf trajectory (compare ratios
between scenarios / versions, not absolute steps/sec across days).

Run:  PYTHONPATH=src python -m benchmarks.perf [--cycles N] [--rounds R]
"""
from __future__ import annotations

import argparse
import json
import platform
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.sim.config import SimConfig
from repro.sim.runner import _compiled_batch_run, _compiled_run, _mix_matrix
from repro.sim.workloads import mix_workloads, pair_workloads

OUT_PATH = Path(__file__).resolve().parent.parent / "BENCH_sim.json"


def _scenarios(design: str, cycles: int):
    """name -> (zero-arg compiled call, sim-steps per call)."""
    from repro.core.design import get_design
    d = get_design(design)

    def single(benches):
        cfg = SimConfig(n_apps=len(benches), sim_cycles=cycles, design=d)
        pm = jnp.asarray(_mix_matrix(benches))
        fn = _compiled_run(cfg)
        return (lambda: jax.block_until_ready(fn(pm))), cycles

    def batch(mixes):
        cfg = SimConfig(n_apps=len(mixes[0]), sim_cycles=cycles, design=d)
        pm = jnp.asarray(np.stack([_mix_matrix(m) for m in mixes]))
        fn = _compiled_batch_run(cfg)
        return (lambda: jax.block_until_ready(fn(pm))), cycles * len(mixes)

    mix4 = mix_workloads(seed=7, n_mixes=1, n_apps=4)[0]
    return {
        "2app": single(["3DS", "BLK"]),
        "4app": single(list(mix4)),
        "batch8": batch(pair_workloads()[:8]),
    }


def run_bench(design: str = "mask", cycles: int = 8_000, rounds: int = 5,
              out_path: Path = OUT_PATH) -> dict:
    scen = _scenarios(design, cycles)
    for name, (call, _) in scen.items():   # compile + warm
        t0 = time.perf_counter()
        call()
        print(f"# warm {name}: {time.perf_counter() - t0:.1f}s", flush=True)

    samples = {name: [] for name in scen}
    for r in range(rounds):                # interleaved measurement
        for name, (call, steps) in scen.items():
            t0 = time.perf_counter()
            call()
            dt = time.perf_counter() - t0
            samples[name].append(steps / dt)
        print(f"# round {r + 1}/{rounds} done", flush=True)

    result = {
        "design": design,
        "cycles": cycles,
        "rounds": rounds,
        "steps_per_sec": {n: float(np.median(v)) for n, v in samples.items()},
        "samples": {n: [float(x) for x in v] for n, v in samples.items()},
        "meta": {
            "jax": jax.__version__,
            "platform": platform.platform(),
            "backend": jax.default_backend(),
        },
    }
    out_path.write_text(json.dumps(result, indent=2) + "\n")
    print(json.dumps({k: result[k] for k in ("design", "cycles",
                                             "steps_per_sec")}, indent=2))
    print(f"# wrote {out_path}")
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--design", default="mask")
    ap.add_argument("--cycles", type=int, default=8_000)
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--out", type=Path, default=OUT_PATH)
    args = ap.parse_args()
    run_bench(args.design, args.cycles, args.rounds, args.out)


if __name__ == "__main__":
    main()
