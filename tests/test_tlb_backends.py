"""Backend parity + dispatch rules for the fused shared-round backends.

The Pallas kernel (interpret mode on CPU) must be bit-for-bit identical
to the inline XLA path — same float-hex stats across every builtin
design and every supported app count — and requesting a real Pallas
lowering on a platform that has none must raise, never silently fall
back (acceptance criteria of the backend scale-out PR).
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.design import BUILTIN_DESIGNS, get_design
from repro.sim import runner as R
from repro.sim.config import SimConfig, resolve_tlb_backend

BENCHES = ("3DS", "BLK", "MUM")
DESIGN_NAMES = tuple(d.name for d in BUILTIN_DESIGNS)


@functools.lru_cache(maxsize=None)
def _stats(design_name: str, n_apps: int, backend: str):
    cfg = SimConfig(n_cores=6, warps_per_core=8, n_apps=n_apps,
                    sim_cycles=300,
                    design=get_design(design_name).with_(epoch_cycles=100),
                    tlb_backend=backend)
    pm = jnp.asarray(R._mix_matrix(list(BENCHES[:n_apps])))
    return R._stats(cfg, R._compiled_run(cfg)(pm))


@pytest.mark.parametrize("n_apps", [1, 2, 3])
@pytest.mark.parametrize("name", DESIGN_NAMES)
def test_backend_parity_float_hex(name, n_apps):
    """pallas-interpret == xla, float-hex, all 8 designs x n_apps 1..3."""
    a = _stats(name, n_apps, "xla")
    b = _stats(name, n_apps, "pallas-interpret")
    assert set(a) == set(b)
    for k in a:
        ha = [float(v).hex() for v in np.atleast_1d(a[k]).ravel()]
        hb = [float(v).hex() for v in np.atleast_1d(b[k]).ravel()]
        assert ha == hb, (name, n_apps, k)


def test_pallas_backend_requires_lowering():
    """'pallas' on a platform without a lowering raises at config time."""
    if jax.default_backend() in ("tpu", "gpu"):
        pytest.skip("real Pallas lowering available here")
    with pytest.raises(RuntimeError, match="no Pallas lowering"):
        SimConfig(tlb_backend="pallas")


def test_backend_env_resolution(monkeypatch):
    monkeypatch.delenv("REPRO_TLB_BACKEND", raising=False)
    assert SimConfig().tlb_backend == "xla"
    monkeypatch.setenv("REPRO_TLB_BACKEND", "pallas-interpret")
    assert SimConfig().tlb_backend == "pallas-interpret"
    # explicit value wins over env
    assert SimConfig(tlb_backend="xla").tlb_backend == "xla"
    monkeypatch.setenv("REPRO_TLB_BACKEND", "nope")
    with pytest.raises(ValueError, match="tlb_backend"):
        SimConfig()


def test_interpret_env_opt_in(monkeypatch):
    if jax.default_backend() in ("tpu", "gpu"):
        pytest.skip("real Pallas lowering available here")
    monkeypatch.setenv("REPRO_TLB_INTERPRET", "1")
    assert resolve_tlb_backend("pallas") == "pallas-interpret"


def test_backend_keys_compile_cache():
    """Distinct backends must be distinct compile-cache keys."""
    a = SimConfig(tlb_backend="xla")
    b = SimConfig(tlb_backend="pallas-interpret")
    assert a != b and hash(a) != hash(b)
