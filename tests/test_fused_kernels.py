"""Equivalence coverage for the lane-fused memory path (PR 4).

Three layers of evidence that the fusion did not change the model:

  * exact — the direct bank kernels (`probe_bank`/`fill_bank`) replicate
    vmapping the general N-lane probe/fill at N=1 bit-for-bit, and the
    packed stat planes replicate the 17 separate one-hot updates
    bit-for-bit;
  * contract — `access_fused`'s documented cross-wave semantics
    (per-(set, wave) fill ports, duplicate suppression, forwarding, LRU
    victim chains) hold on constructed scenarios;
  * statistical — the fused pipeline tracks the frozen sequential
    reference (`tests/reference_memsys.py`, the exact pre-fusion code)
    across ALL registered designs x n_apps in {1, 2, 3} within tight
    paper-metric tolerances.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import reference_memsys as ref
from repro.core import tlb as tlb_mod
from repro.core.design import get_design
from repro.core.mask import ALL_DESIGNS
from repro.sim import memsys
from repro.sim import runner
from repro.sim.config import SimConfig
from repro.sim.workloads import app_matrix


# ------------------------------------------------ bank kernels: exact

def _vmapped_probe_bank(state, vpn, asid, active, time):
    """The pre-fusion implementation: vmap the general probe at N=1."""
    fn = jax.vmap(lambda s, v, a, act: tlb_mod.probe(
        s, v[None], a[None], act[None], time))
    state, hit = fn(state, vpn, asid, active)
    return state, hit[:, 0]


def _vmapped_fill_bank(state, vpn, asid, do_fill, time):
    fn = jax.vmap(lambda s, v, a, d: tlb_mod.fill(
        s, v[None], a[None], d[None], time))
    return fn(state, vpn, asid, do_fill)


@pytest.mark.parametrize("entries,ways", [(8, 8), (16, 4)])
def test_bank_kernels_match_vmapped_general(entries, ways):
    """Direct (B, sets, ways) indexing == vmapped general probe/fill,
    bit-for-bit over random traffic (incl. multi-set banks)."""
    B, T = 5, 300
    rng = np.random.RandomState(3)
    direct = tlb_mod.init_bank(B, entries, ways)
    vmapped = tlb_mod.init_bank(B, entries, ways)
    for t in range(1, T + 1):
        vpn = jnp.asarray(rng.randint(0, 40, B), jnp.int32)
        asid = jnp.asarray(rng.randint(0, 3, B), jnp.int32)
        active = jnp.asarray(rng.rand(B) < 0.8)
        direct, hit_d = tlb_mod.probe_bank(direct, vpn, asid, active, t)
        vmapped, hit_v = _vmapped_probe_bank(vmapped, vpn, asid, active, t)
        np.testing.assert_array_equal(np.asarray(hit_d), np.asarray(hit_v),
                                      err_msg=f"probe t={t}")
        fill = active & ~hit_d & jnp.asarray(rng.rand(B) < 0.9)
        direct = tlb_mod.fill_bank(direct, vpn, asid, fill, t)
        vmapped = _vmapped_fill_bank(vmapped, vpn, asid, fill, t)
    for leaf_d, leaf_v in zip(direct, vmapped):
        np.testing.assert_array_equal(np.asarray(leaf_d), np.asarray(leaf_v))


# ------------------------------------------- packed stat planes: exact

def _old_accumulate(stats17, n_apps, sched, tout, dout, t):
    """The pre-fusion 17-array one-hot update (reference arithmetic)."""
    oh = jax.nn.one_hot(sched.app, n_apps, dtype=jnp.int32) \
        * sched.active[:, None]
    ohf = oh.astype(jnp.float32)
    psum = lambda x: (oh * x[:, None]).sum(0)  # noqa: E731
    fsum = lambda x: (ohf * x[:, None]).sum(0)  # noqa: E731
    out = dict(stats17)
    out["s_l1_hit"] = stats17["s_l1_hit"] + psum(tout.l1_hit)
    out["s_l1_miss"] = stats17["s_l1_miss"] + psum(tout.l1_miss)
    out["s_l2_hit"] = stats17["s_l2_hit"] + psum(tout.l2_hit)
    out["s_l2_miss"] = stats17["s_l2_miss"] + psum(tout.need_walk)
    out["s_byp_hit"] = stats17["s_byp_hit"] + psum(tout.byp_hit)
    out["s_byp_probe"] = stats17["s_byp_probe"] \
        + psum(tout.l1_miss & ~tout.l2_hit)
    out["s_walk_lat"] = stats17["s_walk_lat"] \
        + fsum(jnp.where(tout.new_walk, tout.walk_done_new - t, 0))
    out["s_walks"] = stats17["s_walks"] + psum(tout.new_walk)
    out["s_stall_per_miss"] = stats17["s_stall_per_miss"] + fsum(tout.merged)
    out["s_dram_tlb_lat"] = stats17["s_dram_tlb_lat"] + fsum(tout.dram_tlb_lat)
    out["s_dram_tlb_n"] = stats17["s_dram_tlb_n"] + psum(tout.dram_tlb_n)
    out["s_dram_data_lat"] = stats17["s_dram_data_lat"] \
        + fsum(jnp.where(dout.go_l2d, dout.dlat, 0))
    out["s_dram_data_n"] = stats17["s_dram_data_n"] + psum(dout.go_l2d)
    out["s_l2c_tlb_hit"] = stats17["s_l2c_tlb_hit"] + tout.l2c_hit
    out["s_l2c_tlb_probe"] = stats17["s_l2c_tlb_probe"] + tout.l2c_probe
    out["s_l2c_data_hit"] = stats17["s_l2c_data_hit"] \
        + (dout.go_l2d & dout.l2d_hit).sum(dtype=jnp.int32)
    out["s_l2c_data_probe"] = stats17["s_l2c_data_probe"] \
        + dout.go_l2d.sum(dtype=jnp.int32)
    return out


def test_packed_stats_match_per_array_updates():
    """accumulate_stats on the packed planes == the 17 one-hot updates,
    bit-for-bit over random per-cycle outcomes (ints and floats)."""
    C, na, T = 6, 3, 60
    rng = np.random.RandomState(7)
    packed = memsys.init_stats(na)
    seventeen = {
        name: jnp.zeros((na,), jnp.float32) if name in (
            "s_walk_lat", "s_stall_per_miss", "s_dram_tlb_lat",
            "s_dram_data_lat") else
        jnp.zeros((), jnp.int32) if name.startswith("s_l2c_") else
        jnp.zeros((na,), jnp.int32)
        for name in ("s_l1_hit", "s_l1_miss", "s_l2_hit", "s_l2_miss",
                     "s_byp_hit", "s_byp_probe", "s_walk_lat", "s_walks",
                     "s_stall_per_miss", "s_dram_tlb_lat", "s_dram_tlb_n",
                     "s_dram_data_lat", "s_dram_data_n", "s_l2c_tlb_hit",
                     "s_l2c_tlb_probe", "s_l2c_data_hit", "s_l2c_data_probe")}
    for t in range(1, T + 1):
        b = lambda p: jnp.asarray(rng.rand(C) < p)  # noqa: E731
        z = lambda hi: jnp.asarray(rng.randint(0, hi, C), jnp.int32)  # noqa: E731
        l1_hit, l2_hit, byp_hit = b(.4), b(.3), b(.2)
        l1_miss = ~l1_hit & b(.9)
        need_walk = l1_miss & ~l2_hit
        new_walk = need_walk & b(.7)
        sched = memsys.SchedOut(
            picked_warp=jnp.arange(C), slot=jnp.zeros(C, jnp.int32),
            active=b(.8), app=z(na), asid=z(na),
            vpn=z(100), pos=jnp.zeros(C, jnp.int32))
        tout = memsys.TransOut(
            trans_lat=z(50), l1_hit=l1_hit, l1_miss=l1_miss, l2_hit=l2_hit,
            byp_hit=byp_hit, l2_hit_eff=l2_hit | byp_hit,
            need_walk=need_walk, merged=need_walk & ~new_walk,
            new_walk=new_walk, walk_done_new=t + z(300),
            dram_tlb_lat=z(400).astype(jnp.float32), dram_tlb_n=z(4),
            l2c_hit=z(3)[0], l2c_probe=z(3)[0] + 2)
        dout = memsys.DataOut(data_lat=z(60), l1d_hit=b(.5), go_l2d=b(.5),
                              dlat=z(500), l2d_hit=b(.5))
        packed = memsys.accumulate_stats(packed, na, sched, tout, dout,
                                         jnp.int32(t))
        seventeen = _old_accumulate(seventeen, na, sched, tout, dout,
                                    jnp.int32(t))
    for name, want in seventeen.items():
        np.testing.assert_array_equal(
            np.asarray(getattr(packed, name)), np.asarray(want),
            err_msg=name)


# ------------------------------------------- access_fused: contract

def _mini_cache(sets=4, ways=2):
    return tlb_mod.init(sets * ways, ways)


def test_access_fused_forwarding():
    """Lanes whose line is filled this cycle observe the fill (hit, no
    second fill) — across waves via duplicate suppression, and within a
    wave via the port (MSHR-merge-like resolution against final state)."""
    st = _mini_cache()
    # lanes: wave0 = [line 8, line 8], wave1 = [line 8, line 12]
    vpn = jnp.asarray([8, 8, 8, 12], jnp.int32)
    z = jnp.zeros(4, jnp.int32)
    on = jnp.ones(4, bool)
    st, hit, filled = tlb_mod.access_fused(st, vpn, z, on, on, 1, n_waves=2)
    assert hit.tolist() == [False, True, True, False]
    assert filled.tolist() == [True, False, False, True]


def test_access_fused_per_set_per_wave_port():
    """Two same-set misses in one wave: first fills, second does not;
    the same set can still fill again in the NEXT wave."""
    st = _mini_cache(sets=4, ways=2)
    # set = vpn % 4: lanes 0,1 both set 1 in wave 0; lane 2 set 1 in wave 1
    vpn = jnp.asarray([5, 9, 13, 2], jnp.int32)
    z = jnp.zeros(4, jnp.int32)
    on = jnp.ones(4, bool)
    st, hit, filled = tlb_mod.access_fused(st, vpn, z, on, on, 1, n_waves=2)
    assert filled.tolist() == [True, False, True, True]
    assert not bool(hit[1])              # port loss -> miss, no forward
    # both same-set winners landed in DISTINCT ways (LRU victim chain)
    occ = int((st.tags[1] >= 0).sum())
    assert occ == 2 and sorted(np.asarray(st.tags[1]).tolist()) == [5, 13]


def test_access_fused_duplicate_suppression_same_position():
    """The same flat position (core) re-touching one line in a later wave
    forwards instead of filling twice."""
    st = _mini_cache()
    # one core (C=1), 3 waves, same line every wave
    vpn = jnp.asarray([6, 6, 6], jnp.int32)
    z = jnp.zeros(3, jnp.int32)
    on = jnp.ones(3, bool)
    st, hit, filled = tlb_mod.access_fused(st, vpn, z, on, on, 1, n_waves=3)
    assert filled.tolist() == [True, False, False]
    assert hit.tolist() == [False, True, True]
    assert int((st.tags >= 0).sum()) == 1    # exactly one entry installed


def test_access_fused_respects_may_fill_and_active():
    st = _mini_cache()
    vpn = jnp.asarray([3, 7, 11], jnp.int32)
    z = jnp.zeros(3, jnp.int32)
    active = jnp.asarray([True, True, False])
    may_fill = jnp.asarray([False, True, True])
    st, hit, filled = tlb_mod.access_fused(st, vpn, z, active, may_fill, 1,
                                           n_waves=3)
    assert filled.tolist() == [False, True, False]
    assert hit.tolist() == [False, False, False]
    # bypassed lane went to DRAM without installing anything in its set
    assert int((st.tags >= 0).sum()) == 1


def test_access_fused_matches_probe_on_resident_lines():
    """With everything resident and a single wave, access_fused == probe
    (same hits, same LRU touches)."""
    st = _mini_cache(sets=8, ways=4)
    vpn = jnp.asarray([3, 11, 19, 27], jnp.int32)
    z = jnp.zeros(4, jnp.int32)
    on = jnp.ones(4, bool)
    for i in range(4):
        st = tlb_mod.fill(st, vpn[i:i + 1], z[:1], on[:1], i + 1)
    via_probe, hit_p = tlb_mod.probe(st, vpn, z, on, 9)
    via_fused, hit_f, filled = tlb_mod.access_fused(st, vpn, z, on, on, 9)
    assert bool(hit_p.all()) and bool(hit_f.all()) and not bool(filled.any())
    for leaf_p, leaf_f in zip(via_probe, via_fused):
        np.testing.assert_array_equal(np.asarray(leaf_p), np.asarray(leaf_f))


# ---------------------------- fused pipeline vs sequential reference

BENCHES3 = ["3DS", "BLK", "MUM"]
# Tolerances sized from a measured grid sweep at this exact config: the
# worst absolute hit-rate delta was 0.022 and the worst relative
# latency/ipc delta 23% (pwc, n=1). At that scale the two models diverge
# CHAOTICALLY, not systematically — a slightly different walk latency
# reorders the schedule and the address streams decorrelate — while
# full-size (30-core) runs agree within ~5% on every metric. A real
# regression (dropped stat, broken port logic, wrong lane split) blows
# far past these bounds.
TOL = {
    "ipc": ("rel", 0.30),
    "l1_hit_rate": ("abs", 0.08),
    "l2_hit_rate": ("abs", 0.08),
    "l2c_tlb_hit_rate": ("abs", 0.08),
    "l2c_data_hit_rate": ("abs", 0.08),
    "walk_lat": ("rel", 0.35),
    "dram_tlb_lat": ("rel", 0.25),
    "dram_data_lat": ("rel", 0.20),
}


@pytest.mark.parametrize("name", ALL_DESIGNS)
@pytest.mark.parametrize("n_apps", [1, 2, 3])
def test_fused_pipeline_tracks_sequential_reference(name, n_apps):
    """The fused one-round-per-cycle pipeline reproduces the sequential
    8-round reference within paper-metric tolerances, for every
    registered design and 1-3 concurrent apps (epochs crossed four
    times, so the adaptive token/bypass/DRAM paths are exercised)."""
    design = get_design(name).with_(epoch_cycles=400)
    cfg = SimConfig(n_cores=9, warps_per_core=8, n_apps=n_apps,
                    sim_cycles=1800, design=design)
    pm = jnp.asarray(app_matrix(BENCHES3[:n_apps]))
    new = runner._stats(cfg, runner._compiled_run(cfg)(pm))
    old = ref.metrics(cfg, ref.run_ref(cfg, pm))

    for key, (kind, tol) in TOL.items():
        nv = np.asarray(new[key], np.float64)
        ov = np.asarray(old[key], np.float64)
        assert np.all(np.isfinite(nv)), key
        if kind == "abs":
            err = np.max(np.abs(nv - ov))
        else:
            err = np.max(np.abs(nv - ov) / np.maximum(np.abs(ov), 1e-9))
        assert err <= tol, (f"{name} n_apps={n_apps} {key}: "
                            f"fused={nv} reference={ov} err={err:.3f}")
    # identical workload structure: the reference and the fused pipeline
    # must schedule the same instruction stream (exact, not statistical)
    np.testing.assert_array_equal(new["walks"] > 0, old["walks"] > 0)
