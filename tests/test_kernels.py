"""Per-kernel shape/dtype sweeps vs pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.paged_attention.ops import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref
from repro.kernels.fused_tlb.ops import fused_tlb_access
from repro.kernels.fused_tlb.ref import fused_tlb_access_ref
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_recurrence_ref


@pytest.mark.parametrize("S,H,KV,dh,bq,bk", [
    (128, 4, 4, 64, 64, 64),      # MHA
    (256, 8, 2, 64, 64, 128),     # GQA 4:1
    (128, 4, 1, 128, 32, 64),     # MQA
])
@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 96)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(S, H, KV, dh, bq, bk, causal, window, dtype):
    rng = np.random.RandomState(S + H)
    q = jnp.asarray(rng.randn(2, S, H, dh), dtype)
    k = jnp.asarray(rng.randn(2, S, KV, dh), dtype)
    v = jnp.asarray(rng.randn(2, S, KV, dh), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=bq, block_k=bk, interpret=True)
    ref = attention_ref(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                        jnp.swapaxes(v, 1, 2), causal=causal, window=window)
    ref = jnp.swapaxes(ref, 1, 2)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("B,H,KV,dh,page,npp", [
    (4, 8, 4, 64, 16, 6),
    (2, 4, 4, 128, 32, 4),        # MHA-ish
    (3, 16, 2, 64, 8, 10),        # GQA 8:1
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_sweep(B, H, KV, dh, page, npp, dtype):
    rng = np.random.RandomState(B * H)
    P = npp * B + 4
    q = jnp.asarray(rng.randn(B, H, dh), dtype)
    kp = jnp.asarray(rng.randn(P, page, KV, dh), dtype)
    vp = jnp.asarray(rng.randn(P, page, KV, dh), dtype)
    bt = jnp.asarray(rng.choice(P, (B, npp), replace=False), jnp.int32)
    sl = jnp.asarray(rng.randint(1, npp * page + 1, B), jnp.int32)
    out = paged_attention(q, kp, vp, bt, sl, interpret=True)
    ref = paged_attention_ref(q, kp, vp, bt, sl)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("S,nh,hd,ds,chunk", [
    (64, 4, 16, 16, 16),
    (128, 8, 32, 16, 32),
    (96, 2, 64, 32, 32),
])
def test_ssd_scan_sweep(S, nh, hd, ds, chunk):
    rng = np.random.RandomState(S + nh)
    x = jnp.asarray(rng.randn(2, S, nh, hd) * .5, jnp.float32)
    dt = jnp.asarray(np.abs(rng.randn(2, S, nh)) * .1 + .02, jnp.float32)
    A = jnp.asarray(-np.abs(rng.randn(nh)) * .5 - .1, jnp.float32)
    B = jnp.asarray(rng.randn(2, S, ds) * .5, jnp.float32)
    C = jnp.asarray(rng.randn(2, S, ds) * .5, jnp.float32)
    y, h = ssd_scan(x, dt, A, B, C, chunk=chunk, interpret=True)
    y_ref, h_ref = ssd_recurrence_ref(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("sets,ways,N,W", [(1, 64, 30, 1), (32, 16, 30, 3),
                                           (64, 8, 64, 4), (4, 2, 24, 6)])
@pytest.mark.parametrize("track_asids", [True, False])
def test_fused_tlb_sweep(sets, ways, N, W, track_asids):
    """Pallas fused round (interpret) == the XLA `access_fused` oracle,
    bit for bit, across waves / fill masks / both ASID modes."""
    rng = np.random.RandomState(sets * ways + W)
    tags = jnp.asarray(rng.randint(-1, 500, (sets, ways)), jnp.int32)
    asids = jnp.asarray(rng.randint(0, 3, (sets, ways)), jnp.int32)
    lru = jnp.asarray(rng.randint(0, 100, (sets, ways)), jnp.int32)
    vpn = jnp.asarray(rng.randint(0, 600, (N,)), jnp.int32)
    asid = jnp.asarray(rng.randint(0, 3, (N,)), jnp.int32)
    active = jnp.asarray(rng.rand(N) > 0.25)
    may_fill = jnp.asarray(rng.rand(N) > 0.2)
    out = fused_tlb_access(tags, asids, lru, vpn, asid, active, may_fill, 77,
                           n_waves=W, track_asids=track_asids, interpret=True)
    ref = fused_tlb_access_ref(tags, asids, lru, vpn, asid, active, may_fill,
                               77, n_waves=W, track_asids=track_asids)
    for a, b, name in zip(out, ref, ("tags", "asids", "lru", "hit", "filled")):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=name)


def test_fused_tlb_raises_without_pallas_lowering():
    """No silent fallback: interpret=None on a platform without a Pallas
    lowering must raise, not quietly interpret."""
    if jax.default_backend() in ("tpu", "gpu"):
        pytest.skip("real Pallas lowering available")
    z = jnp.zeros((4, 2), jnp.int32)
    v = jnp.zeros((8,), jnp.int32)
    with pytest.raises(RuntimeError, match="no Pallas lowering"):
        fused_tlb_access(z, z, z, v, v, v, v, 0)
