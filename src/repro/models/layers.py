"""Common pure-JAX layers: RMSNorm, RoPE, SwiGLU MLP, embeddings."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.params import Param


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rmsnorm_params(d: int):
    return {"scale": Param((d,), ("embed",), init="ones", dtype=jnp.float32)}


def rmsnorm(params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * params["scale"]
    return y.astype(x.dtype)


def head_rmsnorm_params(dh: int):
    return {"scale": Param((dh,), (None,), init="ones", dtype=jnp.float32)}


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(dh: int, theta: float) -> jax.Array:
    """Inverse frequencies, shape (dh//2,), float32."""
    return 1.0 / (theta ** (jnp.arange(0, dh, 2, dtype=jnp.float32) / dh))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, dh); positions: broadcastable to (..., seq)."""
    dh = x.shape[-1]
    inv = rope_freqs(dh, theta)                       # (dh/2,)
    ang = positions[..., :, None].astype(jnp.float32) * inv  # (..., seq, dh/2)
    sin = jnp.sin(ang)[..., None, :]                  # (..., seq, 1, dh/2)
    cos = jnp.cos(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def mlp_params(d: int, d_ff: int):
    return {
        "w_gate": Param((d, d_ff), ("embed", "ffn")),
        "w_up": Param((d, d_ff), ("embed", "ffn")),
        "w_down": Param((d_ff, d), ("ffn", "embed")),
    }


def mlp(params, x: jax.Array) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, params["w_gate"])
    u = jnp.einsum("...d,df->...f", x, params["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, params["w_down"])


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------

def embed_params(vocab: int, d: int):
    return {"table": Param((vocab, d), ("vocab", "embed"), scale=1.0)}


def embed(params, tokens: jax.Array) -> jax.Array:
    return jnp.take(params["table"], tokens, axis=0)


def unembed(params, x: jax.Array) -> jax.Array:
    """Returns logits (..., vocab) — callers apply vocab-parallel CE without
    replicating the full logits tensor (sharding constraint applied upstream)."""
    return jnp.einsum("...d,vd->...v", x, params["table"])


def lm_head_params(vocab: int, d: int):
    return {"table": Param((vocab, d), ("vocab", "embed"))}
