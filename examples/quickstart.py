"""Quickstart: the three layers of the repo in a few minutes on CPU.

1. MASK policy objects (the paper's contribution) driving a toy TLB.
2. The memory-hierarchy simulator via the composable design-point API:
   registry designs, a custom `with_`-derived design, and the typed
   `Experiment`/`sweep` façade on one workload pair.
3. A reduced LM: one training step + one decode step through the public API.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

# ----------------------------------------------------------------- 1. MASK
from repro.core import tlb as tlb_mod
from repro.core import tokens as tok_mod

print("== 1. MASK policies ==")
tlb = tlb_mod.init(n_entries=512, n_ways=16)      # the shared L2 TLB
toks = tok_mod.init(n_apps=2, warps_per_app=jnp.asarray([720, 720]))
vpn = jnp.asarray([11, 12, 13], jnp.int32)
asid = jnp.asarray([0, 0, 1], jnp.int32)
tlb = tlb_mod.fill(tlb, vpn, asid, jnp.ones(3, bool), 1)
tlb, hit = tlb_mod.probe(tlb, vpn, asid, jnp.ones(3, bool), 2)
print("probe hits after fill:", np.asarray(hit))
print("initial tokens (80% of warps):", np.asarray(toks.tokens))

# ------------------------------------------------------------ 2. simulator
print("\n== 2. simulator: design registry + Experiment on 3DS+BLK ==")
from repro.core.design import get_design, register_design
from repro.sim.runner import sweep

# a custom design point: MASK with a lower initial token budget and the
# L2 bypass disabled — composed from specs, no simulator edits needed
my_design = get_design("mask").with_(name="mask-lean",
                                     tokens=dict(initial_frac=0.1),
                                     bypass=dict(enabled=False))
register_design(my_design)

# sweep groups designs by static signature and runs each group's whole
# design x mix grid (solo IPC_alone baselines included) as ONE compiled,
# vmapped execution — these three designs share a single program
for res in sweep(["gpu-mmu", "mask", "mask-lean"],
                 [("3DS", "BLK")], cycles=9000).values():
    r = res[0]
    print(f"{res.design.name:10s} ws={r.weighted_speedup():.2f} "
          f"ipc={np.round(r['ipc'], 1)} "
          f"sharedTLB hit={np.round(r['l2_hit_rate'], 2)}")

# -------------------------------------------------------------- 3. tiny LM
print("\n== 3. reduced llama3: one train step + one decode step ==")
from repro.configs import ARCHS, reduced_model
from repro.configs.base import RunConfig, ShapeConfig
from repro.models import model as M
from repro.train import optimizer as opt_mod
from repro.train.step import build_train_step

cfg = reduced_model(ARCHS["llama3-8b"])
shape = ShapeConfig("demo", seq_len=32, global_batch=2, kind="train")
run = RunConfig(model=cfg, shape=shape, remat=False,
                attn_block_q=16, attn_block_k=16)
params = M.init_params(jax.random.PRNGKey(0), cfg)
ocfg = opt_mod.OptConfig(lr=1e-3, warmup_steps=1)
step = build_train_step(cfg, run, ocfg)
rng = np.random.RandomState(0)
batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (2, 32))),
         "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (2, 32)))}
params, opt_state, metrics = step(params, opt_mod.init(params, ocfg), batch)
print(f"train loss: {float(metrics['loss']):.3f}")

logits, caches = M.forward_prefill(
    cfg, run, params, {"tokens": batch["tokens"][:, :8]}, max_len=64)
tok = jnp.argmax(logits[:, -1], -1)[:, None]
logits, caches = M.forward_decode(cfg, run, params, {"tokens": tok}, caches)
print("decode logits shape:", logits.shape, "— done.")
