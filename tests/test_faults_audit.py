"""Fault injection (sim.faults) + state auditor (sim.audit).

Chaos runs must be deterministic (same plan, same bits), must always
complete with finite stats and an audit-clean state, and must not
fragment the compile cache (fault operands are data). The auditor must
pass on healthy states and fail loudly — naming the invariant — on
injected corruptions.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.sim import runner
from repro.sim.audit import AuditError, check_monotone, check_state
from repro.sim.config import SimConfig
from repro.sim.faults import (FAULT_KINDS, Fault, FaultPlan, plan_operands,
                              random_plan)
from repro.sim.runner import run_trace

MIX = ("3DS", "BLK")
SCHED = [MIX, ("3DS", None), ("SC", "MUM"), ("SC", "MUM")]

ALL_KINDS_PLAN = FaultPlan(seed=11, faults=(
    Fault("kill", 1, app=0),
    Fault("tlb_flush", 2, level=1),
    Fault("tlb_corrupt", 2, app=1),
    Fault("drop_dram", 3),
    Fault("walk_clobber", 3, app=0),
))


def _final_state(schedule=None, **kw):
    tr = run_trace("mask", schedule or [MIX, MIX], seg_cycles=250,
                   return_state=True, collect_segments=False, **kw)
    # np.array copies: device_get views can be read-only, and the audit
    # tests mutate the state in place to inject corruption
    st = jax.tree_util.tree_map(np.array, jax.device_get(tr.final_state))
    return tr, st


def test_fault_plan_replay_is_bitwise():
    a = run_trace("mask", SCHED, seg_cycles=250, fault_plan=ALL_KINDS_PLAN)
    b = run_trace("mask", SCHED, seg_cycles=250, fault_plan=ALL_KINDS_PLAN)
    for k in a.stats:
        assert np.asarray(a.stats[k]).tobytes() == \
            np.asarray(b.stats[k]).tobytes(), k


@pytest.mark.parametrize("seed", [1, 2, 3])
def test_fault_runs_finish_finite_and_audit_clean(seed):
    plan = random_plan(seed, len(SCHED), 2)
    tr = run_trace("mask", SCHED, seg_cycles=250, fault_plan=plan,
                   audit=True)   # auditor runs on every snapshot
    for s in tr.segments:
        assert np.isfinite(s["ipc"]).all()
    assert np.isfinite(tr.stats["ipc"]).all()


def test_every_fault_kind_is_exercised_and_audit_clean():
    kinds = {f.kind for f in ALL_KINDS_PLAN.faults}
    assert kinds == set(FAULT_KINDS)
    tr = run_trace("mask", SCHED, seg_cycles=250,
                   fault_plan=ALL_KINDS_PLAN, audit=True)
    assert np.isfinite(tr.stats["ipc"]).all()


def test_fault_plan_does_not_fragment_compile_cache():
    seg = 190   # unique seg_cycles: this test owns its cache entry
    t0 = runner.TRACE_COUNT
    run_trace("mask", [MIX, MIX], seg_cycles=seg)
    traced = runner.TRACE_COUNT - t0
    assert traced == 1
    plan = FaultPlan(seed=5, faults=(Fault("tlb_flush", 1),))
    run_trace("mask", [MIX, MIX], seg_cycles=seg, fault_plan=plan)
    assert runner.TRACE_COUNT - t0 == traced, \
        "a fault plan must ride the no-fault trace (operands are data)"


def test_fault_plan_validation():
    with pytest.raises(ValueError, match="kind"):
        Fault("meteor-strike", 0)
    with pytest.raises(ValueError, match="segment"):
        Fault("kill", -1)
    plan = FaultPlan(seed=0, faults=(Fault("kill", 9, app=0),))
    with pytest.raises(ValueError, match="only 2 segments"):
        run_trace("mask", [MIX, MIX], seg_cycles=100, fault_plan=plan)
    cfg = SimConfig(n_apps=2)
    with pytest.raises(ValueError, match="kills app slot"):
        plan_operands(FaultPlan(0, (Fault("kill", 0, app=7),)), cfg, 2)


def test_operand_lowering_is_deterministic():
    cfg = SimConfig(n_apps=2)
    a = plan_operands(ALL_KINDS_PLAN, cfg, len(SCHED))
    b = plan_operands(ALL_KINDS_PLAN, cfg, len(SCHED))
    for x, y in zip(a, b):
        assert np.array_equal(x, y)
    assert a.kill[1, 0] and a.flush[2, 1] and a.corrupt[2]
    assert a.drop_dram[3] and a.clobber[3]


# ------------------------------------------------------------------ audit

def test_audit_passes_on_healthy_states():
    tr, st = _final_state(SCHED)
    cfg = SimConfig(n_apps=2, sim_cycles=250, design=tr.design)
    check_state(cfg, st)   # must not raise


def _cfg_for(tr):
    return SimConfig(n_apps=2, sim_cycles=250, design=tr.design)


def test_audit_catches_stale_asid():
    tr, st = _final_state()
    st.trans.l2tlb.tags[0, 0] = 777
    st.trans.l2tlb.asids[0, 0] = 9   # not a live generation of any slot
    with pytest.raises(AuditError, match="stale translation"):
        check_state(_cfg_for(tr), st)


def test_audit_catches_duplicate_entries():
    tr, st = _final_state()
    for w in (0, 1):
        st.trans.l2tlb.tags[3, w] = 555
        st.trans.l2tlb.asids[3, w] = 0
    with pytest.raises(AuditError, match="duplicate"):
        check_state(_cfg_for(tr), st)


def test_audit_catches_tag_asid_disagreement():
    tr, st = _final_state()
    st.trans.l1.tags[2, 0, 0] = 42      # valid tag...
    st.trans.l1.asids[2, 0, 0] = -1     # ...without an owner
    with pytest.raises(AuditError, match="validity disagree"):
        check_state(_cfg_for(tr), st)


def test_audit_catches_token_and_counter_corruption():
    tr, st = _final_state()
    st.tokens.tokens[0] = 0
    st.stats.ints[1, 2] = -5
    with pytest.raises(AuditError) as ei:
        check_state(_cfg_for(tr), st)
    msg = str(ei.value)
    assert "tokens outside" in msg and "int counters negative" in msg
    assert len(ei.value.violations) == 2   # collected, not first-only


def test_audit_catches_future_lru_and_dead_walk():
    tr, st = _final_state()
    st.trans.l2tlb.lru[1, 1] = int(st.t) + 999
    st.trans.walk[0] = (123, 9, int(st.t) + 50, 1)  # in-flight, dead asid
    with pytest.raises(AuditError) as ei:
        check_state(_cfg_for(tr), st)
    msg = str(ei.value)
    assert "LRU stamp" in msg and "dead ASID" in msg


def test_audit_monotone():
    tr1, s1 = _final_state([MIX])
    tr2, s2 = _final_state([MIX, MIX])
    check_monotone(s1, s2)                      # must not raise
    with pytest.raises(AuditError, match="decreased|backwards"):
        check_monotone(s2, s1)
    # a changed slot may reset its counters without tripping the law
    ch = np.array([False, True])
    s2.stats.ints[1, :] = 0
    check_monotone(s1, s2, changed=ch)
    with pytest.raises(AuditError, match="decreased"):
        check_monotone(s1, s2, changed=np.array([False, False]))


def test_stats_env_gating(monkeypatch):
    tr, st = _final_state()
    st.trans.l2tlb.tags[0, 0] = 777
    st.trans.l2tlb.asids[0, 0] = 9
    cfg = _cfg_for(tr)
    monkeypatch.setenv("REPRO_AUDIT", "1")
    with pytest.raises(AuditError):
        runner._stats(cfg, st)
    monkeypatch.setenv("REPRO_AUDIT", "0")
    runner._stats(cfg, st)              # gating off: stats still compute
    monkeypatch.setenv("REPRO_AUDIT", "1")
    runner._stats(cfg, st, audit=False)  # explicit False beats the env


def test_fault_plan_on_simconfig_is_hashable_and_canonical_strips_it():
    cfg = SimConfig(n_apps=2, fault_plan=ALL_KINDS_PLAN)
    hash(cfg)               # frozen + hashable (keys nothing, but must not raise)
    assert runner._canonical(cfg).fault_plan is None
    assert runner._canonical(cfg) == runner._canonical(
        dataclasses.replace(cfg, fault_plan=None))
