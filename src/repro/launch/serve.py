"""Serving launcher: multi-tenant continuous batching on the reduced config.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --tenants 2 \
      --requests 8
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_model, reduced_model
from repro.configs.base import RunConfig, ShapeConfig
from repro.memmgr.kv_cache import PoolConfig
from repro.models import model as M
from repro.serving import metrics as smet
from repro.serving.engine import EngineConfig, Request, ServingEngine


def build_engine(arch: str, max_seqs: int = 16):
    cfg = reduced_model(get_model(arch))
    shape = ShapeConfig("serve", seq_len=64, global_batch=1, kind="decode")
    run = RunConfig(model=cfg, shape=shape, remat=False,
                    attn_block_q=16, attn_block_k=16)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    n_attn = sum(1 for k in cfg.layer_kinds() if k == "attn")
    pool = PoolConfig(
        n_pages=max_seqs * 8, page_size=cfg.kv_page_size,
        n_kv=max(cfg.n_kv_heads, 1), head_dim=cfg.head_dim if cfg.n_heads else 1,
        n_layers=max(n_attn, 1), max_seqs=max_seqs, pages_per_seq=8)
    return ServingEngine(cfg, run, params, pool)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--tenants", type=int, default=2)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    eng = build_engine(args.arch)
    rng = np.random.RandomState(0)
    for i in range(args.requests):
        eng.submit(Request(
            rid=i, tenant=i % args.tenants,
            prompt=rng.randint(0, eng.cfg.vocab_size, args.prompt_len),
            max_new=args.max_new))
    finished = eng.run_until_drained()
    tput = smet.tenant_throughput(finished, eng.step_count)
    print(f"finished {len(finished)} requests in {eng.step_count} steps")
    for t, v in sorted(tput.items()):
        print(f"  tenant {t}: {v:.2f} tok/step")
    print(f"mean latency {smet.mean_latency(finished):.1f} steps")


if __name__ == "__main__":
    main()
