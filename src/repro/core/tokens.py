"""TLB-Fill Tokens (paper §5.2).

Every warp may PROBE the shared L2 TLB; only warps holding a token may FILL
it. Token counts are per-application, adapted each epoch by hill-climbing on
the shared-TLB miss-rate delta (the hardware is "30 15-bit token counts with
30 1-bit token direction entries", §7.5 — i.e. direction-based adjustment):

  * miss rate improved since last epoch  -> keep adjusting in same direction
  * miss rate worsened                   -> reverse direction

Tokens are handed to warps round-robin in warpID order (paper: even miss
distribution across warps + token retention beats fancier policies).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class TokenState(NamedTuple):
    tokens: jax.Array          # (n_apps,) int32 current token count
    direction: jax.Array       # (n_apps,) int32 in {-1, +1}
    prev_miss_rate: jax.Array  # (n_apps,) float32
    epoch_hits: jax.Array      # (n_apps,) int32   (shared-TLB hits this epoch)
    epoch_misses: jax.Array    # (n_apps,) int32
    first_epoch: jax.Array     # () bool — no bypassing during warm-up epoch


def init(n_apps: int, warps_per_app, initial_frac: float = 0.8) -> TokenState:
    """warps_per_app: (n_apps,) total warps — InitialTokens = 80% (paper §6)."""
    warps_per_app = jnp.asarray(warps_per_app, jnp.int32)
    return TokenState(
        tokens=jnp.maximum((warps_per_app * initial_frac).astype(jnp.int32), 1),
        # fills start restricted-downward: the mechanism's premise is that
        # fewer fillers reduce thrashing; the climb reverses if that fails
        direction=jnp.full((n_apps,), -1, jnp.int32),
        prev_miss_rate=jnp.ones((n_apps,), jnp.float32),
        epoch_hits=jnp.zeros((n_apps,), jnp.int32),
        epoch_misses=jnp.zeros((n_apps,), jnp.int32),
        first_epoch=jnp.array(True),
    )


def record(state: TokenState, app, hit, active) -> TokenState:
    """Accumulate per-app shared-TLB hit/miss counters. app/hit/active: (N,)."""
    n_apps = state.tokens.shape[0]
    oh = jax.nn.one_hot(app, n_apps, dtype=jnp.int32)
    h = (oh * (hit & active)[:, None]).sum(0)
    m = (oh * ((~hit) & active)[:, None]).sum(0)
    return state._replace(epoch_hits=state.epoch_hits + h,
                          epoch_misses=state.epoch_misses + m)


def has_token(state: TokenState, app, warp_slot) -> jax.Array:
    """Round-robin in warpID order: warp w of app a holds a token iff
    w < tokens[a] (token retention: low warp ids keep theirs across epochs)."""
    return warp_slot < state.tokens[app]


def epoch_update(state: TokenState, warps_per_app, step_frac: float = 0.5,
                 min_tokens: int = 1) -> TokenState:
    """End-of-epoch token adjustment (Fig. 13b hill-climb).

    Steps are geometric (x(1±step_frac)): our simulated epochs are ~20x
    shorter than the paper's 100K cycles, so the equivalent convergence
    needs multiplicative moves; direction semantics match the hardware's
    1-bit-direction design."""
    warps_per_app = jnp.asarray(warps_per_app, jnp.int32)
    total = jnp.maximum(state.epoch_hits + state.epoch_misses, 1)
    miss_rate = state.epoch_misses / total

    improved = miss_rate <= state.prev_miss_rate - 0.01
    new_dir = jnp.where(improved, state.direction, -state.direction)
    step = jnp.maximum((state.tokens * step_frac).astype(jnp.int32), 1)
    proposed = state.tokens + new_dir * step
    new_tokens = jnp.clip(proposed, min_tokens, warps_per_app)
    # bounce off the clip bounds instead of saturating there
    new_dir = jnp.where(proposed != new_tokens, -new_dir, new_dir)
    # during the warm-up epoch no bypassing happens — only install baselines
    new_tokens = jnp.where(state.first_epoch, state.tokens, new_tokens)
    new_dir = jnp.where(state.first_epoch, state.direction, new_dir)

    return TokenState(
        tokens=new_tokens,
        direction=new_dir,
        prev_miss_rate=miss_rate,
        epoch_hits=jnp.zeros_like(state.epoch_hits),
        epoch_misses=jnp.zeros_like(state.epoch_misses),
        first_epoch=jnp.array(False),
    )
