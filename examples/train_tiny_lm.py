"""End-to-end driver: train a reduced llama3 for a few hundred steps with
checkpointing, then restart from the snapshot (fault-tolerance demo).

Run:  PYTHONPATH=src python examples/train_tiny_lm.py [--steps 200]
"""
import argparse
import tempfile

from repro.configs import ARCHS, reduced_model
from repro.configs.base import RunConfig, ShapeConfig
from repro.train import optimizer as opt_mod
from repro.train.loop import TrainConfig, train

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--arch", default="llama3-8b")
args = ap.parse_args()

cfg = reduced_model(ARCHS[args.arch])
shape = ShapeConfig("demo", seq_len=64, global_batch=8, kind="train")
run = RunConfig(model=cfg, shape=shape, remat=True, microbatches=2,
                attn_block_q=32, attn_block_k=32)

with tempfile.TemporaryDirectory() as d:
    tcfg = TrainConfig(steps=args.steps, ckpt_dir=d, ckpt_every=50,
                       log_every=20,
                       opt=opt_mod.OptConfig(lr=3e-3, warmup_steps=20))
    out = train(cfg, run, tcfg)
    h = out["history"]
    print(f"\nloss {h[0]['loss']:.3f} -> {h[-1]['loss']:.3f} "
          f"over {args.steps} steps")

    # simulate a preemption: resume from the last snapshot for 50 more steps
    tcfg2 = TrainConfig(steps=args.steps + 50, ckpt_dir=d, ckpt_every=50,
                        log_every=20,
                        opt=opt_mod.OptConfig(lr=3e-3, warmup_steps=20))
    out2 = train(cfg, run, tcfg2)
    print(f"resumed from checkpoint and reached step {args.steps + 50}: "
          f"loss {out2['history'][-1]['loss']:.3f}")
