"""Vocab-parallel cross-entropy. Never replicates the full [B,S,V] logits:
the vocab axis stays sharded on the `model` mesh axis and XLA inserts the
reductions (max / sum-exp / label gather) as collectives."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cross_entropy(logits: jax.Array, labels: jax.Array, mask=None,
                  real_vocab=None):
    """logits: (B, S, V_padded); labels: (B, S) int32; mask: (B, S) optional.

    ``real_vocab``: logical vocab size — padded tail columns are masked out
    (embedding tables are padded to a 128 multiple for even sharding).
    Returns (mean_loss, metrics). fp32 math regardless of logits dtype.
    """
    lf = logits.astype(jnp.float32)
    if real_vocab is not None and real_vocab < logits.shape[-1]:
        vmask = jnp.arange(logits.shape[-1]) < real_vocab
        lf = jnp.where(vmask, lf, -1e30)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(nll * mask) / denom
    acc = jnp.sum((jnp.argmax(lf, axis=-1) == labels) * mask) / denom
    return loss, {"loss": loss, "accuracy": acc, "tokens": denom}
