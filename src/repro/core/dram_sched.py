"""Address-Space-Aware DRAM Scheduler (paper §5.4).

Three queues per memory channel:

  Golden  — all translation (page-walk) requests; small FIFO; always first.
  Silver  — data requests of ONE application at a time; quota per Eq. (1):
              thres_i = thres_max * (Concurrent_i * WrpStalled_i)
                        / sum_j (Concurrent_j * WrpStalled_j)
  Normal  — everything else. FR-FCFS (row hits first) within Silver/Normal;
            Golden is FIFO (walk requests have poor row locality, fn. 5).

The batched model used by the simulator: each step a channel can service
``slots`` requests. Requests are ranked (queue priority, row-hit, age) and
the top ``slots`` complete with latencies derived from row hit/miss; the
per-bank open row and per-app silver accounting update functionally.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

T_ROW_HIT = 100      # cycles: CAS-only access (GPU clock domain)
T_ROW_MISS = 250     # cycles: precharge + activate + CAS
T_QUEUE_UNIT = 50    # serialization per queued-ahead request


class DramState(NamedTuple):
    open_row: jax.Array        # (channels, banks) int32 open row id
    silver_app: jax.Array      # () int32 — app currently owning Silver
    silver_left: jax.Array     # () int32 — remaining silver quota
    conc_walks: jax.Array      # (n_apps,) int32 'Concurrent_i' (6-bit, §5.4)
    warps_stalled: jax.Array   # (n_apps,) int32 'WrpStalled_i'
    queue_len: jax.Array       # (channels, 3) int32 backlog per class


def init(n_channels: int, n_banks: int, n_apps: int) -> DramState:
    return DramState(
        open_row=jnp.full((n_channels, n_banks), -1, jnp.int32),
        silver_app=jnp.zeros((), jnp.int32),
        silver_left=jnp.full((), 1, jnp.int32),
        conc_walks=jnp.zeros((n_apps,), jnp.int32),
        warps_stalled=jnp.zeros((n_apps,), jnp.int32),
        queue_len=jnp.zeros((n_channels, 3), jnp.int32),
    )


def silver_quota(state: DramState, thres_max: int = 500) -> jax.Array:
    """(n_apps,) Eq. (1) thresholds."""
    w = (state.conc_walks * state.warps_stalled).astype(jnp.float32)
    tot = jnp.maximum(w.sum(), 1.0)
    return jnp.maximum((thres_max * w / tot).astype(jnp.int32), 1)


def classify(state: DramState, app, is_tlb, mask_enabled: bool):
    """queue class per request: 0 golden, 1 silver, 2 normal."""
    if not mask_enabled:
        return jnp.full(app.shape, 2, jnp.int32)
    silver = (app == state.silver_app)
    return jnp.where(is_tlb, 0, jnp.where(silver, 1, 2))


def access(state: DramState, channel, bank, row, app, is_tlb, active,
           mask_enabled: bool, thres_max: int = 500,
           fr_fcfs: bool = True) -> Tuple[DramState, jax.Array]:
    """Batched DRAM access model. All args (N,). Returns (state', latency (N,)).

    Latency = service (row hit/miss) + queueing: number of requests this
    step that rank ahead of you on the same channel (priority-class first,
    then row-hit-first within class) × T_QUEUE_UNIT + standing backlog.
    """
    n_channels, n_banks = state.open_row.shape
    cls = classify(state, app, is_tlb, mask_enabled)

    row_hit = state.open_row[channel, bank] == row
    service = jnp.where(row_hit, T_ROW_HIT, T_ROW_MISS)

    # rank = priority ahead of me on my (channel, bank) this step — banks
    # service in parallel
    same_ch = (channel[None, :] == channel[:, None]) \
        & (bank[None, :] == bank[:, None]) & active[None, :]
    if fr_fcfs:
        key_other = cls[None, :] * 2 + (~row_hit[None, :])
        key_mine = (cls * 2 + (~row_hit))[:, None]
    else:  # pure FCFS
        key_other = cls[None, :] * 2
        key_mine = (cls * 2)[:, None]
    order = jnp.arange(app.shape[0])
    ahead = same_ch & ((key_other < key_mine)
                       | ((key_other == key_mine)
                          & (order[None, :] < order[:, None])))
    n_ahead = ahead.sum(axis=1)

    backlog = state.queue_len[channel, cls]
    latency = service + (n_ahead + backlog) * T_QUEUE_UNIT
    latency = jnp.where(active, latency, 0)

    # ---- state updates ----
    # open rows: last active request per (channel, bank) wins
    new_open = state.open_row.at[channel, bank].set(
        jnp.where(active, row, state.open_row[channel, bank]))

    # silver rotation: consume quota for serviced silver requests
    served_silver = (active & (cls == 1)).sum(dtype=jnp.int32)
    left = state.silver_left - served_silver
    quota = silver_quota(state, thres_max)
    n_apps = state.conc_walks.shape[0]
    next_app = (state.silver_app + 1) % n_apps
    rotate = left <= 0
    silver_app = jnp.where(rotate, next_app, state.silver_app)
    silver_left = jnp.where(rotate, quota[next_app], left)

    # decay standing backlog toward observed per-class pressure (EWMA)
    counts = jnp.zeros((n_channels, 3), jnp.int32).at[channel, cls].add(
        active.astype(jnp.int32))
    queue_len = (state.queue_len * 3 + counts) // 4

    return state._replace(open_row=new_open, silver_app=silver_app,
                          silver_left=silver_left,
                          queue_len=queue_len), latency


def update_pressure(state: DramState, conc_walks, warps_stalled) -> DramState:
    """Refresh the Eq. (1) inputs (reset each epoch, §5.4)."""
    return state._replace(
        conc_walks=jnp.asarray(conc_walks, jnp.int32),
        warps_stalled=jnp.asarray(warps_stalled, jnp.int32))
