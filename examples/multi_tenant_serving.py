"""Multi-tenant serving with the MASK-style 3-class scheduler + paged KV,
now with simulator-driven admission placement.

A bursty heavy tenant and a light interactive tenant share one reduced
model. We replay the SAME seeded trace twice — once with admission wide
open ("none"), once with the contention oracle deciding placement — and
compare the light tenant's latency. The oracle maps each tenant's
declared app profile to a simulator benchmark, predicts the mix's
slowdowns with one batched `run_grid` call, and reserves admission
slots so the aggressor cannot crowd the victim out of the batch.

Run:  PYTHONPATH=src python examples/multi_tenant_serving.py
"""
from repro.launch.serve import build_engine
from repro.serving import metrics as smet
from repro.serving import stream as strm

STEPS = 24
trace = strm.make_trace("flood_vs_trickle", seed=0, steps=STEPS)
print(f"trace {trace.name}: {STEPS} steps, tenants {trace.profiles()}")

results = {}
for policy in ("none", "oracle"):
    eng = build_engine("qwen3-4b", policy=policy,
                       profiles=trace.profiles(),
                       **({"cycles": 300} if policy == "oracle" else {}))
    finished = strm.drive(eng, trace)
    lat = smet.tenant_mean_latency(finished)
    ttft = smet.tenant_ttft(finished)
    results[policy] = lat
    print(f"\npolicy={policy}: {len(finished)} requests drained in "
          f"{eng.step_count} engine steps")
    for t in sorted(lat):
        n = sum(1 for r in finished if r.tenant == t)
        print(f"  tenant {t} ({trace.profiles()[t]}): {n} reqs, "
              f"mean latency {lat[t]:.1f} steps, "
              f"TTFT {ttft.get(t, float('nan')):.1f}")
    if eng.decisions:
        summ = smet.decision_summary(eng.decisions)
        pred = summ["predicted_max_slowdown_mean"]
        if pred is not None:
            print(f"  oracle: {summ['epochs']} decisions, "
                  f"predicted max slowdown {pred:.3f}")

victim = max(trace.profiles())    # the interactive tenant
if victim in results["none"] and victim in results["oracle"]:
    print(f"\nlight tenant mean latency: none={results['none'][victim]:.1f} "
          f"-> oracle={results['oracle'][victim]:.1f} steps")
print("(the oracle's reserved admission slots keep the interactive "
      "tenant's latency near solo even mid-burst — the paper's "
      "contention-aware placement at the serving layer)")
