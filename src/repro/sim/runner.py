"""Simulation runner: N-app mixes, solo/pair wrappers, typed experiments.

Two API levels share one compiled core:

* Raw: `run_mix(design, benches)` co-runs len(benches) applications (None
  entries are idle partners) and returns a per-app stats dict.
  `run_pair` / `run_solo` are thin 2-app wrappers kept for the paper's
  pair-based experiments; `run_batch` vmaps many same-size mixes through
  one compile. `design` is a registered name, a `repro.core.design.Design`
  (including user-registered or ad-hoc compositions), or a legacy
  `DesignPoint`.

* Typed: `Experiment(design, mixes, cycles).run()` returns an
  `ExperimentResult` of `MixResult`/`AppStats` objects with the derived
  metrics (weighted speedup, unfairness, per-app hit rates) as
  methods/properties; `sweep(designs, mixes)` drives many designs,
  batching one compile per (design, n_apps).

Compiled executables are lru-cached on the full `SimConfig` — the
embedded `Design` hashes over every policy-spec field, so two designs
that differ in any spec never collide, even under the same name.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Mapping, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.design import Design, as_design
from repro.sim.config import SimConfig
from repro.sim.memsys import SimState, init_state, step
from repro.sim.workloads import app_matrix

jax.config.update("jax_enable_x64", False)

DesignLike = Union[str, Design]  # legacy DesignPoint also accepted


@functools.lru_cache(maxsize=64)
def _compiled_run(cfg: SimConfig):
    def run(params_mat):
        st = init_state(cfg)

        def body(s, _):
            return step(cfg, params_mat, s), None

        final, _ = jax.lax.scan(body, st, None, length=cfg.sim_cycles)
        return final

    return jax.jit(run)


@functools.lru_cache(maxsize=64)
def _compiled_batch_run(cfg: SimConfig):
    """vmapped over a leading batch of workload parameter matrices — one
    compile serves every mix/solo under a design."""
    return jax.jit(jax.vmap(_compiled_run(cfg)))


def _stats(cfg: SimConfig, st: SimState) -> Dict[str, np.ndarray]:
    # one bulk transfer for the whole state tree (no-op on numpy trees,
    # e.g. the per-mix slices run_batch hands over)
    st = jax.device_get(st)
    na = cfg.n_apps
    warp_app = np.repeat(np.asarray(cfg.app_of_core), cfg.warps_per_core)
    ipc = np.bincount(warp_app, weights=st.instr, minlength=na) / float(st.t)
    s = st.stats
    g = lambda x: np.asarray(x, np.float64)  # noqa: E731
    l1p = g(s.s_l1_hit) + g(s.s_l1_miss)
    l2p = g(s.s_l2_hit) + g(s.s_l2_miss)
    return {
        "ipc": ipc,
        "l1_hit_rate": g(s.s_l1_hit) / np.maximum(l1p, 1),
        "l1_miss_rate": g(s.s_l1_miss) / np.maximum(l1p, 1),
        "l2_hit_rate": g(s.s_l2_hit) / np.maximum(l2p, 1),
        "l2_miss_rate": g(s.s_l2_miss) / np.maximum(l2p, 1),
        "byp_hit_rate": g(s.s_byp_hit) / np.maximum(g(s.s_byp_probe), 1),
        "walk_lat": g(s.s_walk_lat) / np.maximum(g(s.s_walks), 1),
        "walks": g(s.s_walks),
        "stalls_per_miss": g(s.s_stall_per_miss) / np.maximum(g(s.s_walks), 1),
        "dram_tlb_lat": g(s.s_dram_tlb_lat) / np.maximum(g(s.s_dram_tlb_n), 1),
        "dram_data_lat": g(s.s_dram_data_lat)
        / np.maximum(g(s.s_dram_data_n), 1),
        "dram_tlb_n": g(s.s_dram_tlb_n),
        "dram_data_n": g(s.s_dram_data_n),
        # L2 data-cache hit rate for TLB requests (Table 5). np.maximum
        # (not builtin max) so these survive the counters going per-app.
        "l2c_tlb_hit_rate": (g(s.s_l2c_tlb_hit)
                             / np.maximum(g(s.s_l2c_tlb_probe), 1)),
        "l2c_data_hit_rate": (g(s.s_l2c_data_hit)
                              / np.maximum(g(s.s_l2c_data_probe), 1)),
        "tokens": np.asarray(st.tokens.tokens),
        "cycles": float(st.t),
    }


def _mix_matrix(benches: Sequence[Optional[str]]) -> np.ndarray:
    """(n_apps, N_FIELDS) parameter matrix; None entries are idle apps."""
    return app_matrix(list(benches))


def run_mix(design: DesignLike, benches: Sequence[Optional[str]],
            cycles: int = 60_000) -> Dict:
    """Co-run N apps under a design; returns per-app stats.

    `benches` may contain None for idle partners (the §6 `IPC_alone`
    emulation keeps the core split of the shared run but removes memory
    contention from the partner slots).
    """
    cfg = SimConfig(n_apps=len(benches), sim_cycles=cycles,
                    design=as_design(design))
    pm = jnp.asarray(_mix_matrix(benches))
    st = _compiled_run(cfg)(pm)
    return _stats(cfg, st)


def run_batch(design: DesignLike,
              bench_mixes: Sequence[Tuple[Optional[str], ...]],
              cycles: int = 60_000) -> List[Dict]:
    """Run many same-size workload mixes at once (vmap). An entry may
    contain None for a solo run (idle partner)."""
    sizes = {len(m) for m in bench_mixes}
    if len(sizes) != 1:
        raise ValueError(f"all mixes must have the same size, got {sizes}")
    cfg = SimConfig(n_apps=sizes.pop(), sim_cycles=cycles,
                    design=as_design(design))
    pm = jnp.asarray(np.stack([_mix_matrix(m) for m in bench_mixes]))
    # one bulk device->host transfer of the whole batched final state,
    # then cheap numpy views per mix (was B per-mix tree transfers)
    final = jax.device_get(_compiled_batch_run(cfg)(pm))
    out = []
    for i in range(len(bench_mixes)):
        sub = jax.tree_util.tree_map(lambda x: x[i], final)
        out.append(_stats(cfg, sub))
    return out


def run_pair(design: DesignLike, bench_a: str, bench_b: str,
             cycles: int = 60_000) -> Dict:
    """Co-run two apps under a design; returns per-app stats."""
    return run_mix(design, [bench_a, bench_b], cycles)


def run_solo(design: DesignLike, bench: str, cycles: int = 60_000) -> Dict:
    """IPC_alone: same core count as in the shared run (paper §6),
    exclusive memory system — emulated by pairing with an idle app."""
    return run_mix(design, [bench, None], cycles)


def weighted_speedup(mix_stats, *solos) -> float:
    """Sum of per-app IPC / IPC_alone over the mix (any N)."""
    return float(sum(mix_stats["ipc"][i] / max(s["ipc"][0], 1e-9)
                     for i, s in enumerate(solos)))


def max_slowdown(mix_stats, *solos) -> float:
    """Unfairness: worst per-app IPC_alone / IPC over the mix (any N)."""
    return float(max(s["ipc"][0] / max(mix_stats["ipc"][i], 1e-9)
                     for i, s in enumerate(solos)))


# ---------------------------------------------------------------------------
# typed results layer
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AppStats:
    """One application's slice of a mix run. `ipc_alone` is the §6
    IPC_alone baseline (same core share, idle partners) when the
    experiment computed solo baselines, else None."""

    bench: Optional[str]          # None = idle partner slot
    index: int                    # position in the mix
    ipc: float
    ipc_alone: Optional[float]
    l1_tlb_hit_rate: float
    l2_tlb_hit_rate: float        # shared L2 TLB (Table 3)
    bypass_hit_rate: float        # token bypass cache (Table 4)
    walk_lat: float               # mean page-walk latency (cycles)
    walks: float
    stalls_per_miss: float
    dram_tlb_lat: float           # mean DRAM latency, walk requests
    dram_data_lat: float          # mean DRAM latency, data requests
    tokens: int                   # final TLB-fill token count

    @property
    def speedup(self) -> float:
        """IPC / IPC_alone (this app's weighted-speedup contribution)."""
        if self.ipc_alone is None:
            raise ValueError("run the experiment with solo baselines")
        return self.ipc / max(self.ipc_alone, 1e-9)

    @property
    def slowdown(self) -> float:
        """IPC_alone / IPC (this app's unfairness contribution)."""
        if self.ipc_alone is None:
            raise ValueError("run the experiment with solo baselines")
        return self.ipc_alone / max(self.ipc, 1e-9)


@dataclasses.dataclass(frozen=True, eq=False)
class MixResult:
    """One mix under one design: per-app `AppStats` + mix-level metrics.
    The raw stats dict stays reachable via `.raw` / `res[key]`."""

    design: Design
    benches: Tuple[Optional[str], ...]
    cycles: int
    apps: Tuple[AppStats, ...]
    raw: Mapping[str, np.ndarray]

    def __getitem__(self, key: str):
        return self.raw[key]

    def app(self, bench: str) -> AppStats:
        """First AppStats running `bench` (mixes may repeat a bench)."""
        for a in self.apps:
            if a.bench == bench:
                return a
        raise KeyError(f"{bench!r} not in mix {self.benches}")

    @property
    def real_apps(self) -> Tuple[AppStats, ...]:
        """Apps excluding idle-partner (None) slots."""
        return tuple(a for a in self.apps if a.bench is not None)

    @property
    def l2c_tlb_hit_rate(self) -> float:
        """L2 data-cache hit rate for TLB (walk) requests (Table 5)."""
        return float(self.raw["l2c_tlb_hit_rate"])

    @property
    def l2c_data_hit_rate(self) -> float:
        return float(self.raw["l2c_data_hit_rate"])

    def weighted_speedup(self) -> float:
        """Sum of IPC / IPC_alone over the real apps (paper Eq. WS)."""
        return float(sum(a.speedup for a in self.real_apps))

    def unfairness(self) -> float:
        """Max per-app slowdown over the real apps (paper max slowdown)."""
        return float(max(a.slowdown for a in self.real_apps))

    max_slowdown = unfairness


@dataclasses.dataclass(frozen=True, eq=False)
class ExperimentResult:
    """All mixes of one `Experiment`, aligned with its mix list."""

    design: Design
    cycles: int
    results: Tuple[MixResult, ...]
    solo_ipc: Mapping[Tuple[str, int], float]  # (bench, n_apps) -> IPC_alone

    def __iter__(self):
        return iter(self.results)

    def __len__(self) -> int:
        return len(self.results)

    def __getitem__(self, i) -> MixResult:
        return self.results[i]

    def mean_weighted_speedup(self) -> float:
        return float(np.mean([r.weighted_speedup() for r in self.results]))

    def mean_unfairness(self) -> float:
        return float(np.mean([r.unfairness() for r in self.results]))


@dataclasses.dataclass(frozen=True)
class Experiment:
    """Typed façade over `run_batch`: a design × a list of mixes.

    `design` may be a registered name, a `Design`, or a legacy
    `DesignPoint`; `mixes` entries are bench tuples (a bare bench name
    means a 1-app run; None entries are idle partners). Mixes of
    different sizes are allowed — each (design, n_apps) group is one
    vmapped compile, with the solo baselines batched into the same call.

        exp = Experiment("mask", [("3DS", "BLK"), ("MUM", "RED")])
        res = exp.run()
        res.mean_weighted_speedup()
        res[0].app("3DS").l2_tlb_hit_rate
    """

    design: DesignLike
    mixes: Tuple[Tuple[Optional[str], ...], ...]
    cycles: int = 60_000

    def __post_init__(self):
        object.__setattr__(self, "design", as_design(self.design))
        if isinstance(self.mixes, str):
            raise TypeError(
                f"mixes must be a sequence of mixes, got the bare string "
                f"{self.mixes!r} — did you mean [({self.mixes!r},)]?")
        norm = tuple((m,) if isinstance(m, str) else tuple(m)
                     for m in self.mixes)
        if not norm:
            raise ValueError("Experiment needs at least one mix")
        object.__setattr__(self, "mixes", norm)

    def run(self, solo_baselines: bool = True) -> ExperimentResult:
        by_n: Dict[int, List[Tuple[int, Tuple[Optional[str], ...]]]] = {}
        for i, m in enumerate(self.mixes):
            by_n.setdefault(len(m), []).append((i, m))

        results: List[Optional[MixResult]] = [None] * len(self.mixes)
        solo_ipc: Dict[Tuple[str, int], float] = {}
        for n, items in sorted(by_n.items()):
            mixes = [m for _, m in items]
            benches = sorted({b for m in mixes for b in m
                              if b is not None}) if solo_baselines else []
            # a user mix that IS the canonical solo shape (bench + idle
            # partners) doubles as its own baseline — don't simulate twice
            solo_shaped = {m for m in mixes
                           if m[0] is not None and not any(m[1:])}
            solo_mixes = [(b,) + (None,) * (n - 1) for b in benches]
            solo_mixes = [sm for sm in solo_mixes if sm not in solo_shaped]
            # one compile per (design, n_apps): mixes + solos in one batch
            stats = run_batch(self.design, mixes + solo_mixes,
                              cycles=self.cycles)
            for m, s in zip(mixes, stats):
                if m in solo_shaped:
                    solo_ipc[(m[0], n)] = float(s["ipc"][0])
            for sm, s in zip(solo_mixes, stats[len(mixes):]):
                solo_ipc[(sm[0], n)] = float(s["ipc"][0])
            for (i, m), s in zip(items, stats[:len(mixes)]):
                results[i] = self._mix_result(m, s, solo_ipc, n)
        return ExperimentResult(design=self.design, cycles=self.cycles,
                                results=tuple(results), solo_ipc=solo_ipc)

    def _mix_result(self, benches, s, solo_ipc, n) -> MixResult:
        apps = tuple(
            AppStats(
                bench=b, index=i,
                ipc=float(s["ipc"][i]),
                ipc_alone=solo_ipc.get((b, n)),
                l1_tlb_hit_rate=float(s["l1_hit_rate"][i]),
                l2_tlb_hit_rate=float(s["l2_hit_rate"][i]),
                bypass_hit_rate=float(s["byp_hit_rate"][i]),
                walk_lat=float(s["walk_lat"][i]),
                walks=float(s["walks"][i]),
                stalls_per_miss=float(s["stalls_per_miss"][i]),
                dram_tlb_lat=float(s["dram_tlb_lat"][i]),
                dram_data_lat=float(s["dram_data_lat"][i]),
                tokens=int(s["tokens"][i]),
            ) for i, b in enumerate(benches))
        return MixResult(design=self.design, benches=tuple(benches),
                         cycles=self.cycles, apps=apps, raw=s)


def sweep(designs: Sequence[DesignLike],
          mixes: Sequence, cycles: int = 60_000,
          solo_baselines: bool = True) -> Dict[str, ExperimentResult]:
    """Run several designs over the same mixes: one `Experiment` per
    design (so one compile per (design, n_apps)), keyed by design name."""
    out: Dict[str, ExperimentResult] = {}
    for d in designs:
        dd = as_design(d)
        if dd.name in out:
            raise ValueError(f"duplicate design name in sweep: {dd.name!r}")
        out[dd.name] = Experiment(dd, tuple(mixes), cycles).run(
            solo_baselines=solo_baselines)
    return out
