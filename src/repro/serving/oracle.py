"""Contention oracle: the memory-system simulator as an admission/
placement advisor for the serving engine.

Tenants declare an app *profile* ("interactive", "heavy", a Table 2
bench name, ...); the oracle maps profiles to calibrated simulator
benches (`repro.sim.profiles`) and asks the simulator how candidate
co-placements would contend: for every candidate set of tenants it
returns the predicted weighted speedup, max slowdown (unfairness), and
per-tenant slowdown of co-running their benches on the shared memory
system under the oracle's design point.

Cost discipline — the oracle must be cheap enough to consult every
decision epoch of a serving loop:

* ONE `run_grid` call per epoch: all uncached candidate mixes plus the
  solo-baseline rows their benches need batch through
  `runner.predict_mixes` as a single vmapped grid execution.
* ONE compiled program per signature group for the oracle's LIFETIME:
  mixes are padded to a fixed `slots` count and the row count to a
  fixed `pad_rows` multiple, so repeated epochs never retrace
  (pinned via `runner.TRACE_COUNT` in tests/test_serving_oracle.py).
* Memoized by frozen mix key: a candidate's benches, sorted, key its
  prediction — an epoch whose candidates were all seen before costs no
  simulation at all. Solo IPCs are cached per bench the same way.
* Fail-soft: with `fail_soft=True` (default) a failing simulation
  chunk poisons only its own candidates (their prediction is None and
  the `FailureRecord` is kept on `self.failures`); the serving loop
  keeps running on the surviving predictions.

Predictions are deterministic: the simulator is seeded and
deterministic, and candidate keys/memo insertion order are canonical.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from repro.core.design import Design, as_design
from repro.sim import runner as sim_runner
from repro.sim.profiles import DEFAULT_PROFILE, bench_for_profile


@dataclasses.dataclass(frozen=True)
class PlacementPrediction:
    """A candidate tenant co-placement with its predicted contention."""

    tenants: Tuple[int, ...]          # sorted tenant ids
    benches: Tuple[str, ...]          # aligned with `tenants`
    weighted_speedup: float
    max_slowdown: float
    slowdown: Mapping[int, float]     # per tenant

    def victim(self) -> int:
        """The tenant predicted to suffer most from this placement."""
        return max(self.tenants, key=lambda t: (self.slowdown[t], t))


class ContentionOracle:
    """Maps tenant profiles to benches and batch-predicts candidate
    placements through the simulator (see module docstring)."""

    def __init__(self, design: object = "mask", cycles: int = 1_500,
                 slots: int = 4, pad_rows: int = 16,
                 fail_soft: bool = True):
        self.design: Design = as_design(design)
        self.cycles = int(cycles)
        self.slots = int(slots)
        self.pad_rows = int(pad_rows)
        self.fail_soft = fail_soft
        # frozen mix key (sorted bench tuple) -> prediction (None = failed)
        self._memo: Dict[Tuple[str, ...],
                         Optional[sim_runner.MixPrediction]] = {}
        self._solo: Dict[str, float] = {}       # bench -> IPC_alone
        self.failures: List[sim_runner.FailureRecord] = []
        self.grid_calls = 0                     # run_grid invocations

    # ------------------------------------------------------------ core
    def predict_benches(self, bench_mixes: Sequence[Sequence[str]]
                        ) -> List[Optional[sim_runner.MixPrediction]]:
        """Predict raw bench mixes; memoized, one grid call for all
        fresh keys. Returns None for mixes whose simulation failed
        (fail-soft; the FailureRecord lands on `self.failures`)."""
        keys = [tuple(sorted(m)) for m in bench_mixes]
        fresh: List[Tuple[str, ...]] = []
        for k in keys:
            if k not in self._memo and k not in fresh:
                fresh.append(k)
        if fresh:
            preds = sim_runner.predict_mixes(
                self.design, fresh, cycles=self.cycles, slots=self.slots,
                pad_rows=self.pad_rows, fail_soft=self.fail_soft,
                solo_cache=self._solo)
            self.grid_calls += 1
            for k, p in zip(fresh, preds):
                if isinstance(p, sim_runner.FailureRecord):
                    self.failures.append(p)
                    self._memo[k] = None
                else:
                    self._memo[k] = p
        return [self._memo[k] for k in keys]

    def predict(self, candidates: Sequence[Sequence[int]],
                profiles: Mapping[int, str]
                ) -> List[Optional[PlacementPrediction]]:
        """Predict candidate tenant sets. `profiles` maps tenant id to
        a declared app profile (missing tenants get DEFAULT_PROFILE)."""
        cands = [tuple(sorted(c)) for c in candidates]
        if any(len(c) > self.slots for c in cands):
            raise ValueError(
                f"candidate exceeds oracle slots={self.slots}: "
                f"{max(cands, key=len)}")
        benches = [tuple(bench_for_profile(
            profiles.get(t, DEFAULT_PROFILE)) for t in c) for c in cands]
        base = self.predict_benches(benches)
        out: List[Optional[PlacementPrediction]] = []
        for tenants, bs, p in zip(cands, benches, base):
            if p is None:
                out.append(None)
                continue
            # p.benches is the sorted key; align tenants the same way
            # (equal benches are interchangeable slots)
            order = sorted(zip(bs, tenants))
            slowdown = {t: p.slowdown[i] for i, (_, t) in enumerate(order)}
            out.append(PlacementPrediction(
                tenants=tenants, benches=bs,
                weighted_speedup=p.weighted_speedup,
                max_slowdown=p.max_slowdown, slowdown=slowdown))
        return out

    # ------------------------------------------------------ inspection
    @property
    def memo_size(self) -> int:
        return len(self._memo)

    def solo_ipc(self) -> Dict[str, float]:
        """Cached per-bench IPC_alone baselines (a copy)."""
        return dict(self._solo)
