"""Base model/run configuration dataclasses.

Every assigned architecture is expressed as a ``ModelConfig``. Configs are
plain frozen dataclasses so they can be hashed into jit static args and
serialized into checkpoints / dry-run reports.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters (exact values from the assignment table)."""

    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    # --- attention details ---
    d_head: Optional[int] = None          # explicit head dim (qwen3); else d_model//n_heads
    qk_norm: bool = False                 # qwen3-style per-head RMSNorm on q,k
    sliding_window: Optional[int] = None  # mixtral SWA
    rope_theta: float = 500_000.0
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: Optional[int] = None        # expert FFN width if != d_ff
    moe_every: int = 1                    # MoE FFN every k-th layer (jamba: 2)
    capacity_factor: float = 1.25
    # --- SSM (mamba2 / hybrid) ---
    ssm_state: int = 0                    # d_state
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 256                  # SSD chunk length
    attn_every: int = 0                   # hybrid: attention layer every k-th (jamba: 8)
    # --- encoder-decoder (whisper) ---
    n_enc_layers: int = 0
    enc_len: int = 0                      # fixed encoder frame count (frontend stub)
    # --- multimodal stub ---
    n_patches: int = 0                    # vlm: prepended precomputed patch embeddings
    # --- misc ---
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    # --- serving / paged-KV (the MASK-managed memory) ---
    kv_page_size: int = 128               # tokens per KV page

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Embedding-table rows padded to a multiple of 128 so the vocab dim
        shards evenly (Megatron-style). ``vocab_size`` stays the logical
        vocab; padded logits are masked in the loss."""
        return ((self.vocab_size + 127) // 128) * 128

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def is_enc_dec(self) -> bool:
        return self.n_enc_layers > 0

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_hybrid(self) -> bool:
        return self.attn_every > 0

    @property
    def expert_d_ff(self) -> int:
        return self.moe_d_ff if self.moe_d_ff is not None else self.d_ff

    @property
    def sub_quadratic(self) -> bool:
        """Whether the arch supports long_500k (sub-quadratic attention path)."""
        return self.family in ("ssm", "hybrid") or self.sliding_window is not None

    # ------------------------------------------------------------------
    # Parameter counting (used for MODEL_FLOPS = 6*N*D in the roofline)
    # ------------------------------------------------------------------
    def _attn_params(self) -> int:
        dh = self.head_dim
        q = self.d_model * self.n_heads * dh
        kv = 2 * self.d_model * self.n_kv_heads * dh
        o = self.n_heads * dh * self.d_model
        return q + kv + o

    def _dense_ffn_params(self, d_ff: int) -> int:
        return 3 * self.d_model * d_ff  # SwiGLU: gate, up, down

    def _ssm_params(self) -> int:
        d_inner = self.ssm_expand * self.d_model
        nh = d_inner // self.ssm_head_dim
        in_proj = self.d_model * (2 * d_inner + 2 * self.ssm_state + nh)
        out_proj = d_inner * self.d_model
        conv = self.ssm_conv_width * (d_inner + 2 * self.ssm_state)
        extra = 2 * nh + d_inner  # A_log, dt_bias, D
        return in_proj + out_proj + conv + extra

    def layer_kinds(self) -> Tuple[str, ...]:
        """Sequence of per-layer kinds: 'attn' | 'ssm' for the mixer."""
        if self.family == "ssm":
            return tuple("ssm" for _ in range(self.n_layers))
        if self.is_hybrid:
            # jamba: attention every `attn_every`-th layer (1:7 mamba:attn)
            return tuple(
                "attn" if (i % self.attn_every) == (self.attn_every // 2) else "ssm"
                for i in range(self.n_layers)
            )
        return tuple("attn" for _ in range(self.n_layers))

    def ffn_kinds(self) -> Tuple[str, ...]:
        if not self.is_moe:
            return tuple("dense" for _ in range(self.n_layers))
        return tuple(
            "moe" if (i % self.moe_every) == (self.moe_every - 1) else "dense"
            for i in range(self.n_layers)
        )

    def param_count(self, active_only: bool = False) -> int:
        """Total (or active per-token) parameter count."""
        total = self.vocab_size * self.d_model  # embed
        if not self.tie_embeddings:
            total += self.vocab_size * self.d_model  # lm head
        kinds, ffns = self.layer_kinds(), self.ffn_kinds()
        for kind, ffn in zip(kinds, ffns):
            total += 2 * self.d_model  # norms
            total += self._attn_params() if kind == "attn" else self._ssm_params()
            if ffn == "moe":
                e = self.top_k if active_only else self.n_experts
                total += e * self._dense_ffn_params(self.expert_d_ff)
                total += self.d_model * self.n_experts  # router
            else:
                total += self._dense_ffn_params(self.d_ff)
        # encoder stack (whisper)
        for _ in range(self.n_enc_layers):
            total += 2 * self.d_model
            total += self._attn_params() + self._dense_ffn_params(self.d_ff)
        if self.is_enc_dec:  # cross attention in each decoder layer
            total += self.n_layers * (self._attn_params() + self.d_model)
        total += self.d_model  # final norm
        return total


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Execution configuration for a (model, shape, mesh) cell."""

    model: ModelConfig
    shape: ShapeConfig
    microbatches: int = 1            # grad-accumulation steps for training
    remat: bool = True
    fsdp: bool = False               # ZeRO-3 param/optim sharding over data axis
    bf16_moments: bool = False       # bf16 Adam moments (398B-class models)
    optimizer: str = "adamw"         # adamw | adafactor (giant MoE)
    attention_impl: str = "xla_blocked"  # xla_blocked | pallas_flash | naive
    seq_shard_decode: bool = False   # sequence-parallel KV for long decode
    quantize_weights: bool = False   # §Perf C2: int8 weight-only serving
    decode_relax_batch: bool = False  # §Perf C1: unpin batch->data on decode
    #   activations (cache stays sharded); lets SPMD move tiny activations
    #   instead of all-gathering FSDP weights every token step
    attn_block_q: int = 512
    attn_block_k: int = 1024
