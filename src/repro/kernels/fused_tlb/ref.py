"""Reference oracle for the fused TLB round: the XLA `access_fused` path.

The simulator's own `repro.core.tlb.access_fused` (backend="xla") IS the
contract — the kernel tests compare the Pallas outputs against it
plane-for-plane, so any drift between the two implementations fails
loudly instead of skewing simulated miss rates.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import tlb as tlb_mod


def fused_tlb_access_ref(tags, asids, lru, vpn, asid, active, may_fill,
                         time, *, n_waves=1, track_asids=True):
    """Same signature/returns as `ops.fused_tlb_access`, via the XLA path."""
    zero = jnp.zeros((), jnp.int32)
    state = tlb_mod.TLBState(tags=tags, asids=asids, lru=lru,
                             hits=zero, misses=zero)
    state, hit, filled = tlb_mod.access_fused(
        state, vpn, asid, active.astype(bool), may_fill.astype(bool), time,
        n_waves=n_waves, track_asids=track_asids, backend="xla")
    return (state.tags, state.asids, state.lru,
            hit.astype(tags.dtype), filled.astype(tags.dtype))
