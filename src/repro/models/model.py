"""Public model API: build/init params, forward entry points, input specs."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.models import lm
from repro.models.params import abstractify, materialize


def param_specs(cfg: ModelConfig):
    return lm.build_param_specs(cfg)


def init_params(rng: jax.Array, cfg: ModelConfig):
    return materialize(rng, lm.build_param_specs(cfg))


def abstract_params(cfg: ModelConfig, sharding_fn=None, quantize=False):
    specs = lm.build_param_specs(cfg)
    if quantize:
        from repro.models.quant import quantize_spec_tree
        specs = dict(specs, blocks=quantize_spec_tree(specs["blocks"]))
    return abstractify(specs, sharding_fn)


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                sharding_fn=None) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    train:   {tokens, labels [, frames | patch_embeds]}
    prefill: {tokens [, frames | patch_embeds]}
    decode:  {tokens (B,1), caches…} — caches are supplied separately via
             lm.cache_shapes.
    """
    B, S = shape.global_batch, shape.seq_len

    def mk(s, dt=jnp.int32, axes=("batch", None)):
        if sharding_fn is None:
            return jax.ShapeDtypeStruct(s, dt)
        return jax.ShapeDtypeStruct(s, dt, sharding=sharding_fn(axes, s))

    specs: Dict[str, Any] = {}
    if shape.kind in ("train", "prefill"):
        s_text = S - (cfg.n_patches or 0)
        specs["tokens"] = mk((B, s_text))
        if shape.kind == "train":
            specs["labels"] = mk((B, S))
        if cfg.n_patches:
            specs["patch_embeds"] = mk((B, cfg.n_patches, cfg.d_model),
                                       jnp.bfloat16, ("batch", None, None))
        if cfg.is_enc_dec:
            specs["frames"] = mk((B, cfg.enc_len, cfg.d_model), jnp.bfloat16,
                                 ("batch", None, None))
    else:  # decode
        specs["tokens"] = mk((B, 1))
    return specs


# re-exports for convenience
forward_train = lm.forward_train
forward_prefill = lm.forward_prefill
forward_decode = lm.forward_decode
cache_shapes = lm.cache_shapes
init_cache = lm.init_cache
