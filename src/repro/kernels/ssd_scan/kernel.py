"""Mamba2 SSD intra-chunk Pallas TPU kernel.

The chunked SSD algorithm splits into (a) a quadratic *intra-chunk* part —
the compute hot-spot, materializing per-chunk (Q x Q) decay matrices — and
(b) a cheap sequential inter-chunk state recurrence. This kernel computes
(a) per (batch, head-tile, chunk): the decay matrix L lives only in VMEM
(never HBM — the XLA path materializes it at (b, nc, nh, Q, Q) in fp32),
and emits y_intra plus the per-chunk state contribution / decay needed by
the recurrence, which ops.py runs in jnp.

Layouts (head-minor tiles, MXU-aligned in hd/ds):
  x:  (B, nc, Q, nh, hd)    dt(+A applied): dA (B, nc, Q, nh)
  Bm/Cm: (B, nc, Q, ds)
outputs:
  y_intra:  (B, nc, Q, nh, hd)
  S_chunk:  (B, nc, nh, hd, ds)
  decay:    (B, nc, nh)      exp(sum dA over chunk)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, da_ref, b_ref, c_ref,
            y_ref, s_ref, dec_ref, *, ht: int):
    # refs (block shapes):
    #   x: (1, 1, Q, ht, hd)  da: (1, 1, Q, ht)  b,c: (1, 1, Q, ds)
    #   y: (1, 1, Q, ht, hd)  s: (1, 1, ht, hd, ds)  dec: (1, 1, ht)
    x = x_ref[0, 0].astype(jnp.float32)            # (Q, ht, hd)
    da = da_ref[0, 0].astype(jnp.float32)          # (Q, ht)
    Bm = b_ref[0, 0].astype(jnp.float32)           # (Q, ds)
    Cm = c_ref[0, 0].astype(jnp.float32)           # (Q, ds)
    Q = x.shape[0]

    cs = jnp.cumsum(da, axis=0)                    # (Q, ht) inclusive
    # L[q, s, h] = exp(cs[q] - cs[s]) for s <= q  (segment decay)
    diff = cs[:, None, :] - cs[None, :, :]         # (Q, Q, ht)
    qi = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    causal = (si <= qi)[..., None]
    L = jnp.where(causal, jnp.exp(diff), 0.0)      # (Q, Q, ht)

    G = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Q, Q)
    M = G[..., None] * L                           # (Q, Q, ht)

    # y[q, h, p] = sum_s M[q, s, h] * x[s, h, p]
    y = jax.lax.dot_general(
        jnp.moveaxis(M, 2, 0), jnp.moveaxis(x, 1, 0),
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)        # (ht, Q, hd)
    y_ref[0, 0] = jnp.moveaxis(y, 0, 1).astype(y_ref.dtype)

    # chunk state: S[h, p, d] = sum_s decay_to_end[s,h] * x[s,h,p] * B[s,d]
    d2e = jnp.exp(cs[-1:, :] - cs)                 # (Q, ht)
    xw = x * d2e[:, :, None]                       # (Q, ht, hd)
    s_out = jax.lax.dot_general(
        jnp.moveaxis(xw, 1, 0), Bm,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)        # (ht, hd, ds)
    s_ref[0, 0] = s_out.astype(s_ref.dtype)
    dec_ref[0, 0] = jnp.exp(cs[-1]).astype(dec_ref.dtype)


def ssd_intra_chunk(x, dA, Bm, Cm, *, head_tile: int = 8,
                    interpret: bool = False):
    """x: (B, nc, Q, nh, hd); dA: (B, nc, Q, nh); Bm/Cm: (B, nc, Q, ds)."""
    B, nc, Q, nh, hd = x.shape
    ds = Bm.shape[-1]
    ht = min(head_tile, nh)
    assert nh % ht == 0
    nt = nh // ht

    grid = (B, nc, nt)
    kern = functools.partial(_kernel, ht=ht)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, Q, ht, hd), lambda b, c, h: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, Q, ht), lambda b, c, h: (b, c, 0, h)),
            pl.BlockSpec((1, 1, Q, ds), lambda b, c, h: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, ds), lambda b, c, h: (b, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, ht, hd), lambda b, c, h: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, ht, hd, ds), lambda b, c, h: (b, c, h, 0, 0)),
            pl.BlockSpec((1, 1, ht), lambda b, c, h: (b, c, h)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, nc, Q, nh, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, nc, nh, hd, ds), jnp.float32),
            jax.ShapeDtypeStruct((B, nc, nh), jnp.float32),
        ],
        interpret=interpret,
    )(x, dA, Bm, Cm)
