"""Serving launcher: multi-tenant continuous batching on the reduced config.

Ad-hoc requests (legacy mode):

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --tenants 2 \
      --requests 8

Trace-driven with a placement policy (serving.stream presets; the
"oracle" policy consults the simulator-backed contention oracle):

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b \
      --trace flood_vs_trickle --steps 24 --policy oracle
"""
from __future__ import annotations

import argparse
from typing import Mapping, Optional

import jax
import numpy as np

from repro.configs import get_model, reduced_model
from repro.configs.base import RunConfig, ShapeConfig
from repro.memmgr.kv_cache import PoolConfig
from repro.models import model as M
from repro.serving import metrics as smet
from repro.serving import stream as strm
from repro.serving.engine import EngineConfig, Request, ServingEngine
from repro.serving.placement import POLICIES, make_policy


def build_engine(arch: str, max_seqs: int = 16, policy: str = "none",
                 profiles: Optional[Mapping[int, str]] = None,
                 epoch_steps: int = 8, ecfg: Optional[EngineConfig] = None,
                 **policy_kw) -> ServingEngine:
    """Engine on the reduced model. `policy`/`profiles` select the
    admission placement layer (serving.placement); extra kwargs reach
    the policy factory (e.g. cycles=..., unfairness_cap=... for
    "oracle")."""
    cfg = reduced_model(get_model(arch))
    shape = ShapeConfig("serve", seq_len=64, global_batch=1, kind="decode")
    run = RunConfig(model=cfg, shape=shape, remat=False,
                    attn_block_q=16, attn_block_k=16)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    n_attn = sum(1 for k in cfg.layer_kinds() if k == "attn")
    pool = PoolConfig(
        n_pages=max_seqs * 8, page_size=cfg.kv_page_size,
        n_kv=max(cfg.n_kv_heads, 1), head_dim=cfg.head_dim if cfg.n_heads else 1,
        n_layers=max(n_attn, 1), max_seqs=max_seqs, pages_per_seq=8)
    placement = make_policy(policy, profiles=profiles,
                            epoch_steps=epoch_steps, **policy_kw)
    return ServingEngine(cfg, run, params, pool,
                         ecfg or EngineConfig(),
                         placement=placement, profiles=profiles)


def run_trace(eng: ServingEngine, trace: strm.TraceSpec,
              drain_steps: int = 400):
    for step_reqs in strm.arrivals(trace, eng.cfg.vocab_size):
        for r in step_reqs:
            eng.submit(r)
        eng.step()
    return eng.run_until_drained(max_steps=drain_steps)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--policy", default="none", choices=POLICIES)
    ap.add_argument("--trace", default=None,
                    help=f"trace preset {sorted(strm.PRESETS)}; omit for "
                         "ad-hoc --requests mode")
    ap.add_argument("--steps", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--epoch-steps", type=int, default=8)
    ap.add_argument("--cycles", type=int, default=300,
                    help="oracle: simulator cycles per prediction")
    ap.add_argument("--tenants", type=int, default=2)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    if args.trace:
        trace = strm.make_trace(args.trace, seed=args.seed,
                                steps=args.steps)
        kw = {"cycles": args.cycles} if args.policy == "oracle" else {}
        eng = build_engine(args.arch, policy=args.policy,
                           profiles=trace.profiles(),
                           epoch_steps=args.epoch_steps, **kw)
        finished = run_trace(eng, trace)
    else:
        eng = build_engine(args.arch, policy=args.policy,
                           profiles={t: "batch"
                                     for t in range(args.tenants)})
        rng = np.random.RandomState(args.seed)
        for i in range(args.requests):
            eng.submit(Request(
                rid=i, tenant=i % args.tenants,
                prompt=rng.randint(0, eng.cfg.vocab_size, args.prompt_len),
                max_new=args.max_new))
        finished = eng.run_until_drained()

    tput = smet.tenant_throughput(finished, eng.step_count)
    print(f"policy={args.policy}: finished {len(finished)} requests "
          f"in {eng.step_count} steps "
          f"({len(eng.decisions)} placement decisions)")
    for t, v in sorted(tput.items()):
        print(f"  tenant {t}: {v:.2f} tok/step")
    print(f"mean latency {smet.mean_latency(finished):.1f} steps")
    if eng.decisions:
        summ = smet.decision_summary(eng.decisions)
        if summ["predicted_max_slowdown_mean"] is not None:
            print(f"oracle predicted max slowdown (mean over epochs): "
                  f"{summ['predicted_max_slowdown_mean']:.3f}")


if __name__ == "__main__":
    main()
