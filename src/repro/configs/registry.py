"""Architecture registry: ``--arch <id>`` → ModelConfig, plus RunConfig tuning.

The per-(arch × shape) RunConfig knobs (microbatch count, FSDP, bf16 moments)
encode how each cell is made to fit 16 GB/chip on the production mesh — see
DESIGN.md §5 and EXPERIMENTS.md §Dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.configs.shapes import SHAPES_BY_NAME, shape_applicable

from repro.configs.phi3_vision_4_2b import CONFIG as PHI3_VISION
from repro.configs.mamba2_1_3b import CONFIG as MAMBA2
from repro.configs.llama3_8b import CONFIG as LLAMA3
from repro.configs.mistral_large_123b import CONFIG as MISTRAL_LARGE
from repro.configs.glm4_9b import CONFIG as GLM4
from repro.configs.qwen3_4b import CONFIG as QWEN3
from repro.configs.jamba_1_5_large_398b import CONFIG as JAMBA
from repro.configs.olmoe_1b_7b import CONFIG as OLMOE
from repro.configs.mixtral_8x22b import CONFIG as MIXTRAL
from repro.configs.whisper_base import CONFIG as WHISPER

ARCHS: Dict[str, ModelConfig] = {
    c.name: c
    for c in (
        PHI3_VISION, MAMBA2, LLAMA3, MISTRAL_LARGE, GLM4,
        QWEN3, JAMBA, OLMOE, MIXTRAL, WHISPER,
    )
}

# ZeRO-3 (FSDP) over the data axis for everything whose optimizer state
# does not comfortably fit TP-only (>= ~8B params); the giants additionally
# use bf16 Adam moments + bf16 grad accumulation to stay under 16 GB/chip.
_FSDP_ARCHS = {"llama3-8b", "glm4-9b", "mistral-large-123b",
               "jamba-1.5-large-398b", "mixtral-8x22b"}
_BF16_MOMENT_ARCHS = {"jamba-1.5-large-398b", "mixtral-8x22b",
                      "mistral-large-123b"}
# 398B-class: factored second moment (Adafactor) — Adam moments would eat
# 6.2 GB/chip on top of params+grads.
_ADAFACTOR_ARCHS = {"jamba-1.5-large-398b"}

# Grad-accumulation microbatches for train_4k (global_batch=256, data axis=16
# → 16 sequences per data shard; microbatching keeps activations + vocab logits
# within HBM).
_TRAIN_MICROBATCHES = {
    "phi-3-vision-4.2b": 8,
    "mamba2-1.3b": 8,
    "llama3-8b": 8,
    "mistral-large-123b": 16,
    "glm4-9b": 16,
    "qwen3-4b": 8,
    "jamba-1.5-large-398b": 16,
    "olmoe-1b-7b": 8,
    "mixtral-8x22b": 16,
    "whisper-base": 4,
}


def get_model(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch]


def get_run_config(arch: str, shape_name: str) -> RunConfig:
    model = get_model(arch)
    shape = SHAPES_BY_NAME[shape_name]
    if not shape_applicable(model, shape):
        raise ValueError(
            f"cell ({arch} x {shape_name}) is skipped: pure full-attention arch "
            "has no sub-quadratic path for 512k decode (DESIGN.md §4)"
        )
    return RunConfig(
        model=model,
        shape=shape,
        microbatches=_TRAIN_MICROBATCHES[arch] if shape.kind == "train" else 1,
        remat=shape.kind == "train",
        fsdp=arch in _FSDP_ARCHS,
        bf16_moments=arch in _BF16_MOMENT_ARCHS,
        optimizer="adafactor" if arch in _ADAFACTOR_ARCHS else "adamw",
        seq_shard_decode=(shape.name == "long_500k"),
    )


def all_cells():
    """Yield every (arch, shape) cell with its applicability flag (40 total)."""
    for arch, model in ARCHS.items():
        for shape in SHAPES_BY_NAME.values():
            yield arch, shape.name, shape_applicable(model, shape)


def reduced_model(model: ModelConfig) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests (shapes scale down,
    structure — GQA ratios, MoE top-k, hybrid interleave — is preserved)."""
    kw = dict(
        name=model.name + "-smoke",
        n_layers=min(model.n_layers, 4 if not model.is_hybrid else 8),
        d_model=128,
        d_ff=256 if model.d_ff else 0,
        vocab_size=512,
        d_head=32 if model.n_heads else None,
    )
    if model.n_heads:
        ratio = max(1, model.n_heads // max(model.n_kv_heads, 1))
        kw["n_heads"] = 4
        kw["n_kv_heads"] = max(1, 4 // ratio)
    if model.is_moe:
        kw["n_experts"] = min(model.n_experts, 8)
        kw["top_k"] = min(model.top_k, 2)
        kw["moe_d_ff"] = 64 if model.moe_d_ff else None
    if model.ssm_state:
        kw["ssm_state"] = 16
        kw["ssm_head_dim"] = 16
        kw["ssm_chunk"] = 16
    if model.is_enc_dec:
        kw["n_enc_layers"] = 2
        kw["enc_len"] = 24
    if model.n_patches:
        kw["n_patches"] = 8
    kw["kv_page_size"] = 16
    return dataclasses.replace(model, **kw)
