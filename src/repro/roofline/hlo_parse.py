"""Trip-count-aware HLO text analyzer.

``compiled.cost_analysis()`` on the CPU backend counts each while-loop body
ONCE, so scanned-layer models under-report FLOPs/bytes by the trip count.
This module parses ``compiled.as_text()`` structurally:

  * builds a per-computation instruction table (name -> result shape),
  * multiplies instructions inside while bodies by the loop trip count
    (extracted from the loop condition's comparison constant),
  * reports: dot/conv FLOPs, HBM bytes (operands+result of every top-level
    non-control instruction — the standard HLO cost-model assumption), and
    per-op collective bytes.

Fusion-internal computations are not double counted: a fusion instruction
contributes its own operands+result only.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_INSTR_RE = re.compile(r"^(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+)$")
_OPNAME_RE = re.compile(r"\s*([\w\-]+)\(")


def _split_shape_op(rhs: str):
    """'(s32[], bf16[..] /*index=5*/ ...) while(...)' -> (shape_str, op, rest).

    Handles tuple result shapes containing /*index=N*/ comments."""
    rhs = rhs.strip()
    if rhs.startswith("("):
        depth = 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    shape_str = rhs[: i + 1]
                    m = _OPNAME_RE.match(rhs[i + 1:])
                    if not m:
                        return shape_str, None, ""
                    return (shape_str, m.group(1),
                            rhs[i + 1 + m.end() - 1:])
        return rhs, None, ""
    m = re.match(r"([\w\[\]\{\},]+)\s+([\w\-]+)\(", rhs)
    if not m:
        return rhs, None, ""
    return m.group(1), m.group(2), rhs[m.end() - 1:]

_CONTROL_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}
_COLLECTIVES = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "all-gather-start", "all-reduce-start",
                "collective-permute-start", "ragged-all-to-all"}


def shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(shape_str: str) -> Tuple[str, List[int]]:
    """First array shape in the string -> (dtype, dims)."""
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return "", []
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    return m.group(1), dims


@dataclasses.dataclass
class Instr:
    name: str
    shape_str: str      # result shape (text before the op name)
    op: str
    operands: List[str]
    attrs: str          # raw text after the op's '(...)'
    raw: str


@dataclasses.dataclass
class Comp:
    name: str
    instrs: List[Instr] = dataclasses.field(default_factory=list)
    shapes: Dict[str, str] = dataclasses.field(default_factory=dict)


class HloModule:
    def __init__(self, hlo_text: str):
        self.comps: Dict[str, Comp] = {}
        self.entry: Optional[str] = None
        self._parse(hlo_text)

    # ------------------------------------------------------------- parse
    def _parse(self, text: str):
        cur: Optional[Comp] = None
        for line in text.splitlines():
            s = line.strip()
            if not s or s.startswith("//"):
                continue
            hm = _HDR_RE.match(s)
            if hm and " = " not in s.split("(")[0]:
                cur = Comp(hm.group(1))
                self.comps[cur.name] = cur
                if s.startswith("ENTRY"):
                    self.entry = cur.name
                # record parameter shapes (fusion-internal dots reference them)
                for pm in re.finditer(r"%?([\w\.\-]+):\s*("
                                      r"(?:\((?:[^()]|\([^()]*\))*\))|"
                                      r"[\w\[\],]+)", s):
                    cur.shapes[pm.group(1)] = pm.group(2)
                continue
            if cur is None:
                continue
            if s == "}" or s.startswith("} "):
                cur = None
                continue
            im = _INSTR_RE.match(s)
            if not im:
                continue
            name, rhs = im.group(1), im.group(2)
            shape_str, op, paren = _split_shape_op(rhs)
            if op is None:
                cur.shapes[name] = shape_str
                continue
            depth = 0
            end = 0
            for i, ch in enumerate(paren):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        end = i
                        break
            inner = paren[1:end]
            attrs = paren[end + 1:]
            operands = re.findall(r"%([\w\.\-]+)", inner)
            cur.instrs.append(Instr(name, shape_str, op, operands, attrs, s))
            cur.shapes[name] = shape_str

    # ---------------------------------------------------------- trip count
    def trip_count(self, cond_name: str) -> int:
        comp = self.comps.get(cond_name)
        if not comp:
            return 1
        consts: Dict[str, int] = {}
        for ins in comp.instrs:
            if ins.op == "constant":
                m = re.search(r"constant\((\d+)\)", ins.raw)
                if m and ins.shape_str.strip().startswith(("s32", "s64", "u32")):
                    consts[ins.name] = int(m.group(1))
        # precise path: ROOT compare(%gte, %constant), direction=LT/LE
        root = next((i for i in comp.instrs if i.raw.startswith("ROOT")), None)
        if root is not None and root.op == "compare":
            dm = re.search(r"direction=(\w+)", root.attrs)
            direction = dm.group(1) if dm else "LT"
            for o in root.operands:
                if o in consts:
                    c = consts[o]
                    return c + 1 if direction == "LE" else max(c, 1)
        return max(consts.values()) if consts else 1

    # ------------------------------------------------------------ analysis
    def _dot_flops(self, comp: Comp, ins: Instr) -> float:
        _, out_dims = _shape_dims(ins.shape_str)
        out_n = 1
        for d in out_dims:
            out_n *= d
        lhs = ins.operands[0] if ins.operands else None
        lhs_shape = comp.shapes.get(lhs, "")
        _, lhs_dims = _shape_dims(lhs_shape)
        cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.attrs)
        contract = 1
        if cm and cm.group(1):
            for ix in cm.group(1).split(","):
                i = int(ix)
                if i < len(lhs_dims):
                    contract *= lhs_dims[i]
        return 2.0 * out_n * contract

    def _conv_flops(self, comp: Comp, ins: Instr) -> float:
        _, out_dims = _shape_dims(ins.shape_str)
        out_n = 1
        for d in out_dims:
            out_n *= d
        rhs = ins.operands[1] if len(ins.operands) > 1 else None
        _, k_dims = _shape_dims(comp.shapes.get(rhs, ""))
        k_n = 1
        for d in k_dims:
            k_n *= d
        return 2.0 * out_n * max(k_n, 1)

    def _fusion_flops(self, name: str, depth: int = 0) -> float:
        comp = self.comps.get(name)
        if comp is None or depth > 3:
            return 0.0
        total = 0.0
        for ins in comp.instrs:
            if ins.op == "dot":
                total += self._dot_flops(comp, ins)
            elif ins.op == "convolution":
                total += self._conv_flops(comp, ins)
            elif ins.op == "fusion":
                fm = re.search(r"calls=%?([\w\.\-]+)", ins.attrs)
                if fm:
                    total += self._fusion_flops(fm.group(1), depth + 1)
        return total

    def analyze(self) -> Dict[str, float]:
        """Walk from ENTRY, trip-aware. Returns flops / hbm bytes /
        collective bytes (all per-device)."""
        totals = {"dot_flops": 0.0, "hbm_bytes": 0.0, "coll_bytes": 0.0,
                  "transcendental_elems": 0.0}
        coll_by_op: Dict[str, float] = {}
        stack: List[str] = []

        def walk(name: str, mult: float):
            comp = self.comps.get(name)
            if comp is None or name in stack:
                return
            stack.append(name)
            for ins in comp.instrs:
                if ins.op in _CONTROL_OPS:
                    continue
                if ins.op == "while":
                    bm = re.search(r"body=%?([\w\.\-]+)", ins.raw)
                    cm = re.search(r"condition=%?([\w\.\-]+)", ins.raw)
                    if bm and cm:
                        trips = self.trip_count(cm.group(1))
                        walk(bm.group(1), mult * max(trips, 1))
                    continue
                if ins.op == "conditional":
                    for b in re.findall(r"%([\w\.\-]+)", ins.attrs):
                        if b in self.comps:
                            walk(b, mult)
                    continue
                if ins.op == "call":
                    m = re.search(r"to_apply=%?([\w\.\-]+)", ins.attrs)
                    if m:
                        walk(m.group(1), mult)
                    continue
                # ---- cost-bearing instruction ----
                out_b = shape_bytes(ins.shape_str)
                in_b = sum(shape_bytes(comp.shapes.get(o, ""))
                           for o in ins.operands)
                totals["hbm_bytes"] += (out_b + in_b) * mult
                if ins.op == "fusion":
                    # count dot/conv FLOPs fused into the fusion body
                    # (bytes already accounted at the fusion boundary)
                    fm = re.search(r"calls=%?([\w\.\-]+)", ins.attrs)
                    if fm:
                        totals["dot_flops"] += (
                            self._fusion_flops(fm.group(1)) * mult)
                    continue
                if ins.op == "dot":
                    totals["dot_flops"] += self._dot_flops(comp, ins) * mult
                elif ins.op == "convolution":
                    totals["dot_flops"] += self._conv_flops(comp, ins) * mult
                elif ins.op in ("exponential", "tanh", "log", "rsqrt", "sqrt",
                                "power", "logistic"):
                    _, od = _shape_dims(ins.shape_str)
                    n = 1
                    for d in od:
                        n *= d
                    totals["transcendental_elems"] += n * mult
                if ins.op in _COLLECTIVES:
                    base = ins.op.replace("-start", "")
                    moved = max(out_b, in_b)
                    coll_by_op[base] = coll_by_op.get(base, 0.0) + moved * mult
                    totals["coll_bytes"] += moved * mult
            stack.pop()

        if self.entry:
            walk(self.entry, 1.0)
        totals["coll_by_op"] = coll_by_op
        return totals


def analyze_hlo(hlo_text: str) -> Dict[str, float]:
    return HloModule(hlo_text).analyze()
