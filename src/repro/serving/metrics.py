"""Per-tenant fairness/throughput metrics (weighted speedup, max slowdown)
— the paper's evaluation metrics applied to the serving engine."""
from __future__ import annotations

from collections import defaultdict
from typing import Dict, List


def tenant_throughput(finished, total_steps: int) -> Dict[int, float]:
    toks = defaultdict(int)
    for r in finished:
        toks[r.tenant] += len(r.out)
    return {t: n / max(total_steps, 1) for t, n in toks.items()}


def weighted_speedup(shared: Dict[int, float],
                     alone: Dict[int, float]) -> float:
    return sum(shared[t] / max(alone.get(t, 1e-9), 1e-9) for t in shared)


def max_slowdown(shared: Dict[int, float], alone: Dict[int, float]) -> float:
    return max(max(alone.get(t, 0.0), 1e-9) / max(v, 1e-9)
               for t, v in shared.items())


def mean_latency(finished) -> float:
    if not finished:
        return 0.0
    return sum(r.finish_step - r.submit_step for r in finished) / len(finished)
