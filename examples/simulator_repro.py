"""Reproduce the paper's headline comparison on a workload bundle.

Sweeps all five designs (ideal / PWC / GPU-MMU / Static / MASK) over one
2-app bundle through the typed `Experiment`/`sweep` façade and prints the
weighted speedup + the paper's Table-3-style TLB hit rates.  ~3-5 min on
CPU.

Run:  PYTHONPATH=src python examples/simulator_repro.py [BENCH_A BENCH_B]
"""
import sys

import numpy as np

from repro.sim.runner import sweep
from repro.sim.workloads import BENCHES

a, b = (sys.argv[1:3] if len(sys.argv) >= 3 else ("3DS", "BLK"))
assert a in BENCHES and b in BENCHES, f"choose from {BENCHES}"
CYCLES = 60_000

print(f"bundle: {a}+{b}  ({CYCLES} cycles)")
results = sweep(["ideal", "pwc", "gpu-mmu", "static", "mask"],
                [(a, b)], cycles=CYCLES)
for name, res in results.items():
    r = res[0]
    print(f"{name:8s} weighted_speedup={r.weighted_speedup():.3f} "
          f"sharedTLB_hit={np.round([x.l2_tlb_hit_rate for x in r.apps], 3)} "
          f"bypass_hit={np.round([x.bypass_hit_rate for x in r.apps], 3)} "
          f"walk_lat={np.round([x.walk_lat for x in r.apps], 0)}")
print("\npaper: MASK ≈ +45.2% weighted speedup over GPU-MMU; "
      "shared TLB hit 49.3% -> 73.9%")
