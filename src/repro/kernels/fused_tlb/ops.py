"""Dispatch wrapper for the fused TLB round kernel.

Follows the `kernels/paged_attention/ops.py` backend-detection idiom:
`interpret=None` means "lower for real" and is only legal on platforms
with a Pallas lowering (TPU/GPU); anywhere else it raises instead of
silently interpreting — interpret mode must be an explicit opt-in
(`interpret=True`, or `tlb_backend="pallas-interpret"` /
`REPRO_TLB_INTERPRET=1` one layer up in `sim/config.py`).
"""
from __future__ import annotations

import functools

import jax

from .kernel import fused_tlb_round

PALLAS_PLATFORMS = ("tpu", "gpu")


@functools.partial(jax.jit,
                   static_argnames=("n_waves", "track_asids", "interpret"))
def fused_tlb_access(tags, asids, lru, vpn, asid, active, may_fill, time, *,
                     n_waves: int = 1, track_asids: bool = True,
                     interpret: bool | None = None):
    """One fused probe+fill round; returns (tags', asids', lru', hit, filled).

    hit/filled come back as int32 masks; counter arithmetic stays with
    the caller so both backends share it bit for bit.
    """
    if interpret is None:
        backend = jax.default_backend()
        if backend not in PALLAS_PLATFORMS:
            raise RuntimeError(
                f"fused_tlb: no Pallas lowering for platform {backend!r}; "
                "pass interpret=True (tlb_backend='pallas-interpret' or "
                "REPRO_TLB_INTERPRET=1) to run the interpreter explicitly, "
                "or use the 'xla' backend")
        interpret = False
    return fused_tlb_round(tags, asids, lru, vpn, asid, active, may_fill,
                           time, n_waves=n_waves, track_asids=track_asids,
                           interpret=interpret)
