"""TLB / tokens / bypass / page-table unit tests (deterministic).

Hypothesis-based property tests live in test_core_tlb_properties.py, which
is skipped gracefully when `hypothesis` is not installed (see
requirements-dev.txt for the full dev dependency set).
"""
import jax.numpy as jnp
import numpy as np

from repro.core import bypass as bp_mod
from repro.core import page_table as pt
from repro.core import tlb as tlb_mod
from repro.core import tokens as tok_mod


# ------------------------------------------------------------------ TLB

def test_fill_then_probe_hits():
    st_ = tlb_mod.init(64, 64)  # fully associative: one set
    vpn = jnp.asarray([5, 9, 13], jnp.int32)
    asid = jnp.asarray([0, 1, 0], jnp.int32)
    act = jnp.ones(3, bool)
    # FA cache has one fill port in this model: fill sequentially
    for i in range(3):
        st_ = tlb_mod.fill(st_, vpn[i:i + 1], asid[i:i + 1],
                           act[i:i + 1], i + 1)
    st_, hit = tlb_mod.probe(st_, vpn, asid, act, 5)
    assert bool(hit.all())


def test_asid_isolation():
    st_ = tlb_mod.init(64, 64)
    vpn = jnp.asarray([42], jnp.int32)
    st_ = tlb_mod.fill(st_, vpn, jnp.asarray([0]), jnp.asarray([True]), 1)
    _, hit_same = tlb_mod.probe(st_, vpn, jnp.asarray([0]),
                                jnp.asarray([True]), 2)
    _, hit_other = tlb_mod.probe(st_, vpn, jnp.asarray([1]),
                                 jnp.asarray([True]), 2)
    assert bool(hit_same[0]) and not bool(hit_other[0])


def test_flush_asid():
    st_ = tlb_mod.init(16, 16)
    vpns = jnp.arange(8, dtype=jnp.int32)
    asids = jnp.asarray([0, 1] * 4, jnp.int32)
    for i in range(8):  # FA structure: one fill per call
        st_ = tlb_mod.fill(st_, vpns[i:i + 1], asids[i:i + 1],
                           jnp.ones(1, bool), i + 1)
    st_ = tlb_mod.flush_asid(st_, 0)
    occ = tlb_mod.occupancy_by_asid(st_, 2)
    assert int(occ[0]) == 0 and int(occ[1]) == 4


def test_lru_eviction():
    st_ = tlb_mod.init(4, 4)  # 1 set of 4 ways effectively per index
    # fill 4 entries in set 0 (vpns multiples of 4 -> set 0 when sets=1)
    st_ = tlb_mod.init(4, 4)
    n_sets = st_.tags.shape[0]
    vpns = jnp.asarray([0 * n_sets, 1 * n_sets, 2 * n_sets, 3 * n_sets],
                       jnp.int32)
    for i in range(4):
        st_ = tlb_mod.fill(st_, vpns[i:i + 1], jnp.zeros(1, jnp.int32),
                           jnp.ones(1, bool), i + 1)
    # touch entry 0 (most recent), then fill a new one -> evicts vpn[1]
    st_, _ = tlb_mod.probe(st_, vpns[:1], jnp.zeros(1, jnp.int32),
                           jnp.ones(1, bool), 10)
    st_ = tlb_mod.fill(st_, jnp.asarray([4 * n_sets], jnp.int32),
                       jnp.zeros(1, jnp.int32), jnp.ones(1, bool), 11)
    _, hit0 = tlb_mod.probe(st_, vpns[:1], jnp.zeros(1, jnp.int32),
                            jnp.ones(1, bool), 12)
    _, hit1 = tlb_mod.probe(st_, vpns[1:2], jnp.zeros(1, jnp.int32),
                            jnp.ones(1, bool), 12)
    assert bool(hit0[0]) and not bool(hit1[0])


# ---------------------------------------------------------------- tokens

def test_token_hill_climb_directions():
    ts = tok_mod.init(2, jnp.asarray([100, 100]), 0.8)
    assert tuple(np.asarray(ts.tokens)) == (80, 80)
    # warm-up epoch installs baselines only
    ts = ts._replace(epoch_hits=jnp.asarray([50, 50]),
                     epoch_misses=jnp.asarray([50, 50]))
    ts = tok_mod.epoch_update(ts, jnp.asarray([100, 100]))
    assert tuple(np.asarray(ts.tokens)) == (80, 80)
    # improving epoch: keep direction (down)
    ts = ts._replace(epoch_hits=jnp.asarray([80, 20]),
                     epoch_misses=jnp.asarray([20, 80]))
    ts = tok_mod.epoch_update(ts, jnp.asarray([100, 100]))
    tok = np.asarray(ts.tokens)
    assert tok[0] < 80  # improved -> continue down
    assert 1 <= tok.min() and tok.max() <= 100


def test_token_bounds_bounce():
    ts = tok_mod.init(1, jnp.asarray([10]), 0.1)
    ts = ts._replace(first_epoch=jnp.array(False),
                     direction=jnp.asarray([-1]),
                     prev_miss_rate=jnp.asarray([0.9]),
                     epoch_hits=jnp.asarray([90]),
                     epoch_misses=jnp.asarray([10]))
    for _ in range(5):
        ts = tok_mod.epoch_update(ts, jnp.asarray([10]))
        assert 1 <= int(ts.tokens[0]) <= 10


# ---------------------------------------------------------------- bypass

def test_bypass_epoch_latching_and_sampling():
    bs = bp_mod.init()
    # epoch 0 data: data hit rate 0.9; level-4 (leaf) rate 0.1
    depth = jnp.asarray([0] * 50 + [4] * 50, jnp.int32)
    hits = jnp.asarray([True] * 45 + [False] * 5 + [True] * 5 + [False] * 45)
    bs = bp_mod.record(bs, depth, hits, jnp.ones(100, bool))
    bs = bp_mod.epoch_update(bs)
    fill = bp_mod.should_fill(bs, jnp.asarray([0, 1, 4], jnp.int32))
    # epoch_idx == 1 -> not a sampling epoch; leaf must bypass, data fills
    assert bool(fill[0]) and not bool(fill[2])
    # advance to a sampling epoch: fills re-enabled
    for _ in range(3):
        bs = bp_mod.epoch_update(bs)
    assert (int(bs.epoch_idx) % bp_mod.SAMPLE_EVERY) == 0
    fill = bp_mod.should_fill(bs, jnp.asarray([4], jnp.int32))
    assert bool(fill[0])


# ------------------------------------------------------------ page table

def test_translate_asid_disjoint():
    cfg = pt.PageTableConfig()
    vpn = jnp.arange(100, dtype=jnp.int32)
    p0 = pt.translate(cfg, jnp.zeros(100, jnp.int32), vpn)
    p1 = pt.translate(cfg, jnp.ones(100, jnp.int32), vpn)
    assert not np.array_equal(np.asarray(p0), np.asarray(p1))
    # deterministic
    p0b = pt.translate(cfg, jnp.zeros(100, jnp.int32), vpn)
    np.testing.assert_array_equal(np.asarray(p0), np.asarray(p0b))


def test_walk_depth_tags():
    assert pt.walk_depth_tag(0) == 1
    assert pt.walk_depth_tag(3) == 4
    assert pt.walk_depth_tag(9) == 7  # saturates at 7 (3-bit tag)
