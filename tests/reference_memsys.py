"""SEQUENTIAL REFERENCE pipeline for the lane-fused memory path.

This is a frozen copy of `repro.sim.memsys` as it stood before the
lane-fused rewrite: per cycle it issues 8 back-to-back L2$/DRAM
round-trips (4 page-walk levels + 4 divergent data lines), each a full
probe + fill + DRAM-schedule sequence observing the fills of the rounds
before it, and it carries 17 separate per-app stat arrays.

It exists so `tests/test_fused_kernels.py` can quantify the fused
pipeline against the exact pre-fusion semantics across every registered
design — do not "fix" or modernize it; its value is being the old code.
The only additions are `run_ref` / `metrics` at the bottom.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import bypass as bp_mod
from repro.core import dram_sched
from repro.core import page_table as pt_mod
from repro.core import tlb as tlb_mod
from repro.core import tokens as tok_mod
from repro.core.mask import static_partition_index
from repro.core.page_table import _mix
from repro.sim.config import SimConfig
from repro.sim.workloads import FIELD, gen_vpn

DATA_WIDTH = 4           # divergent cache lines per memory instruction
BIG = jnp.int32(1 << 30)
# the concurrent-page-walk table size (Table 1: 64) comes from
# cfg.design.translation.max_concurrent_walks


# ---------------------------------------------------------------------------
# layered state
# ---------------------------------------------------------------------------

class TransState(NamedTuple):
    """Translation layer: TLB hierarchy + in-flight page-walk table."""
    l1: tlb_mod.TLBState         # per-core bank, leading axis (n_cores,)
    l2tlb: tlb_mod.TLBState
    bypass_tlb: tlb_mod.TLBState
    pwc: tlb_mod.TLBState        # page-walk cache (PTE lines)
    walk_vpn: jax.Array          # (max_concurrent_walks,) int32
    walk_asid: jax.Array         # (max_concurrent_walks,) int32
    walk_done: jax.Array         # (max_concurrent_walks,) completion time
    walk_merged: jax.Array       # (max_concurrent_walks,) warps merged on


class DataState(NamedTuple):
    """Shared data path: L2 data cache, DRAM, bypass accounting."""
    l2c: tlb_mod.TLBState        # line-addressed, reuses TLB machinery
    dram: dram_sched.DramState
    bypass: bp_mod.BypassState


class StatState(NamedTuple):
    """Per-app cumulative counters (all (n_apps,) unless noted)."""
    s_l1_hit: jax.Array
    s_l1_miss: jax.Array
    s_l2_hit: jax.Array
    s_l2_miss: jax.Array
    s_byp_hit: jax.Array         # bypass-cache hits
    s_byp_probe: jax.Array       # bypass-cache probes
    s_walk_lat: jax.Array        # float32 summed walk latency
    s_walks: jax.Array
    s_stall_per_miss: jax.Array  # accumulated merged-warp counts
    s_dram_tlb_lat: jax.Array    # float32
    s_dram_tlb_n: jax.Array
    s_dram_data_lat: jax.Array
    s_dram_data_n: jax.Array
    s_l2c_tlb_hit: jax.Array     # () cumulative L2$ hits for walk requests
    s_l2c_tlb_probe: jax.Array
    s_l2c_data_hit: jax.Array
    s_l2c_data_probe: jax.Array


class SimState(NamedTuple):
    t: jax.Array                 # () int32
    stall_until: jax.Array       # (W,) int32
    instr: jax.Array             # (W,) float32 retired instructions
    pos: jax.Array               # (W,) int32 stream position
    trans: TransState
    data: DataState
    tokens: tok_mod.TokenState
    stats: StatState


def init_trans(cfg: SimConfig) -> TransState:
    tr = cfg.design.translation
    tok = cfg.design.tokens
    wt = tr.max_concurrent_walks
    z = lambda *s: jnp.zeros(s, jnp.int32)  # noqa: E731
    return TransState(
        l1=tlb_mod.init_bank(cfg.n_cores, tr.l1_entries, tr.l1_entries),
        l2tlb=tlb_mod.init(tr.l2_entries, tr.l2_ways),
        bypass_tlb=tlb_mod.init(tok.bypass_cache_entries,
                                tok.bypass_cache_entries),
        pwc=tlb_mod.init(cfg.pwc_entries, cfg.pwc_ways),
        walk_vpn=jnp.full((wt,), -1, jnp.int32),
        walk_asid=jnp.full((wt,), -1, jnp.int32),
        walk_done=z(wt),
        walk_merged=z(wt),
    )


def init_data(cfg: SimConfig) -> DataState:
    return DataState(
        l2c=tlb_mod.init(cfg.l2_sets * cfg.l2_ways, cfg.l2_ways),
        dram=dram_sched.init(cfg.n_channels, cfg.n_banks, cfg.n_apps),
        bypass=bp_mod.init(),
    )


def init_stats(n_apps: int) -> StatState:
    z = lambda *s: jnp.zeros(s, jnp.int32)  # noqa: E731
    zf = lambda *s: jnp.zeros(s, jnp.float32)  # noqa: E731
    na = n_apps
    return StatState(
        s_l1_hit=z(na), s_l1_miss=z(na), s_l2_hit=z(na), s_l2_miss=z(na),
        s_byp_hit=z(na), s_byp_probe=z(na),
        s_walk_lat=zf(na), s_walks=z(na), s_stall_per_miss=zf(na),
        s_dram_tlb_lat=zf(na), s_dram_tlb_n=z(na),
        s_dram_data_lat=zf(na), s_dram_data_n=z(na),
        s_l2c_tlb_hit=z(), s_l2c_tlb_probe=z(),
        s_l2c_data_hit=z(), s_l2c_data_probe=z(),
    )


def init_state(cfg: SimConfig) -> SimState:
    W = cfg.total_warps
    return SimState(
        t=jnp.zeros((), jnp.int32),
        stall_until=jnp.zeros((W,), jnp.int32),
        instr=jnp.zeros((W,), jnp.float32),
        pos=jnp.zeros((W,), jnp.int32),
        trans=init_trans(cfg),
        data=init_data(cfg),
        tokens=tok_mod.init(cfg.n_apps,
                            jnp.asarray(cfg.warps_per_app, jnp.int32),
                            cfg.design.tokens.initial_frac),
        stats=init_stats(cfg.n_apps),
    )


# ---------------------------------------------------------------------------
# stage 1: warp scheduling
# ---------------------------------------------------------------------------

class SchedOut(NamedTuple):
    """One candidate memory instruction per core, all arrays (n_cores,)."""
    picked_warp: jax.Array       # global warp id
    slot: jax.Array              # warp slot within its core
    active: jax.Array            # bool: core found a ready warp
    app: jax.Array
    asid: jax.Array
    vpn: jax.Array
    pos: jax.Array               # stream position of the picked warp


def warp_sched(cfg: SimConfig, params_mat, stall_until, pos, t) -> SchedOut:
    """GTO-like pick: per core, the ready warp that has waited longest."""
    C, wpc = cfg.n_cores, cfg.warps_per_core
    ready = stall_until <= t
    waiting = jnp.where(ready, t - stall_until, -1)
    wait_grid = waiting.reshape(C, wpc)
    pick = jnp.argmax(wait_grid, axis=1)                  # (C,)
    picked_warp = jnp.arange(C) * wpc + pick
    active = wait_grid[jnp.arange(C), pick] >= 0          # (C,)

    app = jnp.asarray(cfg.app_of_core, jnp.int32)         # oracle split (§6)
    p = pos[picked_warp]
    vpn = gen_vpn(params_mat[app], app, picked_warp, p, t)
    # one address space per application
    return SchedOut(picked_warp=picked_warp, slot=pick, active=active,
                    app=app, asid=app, vpn=vpn, pos=p)


# ---------------------------------------------------------------------------
# shared L2 data cache + DRAM (used by both translation and datapath)
# ---------------------------------------------------------------------------

def _l2_cache_access(cfg: SimConfig, l2c, dram, line, app, is_tlb,
                     may_fill, active, t, static_split):
    """Shared L2 data cache + DRAM for a batch of line addresses.

    Returns (l2c', dram', latency, l2_hit). `may_fill` implements the MASK
    L2 bypass decision; `static_split` gives each app an equal slice of the
    sets/channels by restricting its index range (Static design)."""
    dr = cfg.design.dram
    key = jnp.where(static_split,
                    static_partition_index(line, cfg.l2_sets, cfg.n_apps,
                                           app),
                    line % cfg.l2_sets)
    # reuse TLB machinery: tag = full line id, "asid" field = 0
    zero = jnp.zeros_like(line)
    l2c, hit = tlb_mod.probe(l2c, line * cfg.l2_sets + key, zero, active, t)
    lat = jnp.where(hit, cfg.lat_l2_cache, 0)
    miss = active & ~hit

    channel = (line % cfg.n_channels).astype(jnp.int32)
    channel = jnp.where(static_split,
                        static_partition_index(line, cfg.n_channels,
                                               cfg.n_apps, app), channel)
    bank = ((line // cfg.n_channels) % cfg.n_banks).astype(jnp.int32)
    row = (line // (cfg.n_channels * cfg.n_banks * 32)).astype(jnp.int32)
    dram, dlat = dram_sched.access(
        dram, channel, bank, row, app, is_tlb, miss,
        mask_enabled=dr.enabled, thres_max=dr.thres_max)
    lat = lat + jnp.where(miss, cfg.lat_l2_cache + dlat, 0)
    l2c = tlb_mod.fill(l2c, line * cfg.l2_sets + key, zero,
                       miss & may_fill, t)
    return l2c, dram, lat, hit


# ---------------------------------------------------------------------------
# stage 2: translation (L1 TLB bank -> L2 TLB/bypass -> page walk)
# ---------------------------------------------------------------------------

class TransOut(NamedTuple):
    """Per-core translation results + walk-level L2$ counters."""
    trans_lat: jax.Array         # (C,) translation latency
    l1_hit: jax.Array            # (C,) bool
    l1_miss: jax.Array
    l2_hit: jax.Array
    byp_hit: jax.Array
    l2_hit_eff: jax.Array        # L2 or bypass-cache hit
    need_walk: jax.Array
    merged: jax.Array            # joined an in-flight walk
    new_walk: jax.Array          # started a fresh walk
    walk_done_new: jax.Array     # (C,) completion time of fresh walks
    dram_tlb_lat: jax.Array      # (C,) float32 DRAM latency on walk path
    dram_tlb_n: jax.Array        # (C,) int32
    l2c_hit: jax.Array           # () walk-request hits in the L2$
    l2c_probe: jax.Array         # () walk-request probes of the L2$


def translation(cfg: SimConfig, trans: TransState, data: DataState,
                tokens: tok_mod.TokenState, sched: SchedOut, t
                ) -> Tuple[TransState, DataState, TransOut]:
    """Translate one request per core through the full TLB hierarchy.

    Dispatch is by the translation/tokens/bypass policy specs: the
    spec fields are static Python values, so each design compiles to a
    specialized pipeline with the unused paths traced out."""
    des = cfg.design
    tr = des.translation
    ideal = tr.kind == "ideal"
    use_pwc = tr.kind == "pwc"
    use_l2tlb = tr.kind == "shared_l2_tlb"
    tokens_on = des.tokens.enabled
    C = cfg.n_cores
    vpn, asid, active = sched.vpn, sched.asid, sched.active

    # ---------------- L1 TLB bank --------------------------------------
    l1, l1_hit = tlb_mod.probe_bank(trans.l1, vpn, asid, active, t)
    if ideal:
        l1_hit = active
    l1_miss = active & ~l1_hit

    # ---------------- shared L2 TLB + bypass cache ---------------------
    l2tlb, byp_tlb = trans.l2tlb, trans.bypass_tlb
    if use_l2tlb:
        l2tlb, l2_hit = tlb_mod.probe(l2tlb, vpn, asid, l1_miss, t)
        if tokens_on:
            byp_tlb, byp_hit = tlb_mod.probe(byp_tlb, vpn, asid,
                                             l1_miss & ~l2_hit, t)
            l2_hit_eff = l2_hit | byp_hit
        else:
            byp_hit = jnp.zeros_like(l2_hit)
            l2_hit_eff = l2_hit
    else:
        l2_hit = jnp.zeros_like(l1_miss)
        byp_hit = jnp.zeros_like(l1_miss)
        l2_hit_eff = l2_hit

    need_walk = l1_miss & ~l2_hit_eff

    # ---------------- page walk (4 dependent PTE accesses) -------------
    # MSHR merge: outstanding walk for same (vpn, asid)?
    wmatch = (trans.walk_vpn[None, :] == vpn[:, None]) & \
             (trans.walk_asid[None, :] == asid[:, None]) & \
             (trans.walk_done[None, :] > t)
    merged = wmatch.any(axis=1) & need_walk
    merge_done = jnp.where(
        merged, jnp.max(jnp.where(wmatch, trans.walk_done[None, :], 0),
                        axis=1), 0)

    new_walk = need_walk & ~merged
    n_live = (trans.walk_done > t).sum()
    # walker occupancy queue penalty (finite walker threads)
    wt = tr.max_concurrent_walks
    over = jnp.maximum(n_live + jnp.cumsum(new_walk) - wt, 0)
    queue_pen = over * 30

    pte_lines = pt_mod.pte_line_addresses(
        pt_mod.PageTableConfig(levels=tr.walk_levels), asid, vpn)  # (C, L)

    walk_lat = jnp.zeros((C,), jnp.int32)
    dram_tlb_lat = jnp.zeros((C,), jnp.float32)
    dram_tlb_n = jnp.zeros((C,), jnp.int32)
    l2c, dram, bp_state = data.l2c, data.dram, data.bypass
    pwc = trans.pwc
    static = jnp.asarray(des.partition.kind == "static")
    l2c_hit = l2c_probe = jnp.zeros((), jnp.int32)
    for lvl in range(tr.walk_levels):
        line = pte_lines[:, lvl]
        lvl_active = new_walk
        depth_tag = jnp.full((C,), pt_mod.walk_depth_tag(lvl), jnp.int32)
        if use_pwc:
            pwc, pwc_hit = tlb_mod.probe(pwc, line, asid * 0, lvl_active, t)
            pwc = tlb_mod.fill(pwc, line, asid * 0, lvl_active & ~pwc_hit, t)
            go_l2 = lvl_active & ~pwc_hit
            walk_lat = walk_lat + jnp.where(lvl_active & pwc_hit, 5, 0)
        else:
            go_l2 = lvl_active
        if des.bypass.enabled:
            may_fill = bp_mod.should_fill(bp_state, depth_tag)
        else:
            may_fill = jnp.ones((C,), bool)
        l2c, dram, lat, l2hit = _l2_cache_access(
            cfg, l2c, dram, line, sched.app, jnp.ones((C,), bool),
            may_fill, go_l2, t, static)
        bp_state = bp_mod.record(bp_state, depth_tag, l2hit, go_l2)
        walk_lat = walk_lat + jnp.where(go_l2, lat, 0)
        went_dram = go_l2 & ~l2hit
        dram_tlb_lat = dram_tlb_lat + jnp.where(went_dram, lat, 0)
        dram_tlb_n = dram_tlb_n + went_dram.astype(jnp.int32)
        l2c_hit = l2c_hit + (go_l2 & l2hit).sum(dtype=jnp.int32)
        l2c_probe = l2c_probe + go_l2.sum(dtype=jnp.int32)

    walk_lat = walk_lat + queue_pen
    walk_done_new = t + cfg.lat_l2_tlb + walk_lat

    # install new walks into free slots (expired entries are free)
    free = trans.walk_done <= t
    order_slots = jnp.cumsum(new_walk) - 1
    free_idx = jnp.where(free, jnp.arange(wt), BIG)
    free_sorted = jnp.sort(free_idx)
    slot_for = jnp.where(new_walk,
                         free_sorted[jnp.clip(order_slots, 0, wt - 1)],
                         BIG)
    can_install = slot_for < wt
    slot_safe = jnp.clip(slot_for, 0, wt - 1).astype(jnp.int32)
    inst = new_walk & can_install
    walk_vpn = trans.walk_vpn.at[slot_safe].set(
        jnp.where(inst, vpn, trans.walk_vpn[slot_safe]))
    walk_asid = trans.walk_asid.at[slot_safe].set(
        jnp.where(inst, asid, trans.walk_asid[slot_safe]))
    walk_done = trans.walk_done.at[slot_safe].set(
        jnp.where(inst, walk_done_new, trans.walk_done[slot_safe]))
    walk_merged_arr = trans.walk_merged.at[slot_safe].set(
        jnp.where(inst, 1, trans.walk_merged[slot_safe]))
    # bump merge counters
    first_match = jnp.argmax(wmatch, axis=1)
    walk_merged_arr = walk_merged_arr.at[first_match].add(
        jnp.where(merged, 1, 0))

    # ---------------- translation latency ------------------------------
    trans_lat = jnp.where(
        l1_hit, cfg.lat_l1_tlb,
        jnp.where(l2_hit_eff, cfg.lat_l2_tlb,
                  jnp.where(merged, jnp.maximum(merge_done - t, 1),
                            jnp.maximum(walk_done_new - t, 1))))
    if ideal:
        trans_lat = jnp.where(active, cfg.lat_l1_tlb, 0)

    # ---------------- TLB fills on walk return -------------------------
    if use_l2tlb:
        if tokens_on:
            # tokens are distributed round-robin over the app's cores in
            # warpID order: per-core allowance = tokens / cores_per_app
            cores_per_app = jnp.asarray(cfg.cores_per_app, jnp.int32)
            tok_per_core = tokens.tokens[sched.app] // cores_per_app[sched.app]
            has_tok = sched.slot < tok_per_core
            fill_l2 = need_walk & has_tok & ~tokens.first_epoch
            fill_l2 = fill_l2 | (need_walk & tokens.first_epoch)
            fill_byp = need_walk & ~fill_l2
            byp_tlb = tlb_mod.fill(byp_tlb, vpn, asid, fill_byp, t)
        else:
            fill_l2 = need_walk
        l2tlb = tlb_mod.fill(l2tlb, vpn, asid, fill_l2, t)
    l1 = tlb_mod.fill_bank(l1, vpn, asid, l1_miss, t)

    trans_out = TransOut(
        trans_lat=trans_lat, l1_hit=l1_hit, l1_miss=l1_miss, l2_hit=l2_hit,
        byp_hit=byp_hit, l2_hit_eff=l2_hit_eff, need_walk=need_walk,
        merged=merged, new_walk=new_walk, walk_done_new=walk_done_new,
        dram_tlb_lat=dram_tlb_lat, dram_tlb_n=dram_tlb_n,
        l2c_hit=l2c_hit, l2c_probe=l2c_probe)
    return (TransState(l1=l1, l2tlb=l2tlb, bypass_tlb=byp_tlb, pwc=pwc,
                       walk_vpn=walk_vpn, walk_asid=walk_asid,
                       walk_done=walk_done, walk_merged=walk_merged_arr),
            DataState(l2c=l2c, dram=dram, bypass=bp_state),
            trans_out)


# ---------------------------------------------------------------------------
# stage 3: data path (L1D -> L2$ -> DRAM)
# ---------------------------------------------------------------------------

class DataOut(NamedTuple):
    """Per-core data-access results, all arrays (n_cores,)."""
    data_lat: jax.Array
    l1d_hit: jax.Array
    go_l2d: jax.Array            # bool: reached the shared L2$
    dlat: jax.Array              # L2$/DRAM part of the latency
    l2d_hit: jax.Array           # bool: any of the lines hit the L2$


def datapath(cfg: SimConfig, data: DataState, params_mat, sched: SchedOut, t
             ) -> Tuple[DataState, DataOut]:
    """Data access for the translated request (after the TLB hierarchy)."""
    C = cfg.n_cores
    l2c, dram, bp_state = data.l2c, data.dram, data.bypass
    static = jnp.asarray(cfg.design.partition.kind == "static")

    pfn = pt_mod.translate(pt_mod.PageTableConfig(), sched.asid, sched.vpn)
    r = _mix(pfn.astype(jnp.uint32) + sched.pos.astype(jnp.uint32))
    l1d_hit = (r % jnp.uint32(1024)).astype(jnp.int32) \
        < params_mat[sched.app, FIELD["l1d_hit_milli"]]
    # warp-wide (divergent) data access: one memory instruction touches
    # DATA_WIDTH cache lines, serviced in parallel (latency = max). This is
    # what gives data traffic its realistic flooding pressure on the shared
    # L2 relative to page-walk traffic.
    go_l2d = sched.active & ~l1d_hit
    dlat = jnp.zeros((C,), jnp.int32)
    l2d_hit_any = jnp.zeros((C,), bool)
    for k in range(DATA_WIDTH):
        r3 = _mix(r + jnp.uint32((0x85EBCA6B + 0x9E3779B9 * k) & 0xFFFFFFFF))
        data_line = pfn * 32 + (r3 % jnp.uint32(32)).astype(jnp.int32)
        l2c, dram, dlat_k, l2d_hit = _l2_cache_access(
            cfg, l2c, dram, data_line, sched.app, jnp.zeros((C,), bool),
            jnp.ones((C,), bool), go_l2d, t, static)
        dlat = jnp.maximum(dlat, dlat_k)
        l2d_hit_any = l2d_hit_any | l2d_hit
        bp_state = bp_mod.record(bp_state, jnp.zeros((C,), jnp.int32),
                                 l2d_hit, go_l2d)
    data_lat = jnp.where(l1d_hit, cfg.lat_l1_data, cfg.lat_l1_data + dlat)
    return (DataState(l2c=l2c, dram=dram, bypass=bp_state),
            DataOut(data_lat=data_lat, l1d_hit=l1d_hit, go_l2d=go_l2d,
                    dlat=dlat, l2d_hit=l2d_hit_any))


# ---------------------------------------------------------------------------
# stage 4: statistics accumulation
# ---------------------------------------------------------------------------

def accumulate_stats(stats: StatState, n_apps: int, sched: SchedOut,
                     tout: TransOut, dout: DataOut, t) -> StatState:
    """Fold one cycle's per-core outcomes into the per-app counters."""
    oh = jax.nn.one_hot(sched.app, n_apps, dtype=jnp.int32) \
        * sched.active[:, None]
    ohf = oh.astype(jnp.float32)
    psum = lambda x: (oh * x[:, None]).sum(0)  # noqa: E731
    fsum = lambda x: (ohf * x[:, None]).sum(0)  # noqa: E731
    return StatState(
        s_l1_hit=stats.s_l1_hit + psum(tout.l1_hit),
        s_l1_miss=stats.s_l1_miss + psum(tout.l1_miss),
        s_l2_hit=stats.s_l2_hit + psum(tout.l2_hit),
        s_l2_miss=stats.s_l2_miss + psum(tout.need_walk),
        s_byp_hit=stats.s_byp_hit + psum(tout.byp_hit),
        s_byp_probe=stats.s_byp_probe + psum(tout.l1_miss & ~tout.l2_hit),
        s_walk_lat=stats.s_walk_lat
        + fsum(jnp.where(tout.new_walk, tout.walk_done_new - t, 0)),
        s_walks=stats.s_walks + psum(tout.new_walk),
        s_stall_per_miss=stats.s_stall_per_miss + fsum(tout.merged),
        s_dram_tlb_lat=stats.s_dram_tlb_lat + fsum(tout.dram_tlb_lat),
        s_dram_tlb_n=stats.s_dram_tlb_n + psum(tout.dram_tlb_n),
        s_dram_data_lat=stats.s_dram_data_lat
        + fsum(jnp.where(dout.go_l2d, dout.dlat, 0)),
        s_dram_data_n=stats.s_dram_data_n + psum(dout.go_l2d),
        s_l2c_tlb_hit=stats.s_l2c_tlb_hit + tout.l2c_hit,
        s_l2c_tlb_probe=stats.s_l2c_tlb_probe + tout.l2c_probe,
        s_l2c_data_hit=stats.s_l2c_data_hit
        + (dout.go_l2d & dout.l2d_hit).sum(dtype=jnp.int32),
        s_l2c_data_probe=stats.s_l2c_data_probe
        + dout.go_l2d.sum(dtype=jnp.int32),
    )


# ---------------------------------------------------------------------------
# retire + epoch maintenance
# ---------------------------------------------------------------------------

def retire(stall_until, instr, pos, sched: SchedOut, total_lat, gap, t):
    """Stall issued warps until their latency resolves; credit instrs."""
    w = sched.picked_warp
    stall_until = stall_until.at[w].set(
        jnp.where(sched.active, t + total_lat, stall_until[w]))
    instr = instr.at[w].add(
        jnp.where(sched.active, (1 + gap).astype(jnp.float32), 0.0))
    pos = pos.at[w].add(jnp.where(sched.active, 1, 0))
    return stall_until, instr, pos


def epoch_maintenance(cfg: SimConfig, trans: TransState,
                      tokens: tok_mod.TokenState, data: DataState, t
                      ) -> Tuple[tok_mod.TokenState, DataState]:
    """Every epoch_cycles: token hill-climb, DRAM pressure, bypass latch.

    `trans` must be the PRE-update translation state: the walk table is
    sampled before this cycle's installs, matching the paper's epoch-end
    census of in-flight walks."""
    des = cfg.design
    na = cfg.n_apps

    def do_epoch(args):
        tokens, dram, bp = args
        warps_per_app = jnp.asarray(cfg.warps_per_app, jnp.int32)
        conc = jnp.zeros((na,), jnp.int32).at[
            jnp.clip(trans.walk_asid, 0, na - 1)].add(
            (trans.walk_done > t).astype(jnp.int32))
        stalled = jnp.zeros((na,), jnp.int32).at[
            jnp.clip(trans.walk_asid, 0, na - 1)].add(
            trans.walk_merged * (trans.walk_done > t))
        dram = dram_sched.update_pressure(dram, conc, stalled)
        return (tok_mod.epoch_update(tokens, warps_per_app,
                                     step_frac=des.tokens.step_frac), dram,
                bp_mod.epoch_update(bp))

    any_adaptive = (des.tokens.enabled or des.dram.enabled
                    or des.bypass.enabled)
    is_epoch = (t % des.epoch_cycles) == 0
    tokens, dram, bp_state = jax.lax.cond(
        is_epoch & jnp.asarray(any_adaptive),
        do_epoch, lambda args: args, (tokens, data.dram, data.bypass))
    return tokens, data._replace(dram=dram, bypass=bp_state)


# ---------------------------------------------------------------------------
# one-cycle transition: thin composition of the stages
# ---------------------------------------------------------------------------

def step(cfg: SimConfig, params_mat, state: SimState) -> SimState:
    """One cycle. params_mat: (n_apps, N_FIELDS) int32 workload params."""
    t = state.t + 1
    sched = warp_sched(cfg, params_mat, state.stall_until, state.pos, t)
    trans_st, data_st, tout = translation(
        cfg, state.trans, state.data, state.tokens, sched, t)
    data_st, dout = datapath(cfg, data_st, params_mat, sched, t)

    gap = params_mat[sched.app, FIELD["gap"]]
    total_lat = tout.trans_lat + dout.data_lat + gap
    stall_until, instr, pos = retire(
        state.stall_until, state.instr, state.pos, sched, total_lat, gap, t)

    tokens = tok_mod.record(state.tokens, sched.app, tout.l2_hit_eff,
                            tout.l1_miss)
    stats = accumulate_stats(state.stats, cfg.n_apps, sched, tout, dout, t)
    tokens, data_st = epoch_maintenance(cfg, state.trans, tokens, data_st, t)

    return SimState(t=t, stall_until=stall_until, instr=instr, pos=pos,
                    trans=trans_st, data=data_st, tokens=tokens, stats=stats)


# ---------------------------------------------------------------------------
# reference entry points (additions — everything above is the frozen copy)
# ---------------------------------------------------------------------------

def run_ref(cfg: SimConfig, params_mat) -> SimState:
    """Scan the reference step over cfg.sim_cycles under jit."""

    @jax.jit
    def run(pm):
        st = init_state(cfg)

        def body(s, _):
            return step(cfg, pm, s), None

        final, _ = jax.lax.scan(body, st, None, length=cfg.sim_cycles)
        return final

    return jax.device_get(run(params_mat))


def metrics(cfg: SimConfig, st: SimState) -> dict:
    """Paper-metric dict from a reference final state (old _stats maths)."""
    import numpy as np
    na = cfg.n_apps
    warp_app = np.repeat(np.asarray(cfg.app_of_core), cfg.warps_per_core)
    instr = np.asarray(st.instr)
    ipc = np.array([instr[warp_app == a].sum() for a in range(na)]) \
        / float(st.t)
    s = st.stats
    g = lambda x: np.asarray(x, np.float64)  # noqa: E731
    l1p = g(s.s_l1_hit) + g(s.s_l1_miss)
    l2p = g(s.s_l2_hit) + g(s.s_l2_miss)
    return {
        "ipc": ipc,
        "l1_hit_rate": g(s.s_l1_hit) / np.maximum(l1p, 1),
        "l2_hit_rate": g(s.s_l2_hit) / np.maximum(l2p, 1),
        "byp_hit_rate": g(s.s_byp_hit) / np.maximum(g(s.s_byp_probe), 1),
        "walk_lat": g(s.s_walk_lat) / np.maximum(g(s.s_walks), 1),
        "walks": g(s.s_walks),
        "dram_tlb_lat": g(s.s_dram_tlb_lat) / np.maximum(g(s.s_dram_tlb_n), 1),
        "dram_data_lat": g(s.s_dram_data_lat)
        / np.maximum(g(s.s_dram_data_n), 1),
        "l2c_tlb_hit_rate": (g(s.s_l2c_tlb_hit)
                             / np.maximum(g(s.s_l2c_tlb_probe), 1)),
        "l2c_data_hit_rate": (g(s.s_l2c_data_hit)
                              / np.maximum(g(s.s_l2c_data_probe), 1)),
        "tokens": np.asarray(st.tokens.tokens),
    }
