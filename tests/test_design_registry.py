"""Design-registry coverage: hashability, jit-compilability over n_apps,
custom-design registration, and compile-cache isolation.

The jit grid below (every registered design x n_apps in {1, 2, 3}) uses a
small SimConfig: compile time is graph-size bound, not array-size bound,
so the small config proves the same pipeline specialization cheaply.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.design import (Design, TokenSpec, as_design, from_legacy,
                               get_design, list_designs, register_design)
from repro.core.mask import ALL_DESIGNS, DesignPoint, MaskConfig
from repro.sim import runner
from repro.sim.config import SimConfig
from repro.sim.runner import Experiment
from repro.sim.workloads import app_matrix

SMALL = dict(n_cores=6, warps_per_core=2, sim_cycles=64)
BENCHES3 = ["3DS", "BLK", "MUM"]


def _small_run(design: Design, n_apps: int):
    cfg = SimConfig(n_apps=n_apps, design=design, **SMALL)
    pm = jnp.asarray(app_matrix(BENCHES3[:n_apps]))
    return runner._stats(cfg, runner._compiled_run(cfg)(pm))


# ---------------------------------------------------------------- registry

def test_builtins_registered():
    names = list_designs()
    for n in ALL_DESIGNS:
        assert n in names
        assert get_design(n).name == n
    with pytest.raises(KeyError):
        get_design("no-such-design")


def test_designs_hashable_frozen_distinct():
    ds = [get_design(n) for n in ALL_DESIGNS]
    assert len({hash(d) for d in ds}) >= 2     # hashable at all
    assert len(set(ds)) == len(ds)             # all distinct by value
    with pytest.raises(dataclasses.FrozenInstanceError):
        ds[0].name = "nope"
    with pytest.raises(dataclasses.FrozenInstanceError):
        ds[0].tokens.enabled = True


def test_with_nested_merge():
    mask = get_design("mask")
    mine = mask.with_(name="t-lean", tokens=dict(initial_frac=0.1),
                      bypass=dict(enabled=False))
    assert mine.tokens == TokenSpec(enabled=True, initial_frac=0.1,
                                    step_frac=0.5, bypass_cache_entries=32)
    assert not mine.bypass.enabled
    assert mine.dram == mask.dram              # untouched layers carry over
    assert mask.tokens.initial_frac == 0.25    # original untouched
    # replace is an alias; spec instances are accepted too
    assert mine.replace(tokens=TokenSpec()) == mine.with_(tokens=TokenSpec())
    with pytest.raises(TypeError):
        mask.with_(no_such_layer=dict())


def test_register_collision_semantics():
    d1 = get_design("mask").with_(name="t-collide")
    d2 = d1.with_(tokens=dict(initial_frac=0.9))
    register_design(d1)
    register_design(d1)                        # identical re-register: ok
    with pytest.raises(ValueError):
        register_design(d2)                    # same name, different specs
    register_design(d2, overwrite=True)
    assert get_design("t-collide") == d2


def test_as_design_legacy_roundtrip():
    """A legacy flag-bag DesignPoint converts to the same Design the
    registry serves (modulo nothing — field for field)."""
    legacy = DesignPoint("mask", mask=MaskConfig())
    assert as_design(legacy) == get_design("mask")
    assert from_legacy(legacy) is not legacy
    base_off = MaskConfig(tlb_tokens=False, l2_bypass=False,
                          dram_sched=False)
    assert as_design(DesignPoint("ideal", ideal_tlb=True, mask=base_off)) \
        == get_design("ideal")
    assert as_design(DesignPoint("pwc", use_l2_tlb=False, use_pwc=True,
                                 mask=base_off)) == get_design("pwc")
    assert as_design("static") == get_design("static")
    with pytest.raises(TypeError):
        as_design(42)
    # the old pipeline ran shared L2 TLB + PWC together for this combo;
    # no spec kind expresses that, so conversion must refuse loudly
    with pytest.raises(ValueError, match="use_l2_tlb and.*use_pwc"):
        from_legacy(DesignPoint("bad", use_l2_tlb=True, use_pwc=True,
                                mask=base_off))


# ------------------------------------------------------------- jit grid

@pytest.mark.parametrize("name", ALL_DESIGNS)
def test_every_design_compiles_and_is_finite(name):
    """Each registered design compiles under jit for n_apps in {1, 2, 3}
    and yields finite stats."""
    d = get_design(name)
    for n_apps in (1, 2, 3):
        s = _small_run(d, n_apps)
        assert s["ipc"].shape == (n_apps,)
        for k, v in s.items():
            arr = np.asarray(v, np.float64)
            assert np.all(np.isfinite(arr)), (name, n_apps, k)


# ------------------------------------------------- compile-cache isolation

def test_same_name_designs_do_not_collide_in_compile_cache():
    """Two distinct designs sharing a name must key separate run
    callables (the cache hashes every spec field, not the name). Since
    the static/traced split they may SHARE the underlying executable —
    their differing knobs ride in the traced DesignParams — so the
    observable check below (distinct token budgets) is the load-bearing
    one."""
    a = get_design("mask").with_(name="t-dup", tokens=dict(initial_frac=0.25))
    b = get_design("mask").with_(name="t-dup", tokens=dict(initial_frac=0.75))
    assert a != b and hash(SimConfig(design=a)) != hash(SimConfig(design=b))
    cfg_a = SimConfig(n_apps=2, design=a, **SMALL)
    cfg_b = SimConfig(n_apps=2, design=b, **SMALL)
    assert runner._compiled_run(cfg_a) is runner._compiled_run(cfg_a)
    assert runner._compiled_run(cfg_a) is not runner._compiled_run(cfg_b)
    # observable separation: initial token budgets differ (no epoch at 64
    # cycles), so a stale shared executable would be caught here
    sa, sb = _small_run(a, 2), _small_run(b, 2)
    warps = SMALL["n_cores"] // 2 * SMALL["warps_per_core"]
    assert sa["tokens"].tolist() == [int(warps * 0.25)] * 2
    assert sb["tokens"].tolist() == [int(warps * 0.75)] * 2


# ------------------------------------------- custom design via Experiment

def test_custom_design_through_experiment():
    """Acceptance: a user-defined design (MASK with a different
    initial_token_frac and bypass disabled) registers and runs through
    Experiment without touching repro.sim/repro.core internals."""
    custom = register_design(
        get_design("mask").with_(name="t-mask-custom",
                                 tokens=dict(initial_frac=0.5),
                                 bypass=dict(enabled=False)))
    res = Experiment("t-mask-custom", [("3DS", "BLK")], cycles=64).run()
    r = res[0]
    assert r.design == custom
    assert np.isfinite(r.weighted_speedup())
    assert np.isfinite(r.unfairness())
    a = r.app("3DS")
    assert a.ipc_alone is not None and a.ipc > 0
    # full-size config: 30 cores / 2 apps -> 480 warps/app; frac 0.5 and
    # no epoch boundary before cycle 64 means tokens stay at 240
    assert a.tokens == 240
