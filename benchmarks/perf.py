"""Simulator throughput microbenchmark -> BENCH_sim.json.

Measures steps/sec of the compiled one-cycle pipeline in three shapes:

  2app    — one 2-app mix (the paper's pair setting)
  4app    — one 4-app mix (N-way sharing)
  batch8  — eight 2-app mixes vmapped through one executable

The three scenarios are interleaved round-robin inside ONE process and
the median per-scenario rate is reported: this box's absolute throughput
drifts with neighbor load, so sequential before/after blocks are not
comparable — interleaving keeps the scenarios under the same drift, and
the recorded JSON gives future PRs a perf trajectory (compare ratios
between scenarios / versions, not absolute steps/sec across days).

`--compare <git-ref>` is the honest A/B protocol for the same reason:
the baseline tree is materialized from git into a renamed `repro_base`
package, both versions are compiled into THIS process, and each round
times them back-to-back (pair-by-pair) so neighbor drift hits both
sides equally; the reported number is the median new/old speedup per
scenario, never a cross-run absolute.

Run:  PYTHONPATH=src python -m benchmarks.perf [--cycles N] [--rounds R]
      PYTHONPATH=src python -m benchmarks.perf --compare HEAD
"""
from __future__ import annotations

import argparse
import importlib
import json
import platform
import re
import shutil
import subprocess
import sys
import tarfile
import time
from io import BytesIO
from pathlib import Path

import jax
import numpy as np

REPO_ROOT = Path(__file__).resolve().parent.parent
OUT_PATH = REPO_ROOT / "BENCH_sim.json"
COMPARE_DIR = REPO_ROOT / ".bench_compare"
_IMPORT_RE = re.compile(r"^(\s*(?:from|import)\s+)repro(?=[.\s])",
                        re.MULTILINE)


def _scenarios(design: str, cycles: int, pkg: str = "repro"):
    """name -> (zero-arg compiled call, sim-steps per call).

    `pkg` selects the simulator package ("repro" or a baseline copy such
    as "repro_base") so two versions can be timed in one process.
    """
    import jax.numpy as jnp
    config_mod = importlib.import_module(pkg + ".sim.config")
    runner_mod = importlib.import_module(pkg + ".sim.runner")
    workloads_mod = importlib.import_module(pkg + ".sim.workloads")
    design_mod = importlib.import_module(pkg + ".core.design")
    d = design_mod.get_design(design)

    def single(benches):
        cfg = config_mod.SimConfig(n_apps=len(benches), sim_cycles=cycles,
                                   design=d)
        pm = jnp.asarray(runner_mod._mix_matrix(benches))
        fn = runner_mod._compiled_run(cfg)
        return (lambda: jax.block_until_ready(fn(pm))), cycles

    def batch(mixes):
        cfg = config_mod.SimConfig(n_apps=len(mixes[0]), sim_cycles=cycles,
                                   design=d)
        pm = jnp.asarray(np.stack([runner_mod._mix_matrix(m)
                                   for m in mixes]))
        fn = runner_mod._compiled_batch_run(cfg)
        return (lambda: jax.block_until_ready(fn(pm))), cycles * len(mixes)

    mix4 = workloads_mod.mix_workloads(seed=7, n_mixes=1, n_apps=4)[0]
    return {
        "2app": single(["3DS", "BLK"]),
        "4app": single(list(mix4)),
        "batch8": batch(workloads_mod.pair_workloads()[:8]),
    }


# ---------------------------------------------------------------------------
# baseline materialization for --compare
# ---------------------------------------------------------------------------

def _materialize_baseline(ref: str) -> str:
    """Extract src/repro at `ref` into .bench_compare/<sha>/src/repro_base
    (imports rewritten), put it on sys.path, and return the resolved sha."""
    sha = subprocess.run(["git", "rev-parse", ref], cwd=REPO_ROOT,
                         capture_output=True, text=True,
                         check=True).stdout.strip()
    dest = COMPARE_DIR / sha[:12]
    pkg_dir = dest / "src" / "repro_base"
    if not pkg_dir.exists():
        # stage into a temp dir and rename into place only when fully
        # rewritten — a half-rewritten cached baseline would silently
        # import the CURRENT `repro` modules and fake a ~1.0x ratio
        shutil.rmtree(dest, ignore_errors=True)
        tmp = COMPARE_DIR / (dest.name + ".tmp")
        shutil.rmtree(tmp, ignore_errors=True)
        tar_bytes = subprocess.run(
            ["git", "archive", "--format=tar", sha, "src/repro"],
            cwd=REPO_ROOT, capture_output=True, check=True).stdout
        with tarfile.open(fileobj=BytesIO(tar_bytes)) as tf:
            try:
                tf.extractall(tmp, filter="data")
            except TypeError:            # Python < 3.12
                tf.extractall(tmp)
        (tmp / "src" / "repro").rename(tmp / "src" / "repro_base")
        for py in (tmp / "src" / "repro_base").rglob("*.py"):
            py.write_text(_IMPORT_RE.sub(r"\1repro_base", py.read_text()))
        tmp.rename(dest)
    path = str(dest / "src")
    if path not in sys.path:
        sys.path.insert(0, path)
    mod = importlib.import_module("repro_base.sim.runner")
    assert mod.__file__.startswith(str(dest)), mod.__file__
    return sha


def run_compare(ref: str, design: str = "mask", cycles: int = 8_000,
                rounds: int = 5, out_path: Path = OUT_PATH) -> dict:
    """Interleaved A/B: current tree vs the committed tree at `ref`.

    Each round times (new, old) back-to-back per scenario; the headline
    number is the median over rounds of old_time / new_time (>1 means
    the working tree is faster)."""
    sha = _materialize_baseline(ref)
    scen_new = _scenarios(design, cycles, "repro")
    scen_old = _scenarios(design, cycles, "repro_base")
    for name in scen_new:                  # compile + warm both sides
        for tag, scen in (("new", scen_new), ("old", scen_old)):
            t0 = time.perf_counter()
            scen[name][0]()
            print(f"# warm {name}/{tag}: {time.perf_counter() - t0:.1f}s",
                  flush=True)

    ratios = {name: [] for name in scen_new}
    rates = {name: {"new": [], "old": []} for name in scen_new}
    for r in range(rounds):
        for name in scen_new:
            call_new, steps = scen_new[name]
            call_old, _ = scen_old[name]
            t0 = time.perf_counter()
            call_new()
            t_new = time.perf_counter() - t0
            t0 = time.perf_counter()
            call_old()
            t_old = time.perf_counter() - t0
            ratios[name].append(t_old / t_new)
            rates[name]["new"].append(steps / t_new)
            rates[name]["old"].append(steps / t_old)
        print(f"# compare round {r + 1}/{rounds} done", flush=True)

    result = _measure_report(design, cycles, rounds,
                             {n: rates[n]["new"] for n in rates})
    result["compare"] = {
        "ref": ref,
        "sha": sha,
        "speedup": {n: float(np.median(v)) for n, v in ratios.items()},
        "ratio_samples": {n: [float(x) for x in v]
                          for n, v in ratios.items()},
        "baseline_steps_per_sec": {n: float(np.median(rates[n]["old"]))
                                   for n in rates},
    }
    out_path.write_text(json.dumps(result, indent=2) + "\n")
    print(json.dumps({"design": design, "cycles": cycles,
                      "steps_per_sec": result["steps_per_sec"],
                      "speedup_vs_" + sha[:8]: result["compare"]["speedup"]},
                     indent=2))
    print(f"# wrote {out_path}")
    return result


def _measure_report(design, cycles, rounds, samples) -> dict:
    return {
        "design": design,
        "cycles": cycles,
        "rounds": rounds,
        "steps_per_sec": {n: float(np.median(v)) for n, v in samples.items()},
        "samples": {n: [float(x) for x in v] for n, v in samples.items()},
        "meta": {
            "jax": jax.__version__,
            "platform": platform.platform(),
            "backend": jax.default_backend(),
        },
    }


def run_bench(design: str = "mask", cycles: int = 8_000, rounds: int = 5,
              out_path: Path = OUT_PATH) -> dict:
    scen = _scenarios(design, cycles)
    for name, (call, _) in scen.items():   # compile + warm
        t0 = time.perf_counter()
        call()
        print(f"# warm {name}: {time.perf_counter() - t0:.1f}s", flush=True)

    samples = {name: [] for name in scen}
    for r in range(rounds):                # interleaved measurement
        for name, (call, steps) in scen.items():
            t0 = time.perf_counter()
            call()
            dt = time.perf_counter() - t0
            samples[name].append(steps / dt)
        print(f"# round {r + 1}/{rounds} done", flush=True)

    result = _measure_report(design, cycles, rounds, samples)
    out_path.write_text(json.dumps(result, indent=2) + "\n")
    print(json.dumps({k: result[k] for k in ("design", "cycles",
                                             "steps_per_sec")}, indent=2))
    print(f"# wrote {out_path}")
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--design", default="mask")
    ap.add_argument("--cycles", type=int, default=8_000)
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--out", type=Path, default=OUT_PATH)
    ap.add_argument("--compare", metavar="GIT_REF", default=None,
                    help="interleave against the committed tree at GIT_REF "
                         "and report median new/old speedups")
    args = ap.parse_args()
    if args.compare:
        run_compare(args.compare, args.design, args.cycles, args.rounds,
                    args.out)
    else:
        run_bench(args.design, args.cycles, args.rounds, args.out)


if __name__ == "__main__":
    main()
