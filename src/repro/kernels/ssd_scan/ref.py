"""Pure-jnp oracle: full chunked SSD (delegates to the model module, which
is itself validated against the O(S) recurrence in tests)."""
from repro.models.mamba2 import ssd_chunked  # noqa: F401


def ssd_recurrence_ref(x, dt, A, B, C):
    """O(S) sequential recurrence — ground truth for everything SSD.

    x: (b, S, nh, hd); dt: (b, S, nh); A: (nh,); B, C: (b, S, ds).
    """
    import jax
    import jax.numpy as jnp

    b, S, nh, hd = x.shape
    ds = B.shape[-1]
    h0 = jnp.zeros((b, nh, hd, ds), jnp.float32)

    def step(h, inp):
        xt, dtt, Bt, Ct = inp
        decay = jnp.exp(dtt * A[None])                  # (b, nh)
        xin = xt * dtt[..., None]                       # (b, nh, hd)
        h = h * decay[..., None, None] + jnp.einsum(
            "bhp,bd->bhpd", xin.astype(jnp.float32), Bt.astype(jnp.float32))
        y = jnp.einsum("bhpd,bd->bhp", h, Ct.astype(jnp.float32))
        return h, y

    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(B, 1, 0), jnp.moveaxis(C, 1, 0))
    h, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1), h                    # (b, S, nh, hd)
