"""Multi-tenant continuous-batching serving engine.

Requests from multiple tenants (ASIDs) share one model + one paged KV pool.
Scheduling is the paper's three-class discipline (repro.core.dram_sched
semantics transplanted to request admission, §5.4):

  Golden — translation/metadata work (page allocation, table updates,
           admission) always runs before token work each step.
  Silver — one tenant at a time gets guaranteed decode slots, quota
           proportional to Concurrent_i * Stalled_i (Eq. 1 analogue:
           in-flight sequences x queue depth).
  Normal — remaining decode slots round-robin over other tenants.

Admission is additionally gated by a pluggable placement policy
(serving.placement): once per decision epoch the policy — possibly
consulting the simulator-backed contention oracle (serving.oracle) —
decides which tenants may co-run and each tenant's admission cap;
decisions are recorded on `self.decisions` for the serving benchmark's
predicted-vs-achieved fairness accounting.

Per-tenant throughput / weighted-speedup metrics mirror the paper's
evaluation (serving.metrics).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.memmgr import kv_cache as kvc
from repro.models import model as M
from repro.serving.placement import (EngineView, PlacementDecision,
                                     PlacementPolicy)


@dataclasses.dataclass
class Request:
    rid: int
    tenant: int
    prompt: np.ndarray
    max_new: int                 # decode steps (prefill token not counted)
    out: List[int] = dataclasses.field(default_factory=list)
    seq_slot: int = -1
    submit_step: int = 0
    first_token_step: int = -1   # prefill emission step (TTFT anchor)
    finish_step: int = -1

    @property
    def decoded(self) -> int:
        """Tokens produced by DECODE steps. `out` also holds the token
        the prefill emitted, so completion/throughput accounting uses
        this, not len(out) — a request runs exactly
        min(max_new, decode_len_cap) decode steps."""
        return max(len(self.out) - 1, 0)


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 8
    thres_max: int = 16          # silver quota scale
    decode_len_cap: int = 256


def stub_forwards():
    """Canonical token-compute stubs for the `forwards` seam: constant
    logits (argmax -> token 0), no KV tensors. Scheduling behavior —
    admission, silver rotation, placement, completion — is identical to
    a real model's; only the token values differ. Used by the serving
    benchmark and the engine scheduling-law tests."""
    def prefill(cfg, run, params, batch, max_len=None):
        return jnp.zeros((1, batch["tokens"].shape[1], 8)), {}

    def decode(cfg, run, params, batch, caches):
        return jnp.zeros((1, 1, 8)), caches
    return prefill, decode


def stub_model_config(vocab_size: int = 64):
    """Minimal cfg satisfying the engine's host-side checks (no real
    model fields needed when `forwards` is stubbed)."""
    import types
    return types.SimpleNamespace(n_patches=0, is_enc_dec=False,
                                 vocab_size=vocab_size)


class ServingEngine:
    """CPU-scale reference engine (smoke/examples); the same scheduling laws
    drive the dry-run serve_step at production shapes."""

    def __init__(self, cfg: ModelConfig, run: RunConfig, params,
                 pool_cfg: kvc.PoolConfig, ecfg: EngineConfig = EngineConfig(),
                 placement: Optional[PlacementPolicy] = None,
                 profiles: Optional[Mapping[int, str]] = None,
                 forwards: Optional[Tuple] = None):
        self.cfg = cfg
        self.run = run
        self.params = params
        self.pool_cfg = pool_cfg
        self.ecfg = ecfg
        self.pool = kvc.init(pool_cfg)
        self.queues: Dict[int, deque] = {}
        self.running: List[Request] = []
        self.finished: List[Request] = []
        self.step_count = 0
        self.silver_tenant = 0
        self.silver_left = 1
        self.placement = placement if placement is not None \
            else PlacementPolicy()
        self.profiles: Dict[int, str] = dict(profiles or {})
        self.decisions: List[PlacementDecision] = []
        self._free_slots = list(range(pool_cfg.max_seqs))
        self._decode = None
        self._prefill_cache: Dict[int, tuple] = {}
        self._silver_quota_used = 0
        # (prefill_fn, decode_fn) seam: benchmarks/tests that measure
        # SCHEDULING (steps, not wall-clock) stub the token compute
        self._fwd_prefill, self._fwd_decode = (
            forwards if forwards is not None
            else (M.forward_prefill, M.forward_decode))

    # ------------------------------------------------------------- API
    def submit(self, req: Request):
        req.submit_step = self.step_count
        self.queues.setdefault(req.tenant, deque()).append(req)

    def _running_count(self, tenant: int) -> int:
        return sum(1 for r in self.running if r.tenant == tenant)

    def view(self) -> EngineView:
        """Host-side snapshot the placement policy decides from."""
        pressure = kvc.pool_pressure(self.pool_cfg, self.pool)
        return EngineView(
            step=self.step_count,
            max_batch=self.ecfg.max_batch,
            queued={t: len(q) for t, q in self.queues.items()},
            running={t: self._running_count(t)
                     for t in {r.tenant for r in self.running}},
            waiting_since={t: q[0].submit_step
                           for t, q in self.queues.items() if q},
            pool_used_frac=pressure.used_frac,
            pool_free_seqs=pressure.free_seqs,
            profiles=self.profiles)

    def _quota(self) -> Dict[int, int]:
        """Eq. (1) analogue over tenants with queued work."""
        w = {t: max(len(q), 1) * (1 + sum(1 for r in self.running
                                          if r.tenant == t))
             for t, q in self.queues.items() if q}
        tot = sum(w.values()) or 1
        return {t: max(self.ecfg.thres_max * v // tot, 1)
                for t, v in w.items()}

    # ------------------------------------------------------- scheduling
    def _admit(self):
        """Golden phase: admissions + page allocation first. The
        placement decision gates every admission: a tenant outside the
        epoch's allowed set, or at its admission cap, keeps queueing
        (its running requests still decode — caps are admission-only)."""
        tenants = sorted(self.queues)
        # silver tenant first
        order = ([self.silver_tenant] +
                 [t for t in tenants if t != self.silver_tenant])
        for t in order:
            q = self.queues.get(t)
            while (q and len(self.running) < self.ecfg.max_batch
                   and self._free_slots
                   and self.placement.may_admit(t, self._running_count(t))):
                req = q.popleft()
                slot = self._free_slots.pop()
                self.pool, ok = kvc.admit_seq_jit(
                    self.pool_cfg, self.pool, jnp.int32(slot),
                    jnp.int32(t), jnp.int32(len(req.prompt)))
                if not bool(ok):
                    self._free_slots.append(slot)
                    q.appendleft(req)
                    break
                req.seq_slot = slot
                self._prefill(req)
                self.running.append(req)

    def _prefill(self, req: Request):
        batch = {"tokens": jnp.asarray(req.prompt, jnp.int32)[None]}
        if self.cfg.n_patches:
            batch["patch_embeds"] = jnp.zeros(
                (1, self.cfg.n_patches, self.cfg.d_model), jnp.bfloat16)
        if self.cfg.is_enc_dec:
            batch["frames"] = jnp.zeros(
                (1, self.cfg.enc_len, self.cfg.d_model), jnp.bfloat16)
        logits, caches = self._fwd_prefill(
            self.cfg, self.run, self.params, batch,
            max_len=self.pool_cfg.pages_per_seq * self.pool_cfg.page_size)
        tok = int(jnp.argmax(logits[0, -1]))
        req.out.append(tok)
        req.first_token_step = self.step_count
        self._prefill_cache[req.rid] = caches

    def _select_decode_batch(self) -> List[Request]:
        """Silver quota first, then normal-class round over the rest.
        Silver requests beyond the quota backfill as NORMAL class: they
        run only when slots would otherwise go unused and do not burn
        silver quota (`_silver_quota_used` counts only the quota-class
        head of the batch)."""
        silver = [r for r in self.running if r.tenant == self.silver_tenant]
        others = [r for r in self.running if r.tenant != self.silver_tenant]
        quota_n = min(len(silver), max(self.silver_left, 0))
        batch = (silver[:quota_n] + others + silver[quota_n:])
        batch = batch[: self.ecfg.max_batch]
        self._silver_quota_used = min(quota_n, len(batch))
        return batch

    def step(self):
        """One engine iteration: placement epoch -> golden (admit/alloc)
        -> silver/normal decode."""
        self.step_count += 1
        active = tuple(sorted({t for t, q in self.queues.items() if q}
                              | {r.tenant for r in self.running}))
        if self.placement.due(self.step_count) or self.placement.stale(active):
            self.decisions.append(self.placement.refresh(self.view()))
        self._admit()
        batch = self._select_decode_batch()
        if not batch:
            return
        done = []
        for req in batch:  # reference implementation decodes per-request
            caches = self._prefill_cache[req.rid]
            tok = jnp.asarray([[req.out[-1]]], jnp.int32)
            logits, caches = self._fwd_decode(
                self.cfg, self.run, self.params, {"tokens": tok}, caches)
            self._prefill_cache[req.rid] = caches
            nxt = int(jnp.argmax(logits[0, -1]))
            req.out.append(nxt)
            self.pool, ok = kvc.append_token_alloc_jit(
                self.pool_cfg, self.pool, jnp.int32(req.seq_slot))
            if req.decoded >= min(req.max_new, self.ecfg.decode_len_cap):
                done.append(req)
        # silver rotation: only quota-class decodes burn quota (backfilled
        # silver requests ran as normal class)
        self.silver_left -= self._silver_quota_used
        if self.silver_left <= 0 and self.queues:
            tenants = sorted(set(list(self.queues) +
                                 [r.tenant for r in self.running]))
            if tenants:
                ix = (tenants.index(self.silver_tenant) + 1) % len(tenants) \
                    if self.silver_tenant in tenants else 0
                self.silver_tenant = tenants[ix]
                self.silver_left = self._quota().get(self.silver_tenant, 1)
        for req in done:
            req.finish_step = self.step_count
            self.running.remove(req)
            self.pool = kvc.release_seq_jit(self.pool_cfg, self.pool,
                                            jnp.int32(req.seq_slot))
            self._free_slots.append(req.seq_slot)
            self._prefill_cache.pop(req.rid, None)
            self.finished.append(req)

    def run_until_drained(self, max_steps: int = 1000):
        for _ in range(max_steps):
            if not self.running and not any(self.queues.values()):
                break
            self.step()
        return self.finished
