"""Address-Space-Aware DRAM Scheduler (paper §5.4).

Three queues per memory channel:

  Golden  — all translation (page-walk) requests; small FIFO; always first.
  Silver  — data requests of ONE application at a time; quota per Eq. (1):
              thres_i = thres_max * (Concurrent_i * WrpStalled_i)
                        / sum_j (Concurrent_j * WrpStalled_j)
  Normal  — everything else. FR-FCFS (row hits first) within Silver/Normal;
            Golden is FIFO (walk requests have poor row locality, fn. 5).

The batched model used by the simulator: each step a channel can service
``slots`` requests. Requests are ranked (queue priority, row-hit, age) and
the top ``slots`` complete with latencies derived from row hit/miss; the
per-bank open row and per-app silver accounting update functionally.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

T_ROW_HIT = 100      # cycles: CAS-only access (GPU clock domain)
T_ROW_MISS = 250     # cycles: precharge + activate + CAS
T_QUEUE_UNIT = 50    # serialization per queued-ahead request


class DramState(NamedTuple):
    open_row: jax.Array        # (channels, banks) int32 open row id
    silver_app: jax.Array      # () int32 — app currently owning Silver
    silver_left: jax.Array     # () int32 — remaining silver quota
    conc_walks: jax.Array      # (n_apps,) int32 'Concurrent_i' (6-bit, §5.4)
    warps_stalled: jax.Array   # (n_apps,) int32 'WrpStalled_i'
    queue_len: jax.Array       # (channels, 3) int32 backlog per class


def init(n_channels: int, n_banks: int, n_apps: int) -> DramState:
    return DramState(
        open_row=jnp.full((n_channels, n_banks), -1, jnp.int32),
        silver_app=jnp.zeros((), jnp.int32),
        silver_left=jnp.full((), 1, jnp.int32),
        conc_walks=jnp.zeros((n_apps,), jnp.int32),
        warps_stalled=jnp.zeros((n_apps,), jnp.int32),
        queue_len=jnp.zeros((n_channels, 3), jnp.int32),
    )


def silver_quota(state: DramState, thres_max=500) -> jax.Array:
    """(n_apps,) Eq. (1) thresholds."""
    w = (state.conc_walks * state.warps_stalled).astype(jnp.float32)
    tot = jnp.maximum(w.sum(), 1.0)
    return jnp.maximum((thres_max * w / tot).astype(jnp.int32), 1)


def classify(state: DramState, app, is_tlb, mask_enabled):
    """queue class per request: 0 golden, 1 silver, 2 normal.

    `mask_enabled` may be a Python bool or a traced boolean scalar (the
    design-vectorized grid feeds it from `DesignParams`); disabled means
    one FR-FCFS queue, i.e. everything is class 2."""
    silver = (app == state.silver_app)
    cls = jnp.where(is_tlb, 0, jnp.where(silver, 1, 2)).astype(jnp.int32)
    return jnp.where(mask_enabled, cls, jnp.int32(2))


def access(state: DramState, channel, bank, row, app, is_tlb, active,
           mask_enabled, thres_max=500,
           fr_fcfs: bool = True, waves: int = 1) -> Tuple[DramState, jax.Array]:
    """Batched DRAM access model. All args (N,). Returns (state', latency (N,)).

    `mask_enabled` / `thres_max` may be Python values or traced scalars
    (see `classify`), so one compiled program serves every design point.

    Latency = service (row hit/miss) + queueing: number of requests this
    step that rank ahead of you on the same channel (priority-class first,
    then row-hit-first within class) × T_QUEUE_UNIT + standing backlog.

    `waves` partitions the batch into `waves` contiguous equal groups that
    are queued independently (in-batch ranking is block-diagonal): the
    simulator's fused memory path hands over all of a cycle's sub-access
    rounds in one call, and each round contends only with itself — exactly
    as when the rounds were separate sequential calls. `waves=1` is the
    plain fully-contending batch.
    """
    n_channels, n_banks = state.open_row.shape
    cls = classify(state, app, is_tlb, mask_enabled)

    N = app.shape[0]
    C = N // waves
    row_hit = state.open_row[channel, bank] == row
    if waves > 1:
        # progressive open rows across waves, per flat position (the same
        # core's earlier sub-access opening the row it re-touches is the
        # dominant sequential row-hit source); cross-position openings and
        # closings between waves are not modeled
        row_w = row.reshape(waves, C)
        cb_w = (channel * n_banks + bank).reshape(waves, C)
        tri_w = jnp.arange(waves)[:, None, None] \
            < jnp.arange(waves)[None, :, None]
        opened = ((row_w[:, None, :] == row_w[None, :, :])
                  & (cb_w[:, None, :] == cb_w[None, :, :])
                  & tri_w & active.reshape(waves, C)[:, None, :]) \
            .any(0).reshape(N)
        row_hit = row_hit | opened
    service = jnp.where(row_hit, T_ROW_HIT, T_ROW_MISS)

    # rank = priority ahead of me on my (channel, bank) within my wave —
    # banks service in parallel. (waves, C, C) blocks instead of (N, N).
    cb = (channel * n_banks + bank).reshape(waves, C)
    key = cls * 2 + (~row_hit) if fr_fcfs else cls * 2
    key = key.reshape(waves, C)
    tri = jnp.arange(C)[None, :] < jnp.arange(C)[:, None]   # j before i
    ahead = (cb[:, None, :] == cb[:, :, None]) \
        & active.reshape(waves, C)[:, None, :] \
        & ((key[:, None, :] < key[:, :, None])
           | ((key[:, None, :] == key[:, :, None]) & tri[None]))
    n_ahead = ahead.sum(axis=2).reshape(N)

    # standing backlog + EWMA decay toward observed per-class pressure.
    # With waves > 1 the EWMA chains once per wave (exactly the update the
    # sequential per-round calls applied 8x per cycle — a single update
    # with the summed counts would settle ~3x too high) and each wave
    # reads the backlog its round would have seen.
    quota = silver_quota(state, thres_max)
    n_apps = state.conc_walks.shape[0]
    if waves == 1:
        backlog = state.queue_len[channel, cls]
        counts = jnp.zeros((n_channels, 3), jnp.int32).at[channel, cls].add(
            active.astype(jnp.int32))
        queue_len = (state.queue_len * 3 + counts) // 4
        served_w = (active & (cls == 1)).sum(dtype=jnp.int32)[None]
    else:
        wave_ix = jnp.repeat(jnp.arange(waves, dtype=jnp.int32), C)
        counts = jnp.zeros((waves, n_channels, 3), jnp.int32).at[
            wave_ix, channel, cls].add(active.astype(jnp.int32))
        qs = []
        queue_len = state.queue_len
        for k in range(waves):
            qs.append(queue_len)
            queue_len = (queue_len * 3 + counts[k]) // 4
        backlog = jnp.stack(qs)[wave_ix, channel, cls]
        served_w = (active & (cls == 1)).reshape(waves, C) \
            .sum(1, dtype=jnp.int32)

    latency = service + (n_ahead + backlog) * T_QUEUE_UNIT
    latency = jnp.where(active, latency, 0)

    # ---- state updates ----
    # open rows: last active request per (channel, bank) wins; inactive
    # lanes are routed out of bounds and dropped — a masked write-back of
    # the gathered value would let a trailing inactive lane clobber an
    # earlier active lane's update with the stale cycle-start row
    new_open = state.open_row.at[
        jnp.where(active, channel, n_channels), bank].set(row, mode="drop")

    # silver rotation: consume quota per wave (at most one rotation per
    # wave, like the sequential per-round calls); classification keeps the
    # cycle-start silver app — mid-cycle rotations reclassify nothing
    silver_app, silver_left = state.silver_app, state.silver_left
    for k in range(served_w.shape[0]):
        left = silver_left - served_w[k]
        next_app = (silver_app + 1) % n_apps
        rotate = left <= 0
        silver_app = jnp.where(rotate, next_app, silver_app)
        silver_left = jnp.where(rotate, quota[next_app], left)

    return state._replace(open_row=new_open, silver_app=silver_app,
                          silver_left=silver_left,
                          queue_len=queue_len), latency


def update_pressure(state: DramState, conc_walks, warps_stalled) -> DramState:
    """Refresh the Eq. (1) inputs (reset each epoch, §5.4)."""
    return state._replace(
        conc_walks=jnp.asarray(conc_walks, jnp.int32),
        warps_stalled=jnp.asarray(warps_stalled, jnp.int32))
