"""Config sanity: exact assigned hyper-parameters + published param counts."""
import pytest

from repro.configs import ARCHS, all_cells, get_model, get_run_config, reduced_model
from repro.configs.shapes import ALL_SHAPES

EXPECTED = {
    # name: (total params, rel tolerance)
    "llama3-8b": (8.0e9, 0.06),
    "mistral-large-123b": (123e9, 0.06),
    "glm4-9b": (9.4e9, 0.10),
    "qwen3-4b": (4.0e9, 0.25),       # explicit head_dim inflates attn a bit
    "phi-3-vision-4.2b": (4.2e9, 0.12),
    "mamba2-1.3b": (1.3e9, 0.15),
    "olmoe-1b-7b": (6.9e9, 0.10),
    "mixtral-8x22b": (141e9, 0.10),
    "jamba-1.5-large-398b": (398e9, 0.12),
    "whisper-base": (72e6, 0.30),
}


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_param_counts(arch):
    target, tol = EXPECTED[arch]
    n = ARCHS[arch].param_count()
    assert abs(n - target) / target < tol, f"{arch}: {n/1e9:.2f}B vs {target/1e9}B"


def test_active_params_moe():
    jamba = get_model("jamba-1.5-large-398b")
    active = jamba.param_count(active_only=True)
    assert 70e9 < active < 110e9  # ~94B active
    olmoe = get_model("olmoe-1b-7b")
    assert 0.9e9 < olmoe.param_count(active_only=True) < 1.8e9


def test_40_cells():
    cells = list(all_cells())
    assert len(cells) == 40
    skipped = [(a, s) for a, s, ok in cells if not ok]
    # long_500k skipped exactly for the 7 pure-full-attention archs
    assert len(skipped) == 7
    assert all(s == "long_500k" for _, s in skipped)
    runnable_500k = {a for a, s, ok in cells if s == "long_500k" and ok}
    assert runnable_500k == {"mamba2-1.3b", "jamba-1.5-large-398b",
                             "mixtral-8x22b"}


def test_exact_assigned_values():
    m = get_model("mistral-large-123b")
    assert (m.n_layers, m.d_model, m.n_heads, m.n_kv_heads, m.d_ff,
            m.vocab_size) == (88, 12288, 96, 8, 28672, 32768)
    g = get_model("glm4-9b")
    assert (g.n_kv_heads, g.vocab_size) == (2, 151552)
    j = get_model("jamba-1.5-large-398b")
    assert (j.attn_every, j.n_experts, j.top_k) == (8, 16, 2)
    x = get_model("mixtral-8x22b")
    assert (x.sliding_window, x.n_experts, x.top_k) == (4096, 8, 2)
    q = get_model("qwen3-4b")
    assert q.qk_norm and q.head_dim == 128
    w = get_model("whisper-base")
    assert w.n_enc_layers == 6 and w.is_enc_dec


def test_reduced_models_preserve_structure():
    for arch, cfg in ARCHS.items():
        r = reduced_model(cfg)
        assert r.family == cfg.family
        assert r.is_moe == cfg.is_moe
        assert r.is_hybrid == cfg.is_hybrid
        assert r.is_enc_dec == cfg.is_enc_dec
        if cfg.n_heads:
            assert (r.n_heads // max(r.n_kv_heads, 1)
                    == min(cfg.n_heads // max(cfg.n_kv_heads, 1), 4))


def test_run_config_rejects_skipped_cell():
    with pytest.raises(ValueError):
        get_run_config("llama3-8b", "long_500k")


def test_padded_vocab():
    for cfg in ARCHS.values():
        assert cfg.padded_vocab % 128 == 0
        assert 0 <= cfg.padded_vocab - cfg.vocab_size < 128
