"""Tenant app-profile -> simulator-benchmark mapping (oracle-facing).

The serving layer talks about *tenants* with declared workload profiles
("interactive", "heavy", ...); the simulator talks about Table 2
benchmarks with calibrated (L1 TLB, L2 TLB) locality classes. This thin
mapping is the contract between them: the contention oracle
(`repro.serving.oracle`) maps each tenant's profile to a representative
bench here and asks the simulator how a candidate co-placement would
contend. A profile name may also BE a bench name (power users pin the
exact Table 2 stream they calibrated against).
"""
from __future__ import annotations

from typing import Dict, Tuple

from repro.sim.workloads import BENCHES, CATEGORY

# serving-level profiles -> a representative Table 2 bench per
# (L1 TLB, L2 TLB) locality class. Chosen deterministically from the
# class members so profile-mapped predictions are stable across PRs.
PROFILES: Dict[str, str] = {
    # tiny working set, fits the per-core L1 TLB: cheap co-runner
    "interactive": "NN",      # (low, low)
    "light": "LUD",           # (low, low)
    # page-streaming with reach far beyond the shared L2 TLB
    "streaming": "SAD",       # (low, high)
    "rag": "BFS2",            # (low, high)
    # scattered accesses in a modest set: misses L1, fits shared L2 solo
    "scattered": "GUP",       # (high, low)
    # the aggressor class: thrashes both TLB levels, DRAM-bound walks
    "batch": "MUM",           # (high, high)
    "heavy": "3DS",           # (high, high)
}

DEFAULT_PROFILE = "batch"


def bench_for_profile(profile: str) -> str:
    """Resolve a tenant profile (or a literal bench name) to a bench."""
    if profile in PROFILES:
        return PROFILES[profile]
    if profile in CATEGORY:
        return profile
    raise KeyError(
        f"unknown app profile {profile!r}: expected one of "
        f"{sorted(PROFILES)} or a Table 2 bench name from {BENCHES}")


def profile_category(profile: str) -> Tuple[str, str]:
    """(L1 TLB, L2 TLB) miss-rate class of a profile's mapped bench."""
    return CATEGORY[bench_for_profile(profile)]
