"""Data pipeline determinism, sharder rules, HLO parser correctness."""
import json
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_run_config, reduced_model
from repro.data.pipeline import DataConfig, DataPipeline
from repro.models.losses import cross_entropy
from repro.models.params import Param
from repro.roofline.hlo_parse import analyze_hlo


# ------------------------------------------------------------------- data

def test_pipeline_deterministic_and_sharded():
    cfg = reduced_model(ARCHS["llama3-8b"])
    from repro.configs.base import ShapeConfig
    shape = ShapeConfig("t", seq_len=16, global_batch=8, kind="train")
    full = DataPipeline(cfg, shape).batch_at(3)
    again = DataPipeline(cfg, shape).batch_at(3)
    np.testing.assert_array_equal(full["tokens"], again["tokens"])
    # host shards partition the global batch rows exactly
    h0 = DataPipeline(cfg, shape, host_index=0, host_count=2).batch_at(3)
    h1 = DataPipeline(cfg, shape, host_index=1, host_count=2).batch_at(3)
    np.testing.assert_array_equal(
        np.concatenate([h0["tokens"], h1["tokens"]]), full["tokens"])


def test_pipeline_iterator_skip_ahead():
    cfg = reduced_model(ARCHS["qwen3-4b"])
    from repro.configs.base import ShapeConfig
    shape = ShapeConfig("t", seq_len=16, global_batch=4, kind="train")
    pipe = DataPipeline(cfg, shape)
    seq = list(pipe.iterate(start_step=5, stop_step=8))
    assert [s for s, _ in seq] == [5, 6, 7]
    np.testing.assert_array_equal(seq[1][1]["tokens"],
                                  pipe.batch_at(6)["tokens"])


# ----------------------------------------------------------------- losses

def test_cross_entropy_padded_vocab_masked():
    logits = jnp.zeros((1, 2, 8))
    # make a padded column irresistible — masking must ignore it
    logits = logits.at[..., 7].set(100.0)
    labels = jnp.asarray([[0, 1]])
    loss_masked, m = cross_entropy(logits, labels, real_vocab=7)
    assert abs(float(loss_masked) - np.log(7)) < 1e-4
    loss_unmasked, _ = cross_entropy(logits, labels)
    assert float(loss_unmasked) > 50


# --------------------------------------------------------------- HLO parse

def test_hlo_parser_counts_scan_trips():
    """A scanned matmul must be counted trip-count times."""
    n, m, k, trips = 64, 64, 64, 7

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=trips)
        return y

    x = jnp.zeros((n, k))
    w = jnp.zeros((k, m))
    hlo = jax.jit(f).lower(x, w).compile().as_text()
    t = analyze_hlo(hlo)
    expect = 2 * n * m * k * trips
    assert abs(t["dot_flops"] - expect) / expect < 0.05, t["dot_flops"]


def test_hlo_parser_collectives_smoke():
    hlo = """
HloModule test

ENTRY %main (a: f32[64]) -> f32[64] {
  %a = f32[64]{0} parameter(0)
  ROOT %ar = f32[64]{0} all-reduce(%a), replica_groups={}, to_apply=%add
}
"""
    t = analyze_hlo(hlo)
    assert t["coll_by_op"].get("all-reduce", 0) == 256


# ----------------------------------------------------------------- sharder

class _FakeRun:
    def __init__(self):
        from repro.configs import get_run_config
        self.__dict__.update(get_run_config("llama3-8b", "train_4k").__dict__)


@pytest.mark.slow
def test_sharder_specs_subprocess():
    """Lower a reduced model on an 8-device mesh in a subprocess (the only
    way to get >1 host device under pytest)."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
import jax.numpy as jnp
from repro.configs import ARCHS, reduced_model, get_run_config
from repro.configs.base import RunConfig, ShapeConfig
from repro.distributed.sharding import Sharder
from repro.models import model as M
from repro.train.step import build_train_step
from repro.train import optimizer as opt_mod

cfg = reduced_model(ARCHS["llama3-8b"])
shape = ShapeConfig("t", seq_len=32, global_batch=4, kind="train")
run = RunConfig(model=cfg, shape=shape, remat=False, fsdp=True,
                attn_block_q=16, attn_block_k=16)
mesh = jax.make_mesh((2, 4), ("data", "model"))
sh = Sharder(mesh, run)
with mesh:
    params = M.abstract_params(cfg, sh.param_sharding)
    batch = M.input_specs(cfg, shape, sh.act_sharding)
    ocfg = opt_mod.OptConfig()
    opt = opt_mod.abstract_state(M.param_specs(cfg), ocfg, sh.param_sharding)
    step = build_train_step(cfg, run, ocfg, sh.constrain)
    compiled = jax.jit(step, donate_argnums=(0, 1)).lower(
        params, opt, batch).compile()
ca = compiled.cost_analysis()
ca = ca[0] if isinstance(ca, (list, tuple)) else ca  # jax<0.5 returns a list
print("OK", ca["flops"] > 0)
"""
    # the 8-fake-device CPU compile takes several minutes on slow hosts
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=900,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert "OK True" in out.stdout, out.stderr[-2000:]


def test_param_spec_no_duplicate_axes():
    from jax.sharding import Mesh
    import jax
    from repro.distributed.sharding import Sharder
    run = get_run_config("jamba-1.5-large-398b", "train_4k")
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    sh = Sharder(mesh, run)
    p = Param((16, 8192, 24576), ("experts", "embed", "ffn"))
    spec = sh.param_spec(p)
    flat = [e for entry in spec if entry for e in
            (entry if isinstance(entry, tuple) else (entry,))]
    assert len(flat) == len(set(flat))
