"""Multi-tenant paged KV manager + serving engine integration tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.memmgr import block_table as bt_mod
from repro.memmgr import kv_cache as kvc


def _pool(n_pages=32, page=8, max_seqs=8, pps=4):
    cfg = kvc.PoolConfig(n_pages=n_pages, page_size=page, n_kv=2, head_dim=16,
                         n_layers=2, max_seqs=max_seqs, pages_per_seq=pps)
    return cfg, kvc.init(cfg)


def test_admit_translate_release_lifecycle():
    cfg, pool = _pool()
    pool, ok = kvc.admit_seq(cfg, pool, jnp.int32(0), jnp.int32(1),
                             jnp.int32(20))  # 20 tokens -> 3 pages
    assert bool(ok)
    assert int(pool.seq_lens[0]) == 20
    pool, phys, fault, _ = kvc.lookup(cfg, pool, jnp.asarray([0, 0]),
                                      jnp.asarray([0, 2]))
    assert not bool(fault.any())
    # unmapped logical page faults
    pool, _, fault, _ = kvc.lookup(cfg, pool, jnp.asarray([0]),
                                   jnp.asarray([3]))
    assert bool(fault[0])
    before = int(bt_mod.n_free(pool.tables))
    pool = kvc.release_seq(cfg, pool, jnp.int32(0))
    assert int(bt_mod.n_free(pool.tables)) == before + 3


def test_protection_domain_fault():
    """Cross-ASID access is a protection fault (the paper's §5.1 isolation)."""
    cfg, pool = _pool()
    pool, _ = kvc.admit_seq(cfg, pool, jnp.int32(0), jnp.int32(1),
                            jnp.int32(8))
    # forge: seq 1 owned by tenant 2 pointing at tenant 1's page
    leaf = pool.tables.leaf.at[1, 0].set(pool.tables.leaf[0, 0])
    pool = pool._replace(tables=pool.tables._replace(leaf=leaf),
                         seq_asid=pool.seq_asid.at[1].set(2),
                         seq_lens=pool.seq_lens.at[1].set(4))
    _, fault = bt_mod.translate(pool.tables, jnp.asarray([1]),
                                jnp.asarray([0]), jnp.asarray([2]))
    assert bool(fault[0])


def test_append_allocates_on_page_boundary():
    cfg, pool = _pool(page=4)
    pool, _ = kvc.admit_seq(cfg, pool, jnp.int32(0), jnp.int32(0),
                            jnp.int32(4))   # exactly one page
    free0 = int(bt_mod.n_free(pool.tables))
    pool, ok = kvc.append_token_alloc(cfg, pool, jnp.int32(0))  # needs page 2
    assert bool(ok)
    assert int(bt_mod.n_free(pool.tables)) == free0 - 1
    pool, ok = kvc.append_token_alloc(cfg, pool, jnp.int32(0))  # same page
    assert int(bt_mod.n_free(pool.tables)) == free0 - 1


def test_pool_exhaustion():
    cfg, pool = _pool(n_pages=4, pps=4)
    pool, ok1 = kvc.admit_seq(cfg, pool, jnp.int32(0), jnp.int32(0),
                              jnp.int32(32))  # 4 pages
    pool, ok2 = kvc.admit_seq(cfg, pool, jnp.int32(1), jnp.int32(0),
                              jnp.int32(8))
    assert bool(ok1) and not bool(ok2)


def test_write_kv_and_block_table_gather():
    cfg, pool = _pool(page=4)
    pool, _ = kvc.admit_seq(cfg, pool, jnp.int32(0), jnp.int32(0),
                            jnp.int32(5))
    k = jnp.ones((1, cfg.n_kv, cfg.head_dim), jnp.bfloat16)
    pool, fault = kvc.write_kv(cfg, pool, 0, jnp.asarray([0]), k, k)
    assert not bool(fault.any())
    bt = kvc.gather_block_table(cfg, pool, jnp.asarray([0]))
    assert bt.shape == (1, cfg.pages_per_seq)
    # the written cell is nonzero
    phys = int(bt[0, 1])  # token index 4 -> page 1, offset 0
    assert float(jnp.sum(pool.k[0, phys, 0])) > 0


# ------------------------------------------------- engine scheduling laws
# (stubbed token compute: the laws under test are host-side scheduling —
# admission, silver quota/rotation, completion accounting)

def _stub_engine(max_batch=4, max_seqs=8, profiles=None, placement=None,
                 n_pages=64):
    from repro.serving.engine import (EngineConfig, ServingEngine,
                                      stub_forwards, stub_model_config)
    cfg = kvc.PoolConfig(n_pages=n_pages, page_size=8, n_kv=1, head_dim=4,
                         n_layers=1, max_seqs=max_seqs, pages_per_seq=4)
    return ServingEngine(stub_model_config(), None, None, cfg,
                         EngineConfig(max_batch=max_batch),
                         placement=placement, profiles=profiles,
                         forwards=stub_forwards())


def _req(rid, tenant, max_new=3, plen=8):
    from repro.serving.engine import Request
    rng = np.random.RandomState(rid)
    return Request(rid=rid, tenant=tenant,
                   prompt=rng.randint(0, 64, plen), max_new=max_new)


def test_completion_counts_decode_steps_only():
    """A request finishes after exactly max_new DECODE steps; the token
    the prefill emits is in `out` but is not a decode token (the old
    off-by-one finished requests one decode early)."""
    eng = _stub_engine()
    eng.submit(_req(0, 0, max_new=3))
    eng.run_until_drained(max_steps=20)
    (r,) = eng.finished
    assert len(r.out) == 4                 # prefill token + 3 decoded
    assert r.decoded == 3
    # prefill + first decode share a step, so finishing takes exactly
    # max_new - 1 further steps (the old off-by-one finished one early)
    assert r.finish_step - r.first_token_step == 2
    from repro.serving import metrics as smet
    tput = smet.tenant_throughput(eng.finished, eng.step_count)
    assert tput[0] * eng.step_count == pytest.approx(3)   # decoded only


def test_silver_backfill_fills_idle_slots():
    """Over-quota silver requests run as NORMAL class when slots would
    otherwise idle (the old behavior decoded only the quota head: one
    token per step for a lone tenant)."""
    eng = _stub_engine(max_batch=4)
    for i in range(4):
        eng.submit(_req(i, 0, max_new=5))
    eng.step()                              # admits all 4, silver quota 1
    assert len(eng.running) == 4
    assert all(r.decoded == 1 for r in eng.running)   # backfilled slots ran
    assert eng._silver_quota_used == 1      # ...but only 1 burned quota
    eng.run_until_drained(max_steps=30)
    assert len(eng.finished) == 4
    # parallel decode: 5 decode steps + admission, not 4 reqs x 5 serial
    assert eng.step_count <= 8


def test_silver_rotation_covers_tenants_in_order():
    eng = _stub_engine(max_batch=2, max_seqs=8)
    for i in range(12):
        eng.submit(_req(i, i % 3, max_new=4))
    seen = []
    for _ in range(40):
        if not eng.running and not any(eng.queues.values()):
            break
        eng.step()
        if not seen or seen[-1] != eng.silver_tenant:
            seen.append(eng.silver_tenant)
    assert set(seen) == {0, 1, 2}
    # rotation is cyclic over the sorted live tenants
    for a, b in zip(seen, seen[1:]):
        live = sorted({0, 1, 2})
        assert b == live[(live.index(a) + 1) % len(live)]


def test_admission_backpressure_bounds_running():
    """Admission respects max_batch and pool sequence slots; queued
    work drains as capacity frees (no request is lost)."""
    eng = _stub_engine(max_batch=3, max_seqs=4)
    for i in range(10):
        eng.submit(_req(i, 0, max_new=2))
    peak = 0
    for _ in range(60):
        if not eng.running and not any(eng.queues.values()):
            break
        eng.step()
        peak = max(peak, len(eng.running))
    assert peak <= 3
    assert len(eng.finished) == 10


def test_placement_caps_gate_admission():
    from repro.serving.placement import StaticPartition
    eng = _stub_engine(max_batch=4, placement=StaticPartition((0, 1)),
                       profiles={0: "batch", 1: "batch"})
    for i in range(6):
        eng.submit(_req(i, 0, max_new=2))
    eng.step()
    # static partition: tenant 0 may hold at most 4//2 = 2 slots even
    # though the batch has room for 4
    assert sum(1 for r in eng.running if r.tenant == 0) == 2
    eng.run_until_drained(max_steps=40)
    assert len(eng.finished) == 6
    assert eng.decisions and eng.decisions[0].policy == "static"


def test_stale_refresh_on_new_tenant():
    """A tenant arriving mid-epoch triggers an early re-decision
    instead of waiting out the epoch with a stale placement."""
    from repro.serving.placement import GreedyShare
    eng = _stub_engine(max_batch=4, placement=GreedyShare(epoch_steps=32),
                       profiles={0: "batch", 1: "interactive"})
    eng.submit(_req(0, 0, max_new=8))
    eng.step()
    assert len(eng.decisions) == 1
    assert eng.decisions[-1].allowed == (0,)
    eng.step()
    assert len(eng.decisions) == 1          # nothing changed mid-epoch
    eng.submit(_req(1, 1, max_new=2))
    eng.step()                              # newcomer -> stale -> re-decide
    assert len(eng.decisions) == 2
    assert eng.decisions[-1].allowed == (0, 1)


def test_pool_pressure_snapshot():
    cfg, pool = _pool()
    pool, _ = kvc.admit_seq(cfg, pool, jnp.int32(0), jnp.int32(1),
                            jnp.int32(20))       # 3 pages for tenant 1
    pool, _ = kvc.admit_seq(cfg, pool, jnp.int32(1), jnp.int32(2),
                            jnp.int32(8))        # 1 page for tenant 2
    p = kvc.pool_pressure(cfg, pool)
    assert p.pages_by_tenant == {1: 3, 2: 1}
    assert p.free_pages == cfg.n_pages - 4
    assert p.used_frac == pytest.approx(4 / cfg.n_pages)
    assert p.free_seqs == cfg.max_seqs - 2
    pool = kvc.release_seq(cfg, pool, jnp.int32(0))
    assert kvc.pool_pressure(cfg, pool).pages_by_tenant == {2: 1}


def test_flood_vs_trickle_latency_bound():
    """Even with NO placement layer, the engine's 3-class discipline
    bounds the trickle tenant's latency: a flood of long decodes from
    tenant 0 cannot push tenant 1's mean latency past a small multiple
    of its solo latency."""
    from repro.serving import metrics as smet
    from repro.serving import stream as strm

    trace = strm.make_trace("flood_vs_trickle", seed=0, steps=64)

    def run(tr):
        eng = _stub_engine(max_batch=8, max_seqs=16, n_pages=256,
                           profiles=tr.profiles())
        for step_reqs in strm.arrivals(tr, 64):
            for r in step_reqs:
                eng.submit(r)
            eng.step()
        eng.run_until_drained(max_steps=600)
        return eng

    solo = smet.tenant_mean_latency(run(trace.only(1)).finished)
    shared = smet.tenant_mean_latency(run(trace).finished)
    assert shared[1] <= 3.0 * solo[1]


@pytest.mark.slow
def test_engine_two_tenants_fairness():
    from repro.launch.serve import build_engine
    from repro.serving import metrics as smet
    from repro.serving.engine import Request

    eng = build_engine("qwen3-4b")
    rng = np.random.RandomState(0)
    for i in range(6):
        eng.submit(Request(rid=i, tenant=i % 2,
                           prompt=rng.randint(0, eng.cfg.vocab_size, 8),
                           max_new=4))
    finished = eng.run_until_drained(max_steps=200)
    assert len(finished) == 6
    tput = smet.tenant_throughput(finished, eng.step_count)
    assert set(tput) == {0, 1}
    ratio = max(tput.values()) / max(min(tput.values()), 1e-9)
    assert ratio < 2.5  # silver rotation keeps tenants comparable
    ws = smet.weighted_speedup(tput, tput)
    assert abs(ws - 2.0) < 1e-6
