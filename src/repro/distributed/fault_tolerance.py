"""Fault tolerance & elasticity for 1000+-node runs.

Mechanisms (all exercised by tests/test_fault_tolerance.py):

* **Checkpoint/restart** — Checkpointer writes atomic COMMITTED snapshots;
  `resume_or_init` picks the newest valid one, discarding partials from a
  crashed run. The data pipeline is index-addressed, so restart is exact
  (deterministic skip-ahead, no replayed or skipped batches).

* **Elastic re-scale** — `elastic_remesh` re-lowers the same step function
  over a smaller/larger mesh from the same checkpoint; snapshots are
  topology-independent (host-gathered leaves + device_put resharding).
  Policy: drop the 'data' axis first (keeps TP intact), never below
  min_data.

* **Straggler mitigation** — `StragglerPolicy` tracks a robust step-time
  estimate (median + MAD); steps exceeding `threshold x median` mark the
  epoch as straggling. Remedies, in escalation order: (1) bounded in-flight
  dispatch (never queue more than `max_inflight` steps so one slow host
  cannot build unbounded skew), (2) within-step timeout -> raise
  StragglerAbort so the launcher checkpoints and re-meshes without the slow
  pod. On real fleets remedy (2) keys off collective timeouts; here it is
  driven by wall-clock.
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Callable, List, Optional, Tuple


class StragglerAbort(RuntimeError):
    pass


@dataclasses.dataclass
class StragglerPolicy:
    threshold: float = 3.0
    warmup_steps: int = 5
    max_inflight: int = 2
    window: int = 50
    _times: List[float] = dataclasses.field(default_factory=list)

    def record(self, dt: float) -> bool:
        """Record a step time; returns True if this step straggled."""
        self._times.append(dt)
        if len(self._times) > self.window:
            self._times.pop(0)
        if len(self._times) <= self.warmup_steps:
            return False
        med = statistics.median(self._times)
        return dt > self.threshold * max(med, 1e-9)

    def median(self) -> float:
        return statistics.median(self._times) if self._times else 0.0


@dataclasses.dataclass(frozen=True)
class MeshTopology:
    pod: int
    data: int
    model: int

    @property
    def chips(self) -> int:
        return self.pod * self.data * self.model


def elastic_remesh(current: MeshTopology, lost_chips: int,
                   min_data: int = 2) -> Optional[MeshTopology]:
    """Choose the next-smaller viable topology after losing chips.

    Shrinks pod first (whole-pod failures are the common case), then halves
    the data axis; the model axis is pinned (resharding TP is a weight
    relayout, done only via checkpoint restore anyway)."""
    remaining = current.chips - lost_chips
    cand = []
    for pod in range(current.pod, 0, -1):
        data = current.data
        while data >= min_data:
            t = MeshTopology(pod, data, current.model)
            if t.chips <= remaining:
                cand.append(t)
                break
            data //= 2
    if not cand:
        return None
    # tie-break: keep the data axis wide (fewer pods) — whole-pod loss is
    # the common case and intra-pod DP avoids cross-pod gradient traffic
    return max(cand, key=lambda t: (t.chips, t.data, -t.pod))


def resume_or_init(ckpt, init_fn: Callable[[], Tuple],
                   params_like=None, opt_like=None):
    """Restart protocol: newest COMMITTED checkpoint or fresh init.

    Returns (params, opt_state, start_step)."""
    step = ckpt.latest_step()
    if step is None:
        params, opt_state = init_fn()
        return params, opt_state, 0
    p_like, o_like = (params_like, opt_like)
    if p_like is None:
        p_like, o_like = init_fn()
    params, opt_state, extra = ckpt.restore(step, p_like, o_like)
    return params, opt_state, int(extra.get("next_step", step + 1))


class BoundedDispatcher:
    """Bounded in-flight step dispatch: blocks when more than `max_inflight`
    steps are unresolved (straggler back-pressure instead of queue blowup)."""

    def __init__(self, max_inflight: int = 2):
        self.max_inflight = max_inflight
        self._inflight: List = []

    def dispatch(self, result):
        self._inflight.append(result)
        if len(self._inflight) > self.max_inflight:
            old = self._inflight.pop(0)
            jax_block(old)
        return result

    def drain(self):
        for r in self._inflight:
            jax_block(r)
        self._inflight.clear()


def jax_block(tree):
    import jax

    for leaf in jax.tree_util.tree_leaves(tree):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()
