"""jamba-1.5-large-398b [hybrid] — Mamba+attention 1:7 interleave, MoE. [arXiv:2403.19887]

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16e top-2
(applied every 2nd layer, per the Jamba paper), attention every 8th layer.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    n_experts=16,
    top_k=2,
    moe_every=2,
    attn_every=8,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv_width=4,
    rope_theta=1_000_000.0,
)
