"""Churn demo: segmented traces, fault injection, and an arrival-rate sweep.

Production GPU sharing is time-varying — apps arrive and depart mid-run —
while every paper figure runs a fixed mix for a fixed cycle count. This
demo shows the robustness layer that closes that gap:

1. One churn trace through `run_trace`: a seeded schedule of
   arrivals/departures, per-segment snapshots, and the ASID-generation
   teardown a departure performs.
2. A deterministic fault plan (kill + TLB flush + dropped DRAM round)
   replayed bit-for-bit, with the state auditor on.
3. A fig20-style mini-sweep: aggregate throughput of `gpu-mmu` vs `mask`
   as the arrival rate grows. Every (design, rate, seed) point reuses
   the SAME compiled segment executable — schedules are data.

Run:  PYTHONPATH=src python examples/churn_trace.py
"""
import numpy as np

from repro.sim.faults import Fault, FaultPlan
from repro.sim.runner import run_trace
from repro.sim.workloads import churn_schedule

SEG_CYCLES = 400      # one compile per design-signature at this length
N_SEGMENTS = 6
N_SLOTS = 2

# ------------------------------------------------------- 1. a churn trace
print("== 1. one churn trace (mask) ==")
sched = churn_schedule(seed=3, n_segments=N_SEGMENTS, n_slots=N_SLOTS,
                       arrival_rate=0.6, departure_rate=0.35)
tr = run_trace("mask", sched, seg_cycles=SEG_CYCLES, return_state=True)
for k, (seg, snap) in enumerate(zip(sched, tr.segments)):
    slots = " + ".join(b or "idle" for b in seg)
    print(f"segment {k}: [{slots:>12s}]  ipc={np.round(snap['ipc'], 2)}")
print("(snapshots are cumulative since each slot's last membership "
      "change; idle slots free-run without memory stalls — the "
      "IPC_alone emulation — so their IPC is not contention data)")
print("final ASID generation per slot:",
      np.asarray(tr.final_state.asid_of_app),
      "(slot asid % n_apps recovers the slot; departures bump the "
      "generation — the old one is shot down everywhere)")

# --------------------------------------------- 2. deterministic chaos run
print("\n== 2. seeded fault plan, replayed bit-for-bit, audited ==")
plan = FaultPlan(seed=17, faults=(
    Fault("kill", 2, app=1),          # app slot 1 killed/restarted
    Fault("tlb_flush", 3, level=1),   # shared L2 TLB flushed
    Fault("drop_dram", 4),            # one segment loses a DRAM round
))
a = run_trace("mask", sched, seg_cycles=SEG_CYCLES, fault_plan=plan,
              audit=True)             # auditor checks every snapshot
b = run_trace("mask", sched, seg_cycles=SEG_CYCLES, fault_plan=plan)
same = all(np.asarray(a.stats[k]).tobytes() == np.asarray(b.stats[k]).tobytes()
           for k in a.stats)
print(f"chaos run finished; replay bitwise-identical: {same}; "
      f"final ipc={np.round(a.stats['ipc'], 2)} (finite, audit-clean)")

# ------------------------------------- 3. arrival-rate mini-sweep (fig20)
print("\n== 3. throughput vs arrival rate (fig20 style) ==")


def active_throughput(schedule, trace):
    """Instructions retired by OCCUPIED slots / total cycles.

    Reconstructed from the cumulative snapshots: a slot's counters are
    zeroed when its membership changes, so a changed slot's snapshot IS
    its per-segment count and an unchanged slot's is a delta. Idle
    slots are excluded — their free-running IPC_alone emulation would
    otherwise drown the contention signal."""
    total = 0.0
    prev_instr = np.zeros(len(schedule[0]))
    prev_seg = (object(),) * len(schedule[0])   # != anything
    for seg, snap in zip(schedule, trace.segments):
        instr = np.asarray(snap["ipc"]) * float(snap["cycles"])
        changed = np.array([a != b for a, b in zip(seg, prev_seg)])
        seg_instr = np.where(changed, instr, instr - prev_instr)
        active = np.array([b is not None for b in seg])
        total += float(seg_instr[active].sum())
        prev_instr, prev_seg = instr, seg
    return total / float(trace.segments[-1]["cycles"])


RATES = (0.2, 0.5, 0.8)
print(f"{'design':>8s} | " + " | ".join(f"rate={r:.1f}" for r in RATES))
for design in ("gpu-mmu", "mask"):
    row = []
    for rate in RATES:
        vals = []
        for seed in (0, 1):
            s = churn_schedule(seed=seed, n_segments=N_SEGMENTS,
                               n_slots=N_SLOTS, arrival_rate=rate)
            vals.append(active_throughput(s, run_trace(
                design, s, seg_cycles=SEG_CYCLES)))
        row.append(np.mean(vals))
    print(f"{design:>8s} | " + " | ".join(f"{v:8.3f}" for v in row))
print("(aggregate IPC of occupied slots; a higher arrival rate keeps "
      "the machine fuller — more throughput, more TLB contention)")
