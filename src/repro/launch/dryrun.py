import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ruff: noqa: E402  (the two lines above MUST precede any jax-touching import)
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this produces: memory_analysis (fits-per-device proof),
cost_analysis (FLOPs / bytes for §Roofline), and the collective-bytes
parse of the compiled HLO. Results stream to reports/dryrun/*.json.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod both]
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, SHAPES_BY_NAME, get_model, get_run_config
from repro.configs.shapes import shape_applicable
from repro.distributed.sharding import Sharder
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.models.params import abstractify
from repro.roofline import analysis as RA
from repro.train import optimizer as opt_mod
from repro.train.step import build_decode_step, build_prefill_step, build_train_step

REPORT_DIR = Path(__file__).resolve().parents[3] / "reports" / "dryrun"


def abstract_cache_specs(cfg, B, S, sharder: Sharder):
    shapes = M.cache_shapes(cfg, B, S)
    axes_map = {
        "cache_len": ("batch",),
        "k": (None, None, "batch", "kvseq", "kv_heads", None),
        "v": (None, None, "batch", "kvseq", "kv_heads", None),
        "ssm_h": (None, None, "batch", "heads", None, None),
        "ssm_conv": (None, None, "batch", None, "ssm"),
        "cross_k": (None, None, "batch", None, "kv_heads", None),
        "cross_v": (None, None, "batch", None, "kv_heads", None),
    }
    return {
        k: jax.ShapeDtypeStruct(
            v.shape, v.dtype,
            sharding=sharder.act_sharding(axes_map[k], v.shape))
        for k, v in shapes.items()
    }


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               run_override=None, tag: str = "", save_hlo: str = ""):
    """Lower + compile one cell; return the report dict."""
    run = run_override or get_run_config(arch, shape_name)
    cfg = run.model
    shape = SHAPES_BY_NAME[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    sharder = Sharder(mesh, run)

    t0 = time.time()
    with mesh:
        params = M.abstract_params(cfg, sharder.param_sharding,
                                   quantize=run.quantize_weights)
        batch = M.input_specs(cfg, shape, sharder.act_sharding)

        if shape.kind == "train":
            opt_cfg = opt_mod.OptConfig(name=run.optimizer,
                                        bf16_moments=run.bf16_moments)
            opt_state = opt_mod.abstract_state(
                M.param_specs(cfg), opt_cfg, sharder.param_sharding)
            step = build_train_step(cfg, run, opt_cfg, sharder.constrain)
            lowered = jax.jit(step, donate_argnums=(0, 1)).lower(
                params, opt_state, batch)
        elif shape.kind == "prefill":
            step = build_prefill_step(cfg, run, shape.seq_len, sharder.constrain)
            lowered = jax.jit(step).lower(params, batch)
        else:  # decode
            caches = abstract_cache_specs(
                cfg, shape.global_batch, shape.seq_len, sharder)
            step = build_decode_step(cfg, run, sharder.constrain)
            lowered = jax.jit(step, donate_argnums=(1,)).lower(
                params, caches, batch)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    report = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "kind": shape.kind, "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1), "tag": tag,
    }

    # ---- memory analysis (fits-per-device proof) ----
    try:
        ma = compiled.memory_analysis()
        for key in ("argument_size_in_bytes", "output_size_in_bytes",
                    "temp_size_in_bytes", "generated_code_size_in_bytes",
                    "alias_size_in_bytes"):
            v = getattr(ma, key, None)
            if v is not None:
                report[key] = int(v)
        args_b = report.get("argument_size_in_bytes", 0)
        alias_b = report.get("alias_size_in_bytes", 0)
        out_b = report.get("output_size_in_bytes", 0)
        tmp_b = report.get("temp_size_in_bytes", 0)
        report["hbm_per_device_bytes"] = args_b + tmp_b + max(out_b - alias_b, 0)
        report["memory_analysis_str"] = str(ma)
    except Exception as e:  # pragma: no cover
        report["memory_analysis_error"] = repr(e)

    # ---- cost analysis ----
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        report["hlo_flops_per_device"] = float(ca.get("flops", 0.0))
        report["hlo_bytes_per_device"] = float(ca.get("bytes accessed", 0.0))
        report["cost_analysis_keys"] = sorted(
            k for k in ca.keys() if not k.startswith("bytes accessed operand"))[:40]
    except Exception as e:  # pragma: no cover
        report["cost_analysis_error"] = repr(e)

    # ---- trip-count-aware HLO parse (FLOPs / HBM / collectives) ----
    try:
        from repro.roofline.hlo_parse import analyze_hlo
        hlo = compiled.as_text()
        report["hlo_text_bytes"] = len(hlo)
        if save_hlo:
            Path(save_hlo).write_text(hlo)
        parsed = analyze_hlo(hlo)
        report["parsed_flops_per_device"] = float(parsed["dot_flops"])
        report["parsed_hbm_bytes_per_device"] = float(parsed["hbm_bytes"])
        report["collective_bytes_by_op"] = parsed["coll_by_op"]
        report["collective_bytes_per_device"] = float(parsed["coll_bytes"])
    except Exception as e:  # pragma: no cover
        report["collective_parse_error"] = repr(e)

    # ---- roofline terms (trip-aware parsed numbers; cost_analysis kept as
    # reference — the CPU backend counts while bodies once) ----
    flops_total = report.get("parsed_flops_per_device",
                             report.get("hlo_flops_per_device", 0.0)) * chips
    hbm_total = report.get("parsed_hbm_bytes_per_device",
                           report.get("hlo_bytes_per_device", 0.0)) * chips
    coll_total = report.get("collective_bytes_per_device", 0) * chips
    terms = RA.roofline_terms(flops_total, hbm_total, coll_total, chips)
    mf = RA.model_flops(cfg, shape)
    terms["model_flops"] = mf
    terms["useful_flops_ratio"] = (
        mf / flops_total if flops_total else 0.0)
    report["roofline"] = terms
    return report


def run_cell(arch, shape_name, multi_pod, out_dir: Path, tag=""):
    mesh_tag = "pod2" if multi_pod else "pod1"
    name = f"{arch}__{shape_name}__{mesh_tag}{('__' + tag) if tag else ''}"
    out = out_dir / f"{name}.json"
    try:
        rep = lower_cell(arch, shape_name, multi_pod, tag=tag)
        status = "ok"
    except Exception as e:
        rep = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
               "error": repr(e), "traceback": traceback.format_exc()}
        status = "FAIL"
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(rep, indent=2, default=str))
    r = rep.get("roofline", {})
    print(f"[{status}] {name} compile={rep.get('compile_s', '-')}s "
          f"dom={r.get('dominant', '-')} "
          f"frac={r.get('roofline_fraction', 0):.3f}", flush=True)
    return status == "ok"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=["off", "on", "both"], default="off")
    ap.add_argument("--out", default=str(REPORT_DIR))
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    out_dir = Path(args.out)
    pods = {"off": [False], "on": [True], "both": [False, True]}[args.multi_pod]

    cells = []
    if args.all:
        for arch, cfg in ARCHS.items():
            for sname, s in SHAPES_BY_NAME.items():
                cells.append((arch, sname, shape_applicable(cfg, s)))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape,
                      shape_applicable(get_model(args.arch),
                                       SHAPES_BY_NAME[args.shape])))

    n_ok = n_skip = n_fail = 0
    for arch, sname, applicable in cells:
        if not applicable:
            print(f"[SKIP] {arch}__{sname} (long_500k needs sub-quadratic "
                  "attention; see DESIGN.md §4)", flush=True)
            n_skip += 1
            continue
        for mp in pods:
            mesh_tag = "pod2" if mp else "pod1"
            if args.skip_existing and (
                    out_dir / f"{arch}__{sname}__{mesh_tag}.json").exists():
                continue
            ok = run_cell(arch, sname, mp, out_dir)
            n_ok += ok
            n_fail += (not ok)
    print(f"done: ok={n_ok} fail={n_fail} skipped_cells={n_skip}")


if __name__ == "__main__":
    main()
