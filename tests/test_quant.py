"""Int8 weight-only serving path (§Perf C2)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, reduced_model
from repro.configs.base import RunConfig, ShapeConfig
from repro.models import model as M
from repro.models.quant import dequant_tree, quantize_arrays


def test_quantize_roundtrip_error_bounded():
    rng = jax.random.PRNGKey(0)
    w = jax.random.normal(rng, (3, 16, 24), jnp.float32).astype(jnp.bfloat16)
    q = quantize_arrays({"w": w})["w"]
    assert q["q"].dtype == jnp.int8
    assert q["scale"].shape == (3, 24)
    back = dequant_tree({"w": q})["w"].astype(jnp.float32)
    err = np.abs(np.asarray(back) - np.asarray(w, np.float32))
    scale = np.asarray(q["scale"])[:, None, :]
    assert np.all(err <= scale * 1.01 + 1e-4)


def test_int8_decode_close_to_bf16():
    cfg = reduced_model(ARCHS["llama3-8b"])
    shape = ShapeConfig("t", 16, 2, "decode")
    run = RunConfig(model=cfg, shape=shape, remat=False,
                    attn_block_q=16, attn_block_k=16)
    runq = dataclasses.replace(run, quantize_weights=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    pb = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (2, 8)))}
    lg, caches = M.forward_prefill(cfg, run, params, pb, max_len=32)
    tok = jnp.argmax(lg[:, -1], -1)[:, None]
    ref, _ = M.forward_decode(cfg, run, params, {"tokens": tok}, caches)
    pq = dict(params, blocks=quantize_arrays(params["blocks"]))
    out, _ = M.forward_decode(cfg, runq, pq, {"tokens": tok}, caches)
    rel = (float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                 - ref.astype(jnp.float32))))
           / max(float(jnp.std(ref.astype(jnp.float32))), 1e-6))
    assert rel < 0.1


def test_abstract_quant_specs_shapes():
    cfg = reduced_model(ARCHS["mistral-large-123b"])
    specs = M.abstract_params(cfg, quantize=True)
    leaves = jax.tree_util.tree_leaves(specs)
    dtypes = {str(l.dtype) for l in leaves}
    assert "int8" in dtypes          # quantized block weights
    assert "bfloat16" in dtypes      # embed / lm head stay bf16
