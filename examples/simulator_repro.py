"""Reproduce the paper's headline comparison on a workload bundle.

Runs all five designs (ideal / PWC / GPU-MMU / Static / MASK) on a 2-app
bundle and prints the weighted speedup + the paper's Table-3-style TLB hit
rates.  ~3-5 min on CPU.

Run:  PYTHONPATH=src python examples/simulator_repro.py [BENCH_A BENCH_B]
"""
import sys

import numpy as np

from repro.sim.runner import run_batch
from repro.sim.workloads import BENCHES

a, b = (sys.argv[1:3] if len(sys.argv) >= 3 else ("3DS", "BLK"))
assert a in BENCHES and b in BENCHES, f"choose from {BENCHES}"
CYCLES = 60_000

print(f"bundle: {a}+{b}  ({CYCLES} cycles)")
solo = {}
for d in ("ideal", "pwc", "gpu-mmu", "static", "mask"):
    sa, sb, sp = run_batch(d, [(a, None), (b, None), (a, b)], cycles=CYCLES)
    ws = (sp["ipc"][0] / max(sa["ipc"][0], 1e-9)
          + sp["ipc"][1] / max(sb["ipc"][0], 1e-9))
    print(f"{d:8s} weighted_speedup={ws:.3f} "
          f"sharedTLB_hit={np.round(sp['l2_hit_rate'], 3)} "
          f"bypass_hit={np.round(sp['byp_hit_rate'], 3)} "
          f"walk_lat={np.round(sp['walk_lat'], 0)}")
print("\npaper: MASK ≈ +45.2% weighted speedup over GPU-MMU; "
      "shared TLB hit 49.3% -> 73.9%")
