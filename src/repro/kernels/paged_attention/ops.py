"""jit'd wrapper for paged decode attention."""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels.paged_attention.kernel import paged_attention as _kernel_call


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q, k_pages, v_pages, block_table, seq_lens,
                    interpret: Optional[bool] = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _kernel_call(q, k_pages, v_pages, block_table, seq_lens,
                        interpret=interpret)
