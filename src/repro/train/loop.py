"""Training loop: jitted step + checkpoint/restart + straggler policy."""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import ModelConfig, RunConfig
from repro.data.pipeline import DataConfig, DataPipeline
from repro.distributed.fault_tolerance import (
    BoundedDispatcher, StragglerAbort, StragglerPolicy, resume_or_init)
from repro.models import model as M
from repro.train import optimizer as opt_mod
from repro.train.step import build_train_step


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    log_every: int = 10
    seed: int = 0
    opt: opt_mod.OptConfig = dataclasses.field(default_factory=opt_mod.OptConfig)


def train(cfg: ModelConfig, run: RunConfig, tcfg: TrainConfig,
          constrain=None, log: Callable[[str], None] = print) -> Dict:
    """Single-host reference loop (the multi-pod path jits the same step
    under the production mesh via launch/train.py)."""
    step_fn = jax.jit(build_train_step(cfg, run, tcfg.opt, constrain),
                      donate_argnums=(0, 1))
    pipe = DataPipeline(cfg, run.shape, DataConfig(seed=tcfg.seed))
    straggler = StragglerPolicy()
    dispatcher = BoundedDispatcher()

    def init_fn():
        params = M.init_params(jax.random.PRNGKey(tcfg.seed), cfg)
        return params, opt_mod.init(params, tcfg.opt)

    ckpt = Checkpointer(tcfg.ckpt_dir) if tcfg.ckpt_dir else None
    if ckpt:
        params, opt_state, start = resume_or_init(ckpt, init_fn)
    else:
        params, opt_state = init_fn()
        start = 0

    history = []
    for step, batch in pipe.iterate(start, tcfg.steps):
        jb = {k: jnp.asarray(v) for k, v in batch.items()}
        t0 = time.time()
        params, opt_state, metrics = step_fn(params, opt_state, jb)
        dispatcher.dispatch(metrics)
        dt = time.time() - t0
        if straggler.record(dt):
            log(f"[straggler] step {step} took {dt:.2f}s "
                f"(median {straggler.median():.2f}s)")
        if step % tcfg.log_every == 0:
            m = {k: float(v) for k, v in metrics.items()}
            history.append({"step": step, **m})
            log(f"step {step}: loss={m.get('loss', float('nan')):.4f}")
        if ckpt and step > start and step % tcfg.ckpt_every == 0:
            dispatcher.drain()
            ckpt.save(step, params, opt_state,
                      extra={"next_step": step + 1}, blocking=False)
    dispatcher.drain()
    if ckpt:
        ckpt.save(tcfg.steps, params, opt_state,
                  extra={"next_step": tcfg.steps}, blocking=True)
    return {"params": params, "opt_state": opt_state, "history": history}
