"""Required per-arch smoke tests: reduced config, one forward/train step on
CPU, output shapes + finite values; plus decode-vs-teacher-forcing
equivalence (the strongest end-to-end correctness check)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced_model
from repro.configs.base import RunConfig, ShapeConfig
from repro.models import model as M
from repro.models.losses import cross_entropy
from repro.train import optimizer as opt_mod
from repro.train.step import build_train_step


def _mk(arch, seq=32, batch=2, fp32=False):
    cfg = reduced_model(ARCHS[arch])
    shape = ShapeConfig("t", seq_len=seq, global_batch=batch, kind="train")
    run = RunConfig(model=cfg, shape=shape, remat=False,
                    attn_block_q=16, attn_block_k=16)
    if fp32:
        from repro.models import lm
        from repro.models.params import materialize
        params = materialize(jax.random.PRNGKey(0), lm.build_param_specs(cfg),
                             dtype_override=jnp.float32)
    else:
        params = M.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    batch_d = {"tokens": jnp.asarray(
        rng.randint(0, cfg.vocab_size, (batch, seq - (cfg.n_patches or 0)))),
        "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (batch, seq)))}
    if cfg.n_patches:
        batch_d["patch_embeds"] = jnp.asarray(
            rng.randn(batch, cfg.n_patches, cfg.d_model) * .02, jnp.bfloat16)
    if cfg.is_enc_dec:
        batch_d["frames"] = jnp.asarray(
            rng.randn(batch, cfg.enc_len, cfg.d_model) * .02, jnp.bfloat16)
    return cfg, run, params, batch_d


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_forward_shapes_and_finite(arch):
    cfg, run, params, batch = _mk(arch)
    logits, aux = M.forward_train(cfg, run, params, batch)
    assert logits.shape == (2, 32, cfg.padded_vocab)
    lf = np.asarray(logits, np.float32)
    assert np.all(np.isfinite(lf)), arch
    loss, _ = cross_entropy(logits, batch["labels"], real_vocab=cfg.vocab_size)
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_one_train_step(arch):
    cfg, run, params, batch = _mk(arch)
    ocfg = opt_mod.OptConfig(lr=1e-3, warmup_steps=1)
    step = build_train_step(cfg, run, ocfg)
    opt_state = opt_mod.init(params, ocfg)
    p2, o2, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually changed
    l0 = jax.tree_util.tree_leaves(params)[0]
    l1 = jax.tree_util.tree_leaves(p2)[0]
    assert not np.allclose(np.asarray(l0, np.float32),
                           np.asarray(l1, np.float32))


@pytest.mark.parametrize("arch", ["llama3-8b", "mamba2-1.3b",
                                  "jamba-1.5-large-398b", "mixtral-8x22b",
                                  "whisper-base"])
def test_decode_matches_teacher_forcing(arch):
    """Prefill + stepwise decode must reproduce the full-forward logits
    (fp32 params: this is a logic-equivalence test, not a precision test)."""
    cfg, run, params, batch = _mk(arch, seq=16, fp32=True)
    pb = {k: (v.astype(jnp.float32) if v.dtype == jnp.bfloat16 else v)
          for k, v in batch.items() if k != "labels"}
    full_logits, _ = M.forward_train(cfg, run, params, pb)

    prompt = 8
    pre = dict(pb, tokens=pb["tokens"][:, :prompt])
    logits, caches = M.forward_prefill(cfg, run, params, pre, max_len=64)
    np.testing.assert_allclose(
        np.asarray(logits[:, -1], np.float32),
        np.asarray(full_logits[:, (cfg.n_patches or 0) + prompt - 1],
                   np.float32), rtol=2e-3, atol=2e-3)

    errs = []
    for i in range(prompt, pb["tokens"].shape[1]):
        tok = pb["tokens"][:, i:i + 1]
        logits, caches = M.forward_decode(cfg, run, params, {"tokens": tok},
                                          caches)
        want = full_logits[:, (cfg.n_patches or 0) + i]
        errs.append(float(jnp.max(jnp.abs(
            logits[:, 0].astype(jnp.float32) - want.astype(jnp.float32)))))
    assert max(errs) < 5e-3, (arch, errs)
