"""jit'd wrapper for the fused TLB probe/fill kernel."""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels.tlb_probe.kernel import tlb_probe_fill as _kernel_call


@functools.partial(jax.jit, static_argnames=("interpret",))
def tlb_probe_fill(tags, asids, lru, vpn, asid, active, time,
                   interpret: Optional[bool] = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _kernel_call(tags, asids, lru, vpn, asid, active, time,
                        interpret=interpret)
