"""Multi-level (radix) page tables — functional, array-free address math.

A 4-level walk for VPN v in address space `asid` touches one PTE per level.
The PTE's *physical line address* is what matters to the memory system (it
decides L2-cache hits and DRAM rows), so we compute addresses arithmetically
instead of materializing tables:

    pte_addr(level k) = table_base(asid, k, prefix_k(v)) + entry_offset

Level-0 is nearest the root: its PTE is shared by every VPN with the same
top-bits prefix — this reproduces the paper's Fig. 9 locality gradient
(near-root levels hit in the shared L2 cache, leaves thrash).

Translation itself (VPN -> PFN) is a deterministic per-ASID permutation-ish
hash: correct disjointness across address spaces without storing state.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class PageTableConfig:
    levels: int = 4
    bits_per_level: int = 9          # x86-64-style 9 bits/level
    page_bits: int = 12              # 4KB pages
    pte_bytes: int = 8
    line_bytes: int = 128            # GPU cache line

    @property
    def vpn_bits(self) -> int:
        return self.levels * self.bits_per_level


def _mix(x: jnp.ndarray) -> jnp.ndarray:
    """Cheap deterministic 32-bit mixer (xorshift-multiply)."""
    x = jnp.asarray(x, jnp.uint32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x7FEB352D)
    x = x ^ (x >> 15)
    x = x * jnp.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return x


def pte_line_addresses(cfg: PageTableConfig, asid, vpn) -> jnp.ndarray:
    """Physical line addresses of the PTEs touched by a walk.

    asid: (...,) int32; vpn: (...,) int32  ->  (..., levels) int32 line ids.
    Each (asid, level) gets a disjoint region; within a region the PTE index
    is the VPN prefix for that level, so near-root lines are shared by many
    pages (locality) and leaf lines are nearly unique per page.
    """
    asid = jnp.asarray(asid, jnp.uint32)
    vpn = jnp.asarray(vpn, jnp.uint32)
    out = []
    entries_per_line = cfg.line_bytes // cfg.pte_bytes
    for k in range(cfg.levels):
        shift = (cfg.levels - 1 - k) * cfg.bits_per_level
        prefix = vpn >> shift                      # entry index at level k
        line = prefix // entries_per_line
        region = (asid[..., None] if False else asid) * jnp.uint32(cfg.levels + 1) \
            + jnp.uint32(k + 1)
        # region base spreads tables apart; keep 32-bit line ids
        base = _mix(region) & jnp.uint32(0x0FFFFFFF)
        out.append((base + line).astype(jnp.int32))
    return jnp.stack(out, axis=-1)


def translate(cfg: PageTableConfig, asid, vpn) -> jnp.ndarray:
    """VPN -> PFN (deterministic, disjoint across ASIDs)."""
    a = jnp.asarray(asid, jnp.uint32)
    v = jnp.asarray(vpn, jnp.uint32)
    return (_mix(a * jnp.uint32(0x9E3779B9) + v) & jnp.uint32(0x3FFFFFFF)) \
        .astype(jnp.int32)


def walk_depth_tag(level: int) -> int:
    """3-bit page-walk-depth tag carried by memory requests (§5.3):
    0 = normal data, 1..6 = walk level, 7 = deeper."""
    return min(level + 1, 7)
