"""Deterministic fault injection for chaos-testing the simulator.

A `FaultPlan` is a declarative, hashable list of `Fault`s, each pinned to
a segment boundary of a `runner.run_trace` schedule. Before a segment
runs, the runner applies every fault scheduled at that boundary:

  kill          -- kill + restart the target app slot: a full membership
                   change (fresh ASID generation, TLB shootdown, cold
                   warps/stats — `memsys.apply_membership_change`).
  tlb_flush     -- spurious full flush of one translation cache level
                   (0 = per-core L1 bank, 1 = shared L2 TLB, 2 = bypass
                   cache): models an over-broad shootdown.
  tlb_corrupt   -- overwrite one seeded (set, way) of the shared L2 TLB
                   with a seeded translation for a LIVE ASID: a wrong-
                   but-plausible entry (spurious hits, lost victim). The
                   write dedups any existing same-(vpn, asid) entry in
                   the set first, so state invariants (audit) still hold.
  drop_dram     -- drop the standing DRAM backlog and close all open
                   rows: a lost/reset memory round.
  walk_clobber  -- occupy one seeded walk-table row with a bogus
                   in-flight walk for a live ASID (walker-thread leak):
                   steals a walker slot and soaks up merges until its
                   seeded completion time passes.

Determinism: every operand (which set, which way, which vpn, completion
delta) is derived from `FaultPlan.seed` via a counter-based scheme, so a
plan replays bit-for-bit. The plan is carried on `SimConfig.fault_plan`
but stripped by the runner's compile-cache canonicalization: operands are
lowered to SHAPE-STABLE per-segment arrays (`plan_operands`) fed to one
compiled segment executable as data — every plan (including no plan,
`empty_operands`) shares a single trace, and all-False masks are the
bitwise identity on the state.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax.numpy as jnp
import numpy as np

from repro.sim import memsys
from repro.sim.config import SimConfig

FAULT_KINDS = ("kill", "tlb_flush", "tlb_corrupt", "drop_dram",
               "walk_clobber")
FLUSH_LEVELS = ("l1", "l2tlb", "bypass")


@dataclasses.dataclass(frozen=True)
class Fault:
    """One declarative fault: `kind` applied before segment `segment` runs.

    `app` targets a slot for "kill" (and seeds the live-ASID choice for
    "tlb_corrupt" / "walk_clobber"); `level` picks the cache for
    "tlb_flush" (index into FLUSH_LEVELS).
    """
    kind: str
    segment: int
    app: int = 0
    level: int = 1

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"fault kind must be one of {FAULT_KINDS}, got {self.kind!r}")
        if self.segment < 0:
            raise ValueError(f"fault segment must be >= 0, got {self.segment}")
        if not 0 <= self.level < len(FLUSH_LEVELS):
            raise ValueError(
                f"fault level must index {FLUSH_LEVELS}, got {self.level}")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A deterministic, replayable chaos schedule (hashable: keys nothing
    in the compile cache — see `runner._canonical` — but rides on
    `SimConfig` so a chaos run's config fully describes it)."""
    seed: int = 0
    faults: Tuple[Fault, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "faults", tuple(self.faults))

    def validate(self, n_apps: int, n_segments: int) -> None:
        for f in self.faults:
            if f.segment >= n_segments:
                raise ValueError(
                    f"fault {f} targets segment {f.segment} but the "
                    f"schedule has only {n_segments} segments")
            if f.kind == "kill" and not 0 <= f.app < n_apps:
                raise ValueError(
                    f"fault {f} kills app slot {f.app}, outside "
                    f"[0, {n_apps})")


def random_plan(seed: int, n_segments: int, n_apps: int,
                rate: float = 0.5) -> FaultPlan:
    """Seeded random chaos plan: each boundary draws a fault with
    probability `rate` (boundary 0 is spared — a fault before any cycle
    ran is a no-op for most kinds)."""
    rng = np.random.default_rng(seed)
    faults = []
    for s in range(1, n_segments):
        if rng.random() >= rate:
            continue
        kind = FAULT_KINDS[int(rng.integers(len(FAULT_KINDS)))]
        faults.append(Fault(kind=kind, segment=s,
                            app=int(rng.integers(n_apps)),
                            level=int(rng.integers(len(FLUSH_LEVELS)))))
    return FaultPlan(seed=seed, faults=tuple(faults))


class FaultOps(NamedTuple):
    """Per-segment fault operands, all arrays with leading axis
    (n_segments,) — pure data, one shape for every plan."""
    kill: np.ndarray          # (S, n_apps) bool
    flush: np.ndarray         # (S, 3) bool, FLUSH_LEVELS order
    corrupt: np.ndarray       # (S,) bool
    corrupt_set: np.ndarray   # (S,) int32
    corrupt_way: np.ndarray   # (S,) int32
    corrupt_vpn: np.ndarray   # (S,) int32
    corrupt_app: np.ndarray   # (S,) int32 slot whose LIVE asid is written
    drop_dram: np.ndarray     # (S,) bool
    clobber: np.ndarray       # (S,) bool
    clobber_row: np.ndarray   # (S,) int32
    clobber_vpn: np.ndarray   # (S,) int32
    clobber_app: np.ndarray   # (S,) int32
    clobber_delta: np.ndarray # (S,) int32 cycles until the bogus walk ends


def empty_operands(cfg: SimConfig, n_segments: int) -> FaultOps:
    """The no-fault operand set: all masks False (bitwise identity)."""
    S = n_segments
    z = np.zeros(S, np.int32)
    return FaultOps(
        kill=np.zeros((S, cfg.n_apps), bool),
        flush=np.zeros((S, len(FLUSH_LEVELS)), bool),
        corrupt=np.zeros(S, bool), corrupt_set=z, corrupt_way=z,
        corrupt_vpn=z, corrupt_app=z,
        drop_dram=np.zeros(S, bool),
        clobber=np.zeros(S, bool), clobber_row=z, clobber_vpn=z,
        clobber_app=z, clobber_delta=z)


def plan_operands(plan: FaultPlan, cfg: SimConfig,
                  n_segments: int) -> FaultOps:
    """Lower a declarative plan to per-segment operand arrays.

    Operand draws come from one generator seeded by `plan.seed`, consumed
    in fault-list order — same plan, same operands, bit for bit.
    """
    plan.validate(cfg.n_apps, n_segments)
    ops = empty_operands(cfg, n_segments)
    rng = np.random.default_rng(plan.seed)
    tr = cfg.design.translation
    l2_sets = max(tr.l2_entries // max(tr.l2_ways, 1), 1)
    for f in plan.faults:
        s = f.segment
        if f.kind == "kill":
            ops.kill[s, f.app] = True
        elif f.kind == "tlb_flush":
            ops.flush[s, f.level] = True
        elif f.kind == "tlb_corrupt":
            ops.corrupt[s] = True
            ops.corrupt_set[s] = rng.integers(l2_sets)
            ops.corrupt_way[s] = rng.integers(max(tr.l2_ways, 1))
            ops.corrupt_vpn[s] = rng.integers(1 << 20)
            ops.corrupt_app[s] = f.app % cfg.n_apps
        elif f.kind == "drop_dram":
            ops.drop_dram[s] = True
        elif f.kind == "walk_clobber":
            ops.clobber[s] = True
            ops.clobber_row[s] = rng.integers(
                tr.max_concurrent_walks)
            ops.clobber_vpn[s] = rng.integers(1 << 20)
            ops.clobber_app[s] = f.app % cfg.n_apps
            ops.clobber_delta[s] = int(rng.integers(100, 2000))
    return ops


# --------------------------------------------------------------------------
# Serving-layer fault vocabulary (PR 10): overload faults injected into the
# SERVING ENGINE's host-side loop rather than the jitted simulator state.
# Same discipline as the sim faults — declarative, seeded, replayable
# bit-for-bit — but applied by `ServingEngine.step` at step boundaries:
#
#   pool_spike      -- phantom sequences admitted under a reserved ASID
#                      occupy KV pages for `duration` steps: a pool-
#                      exhaustion spike the degradation ladder must ride
#                      out (quota -> preempt -> freeze) without losing
#                      requests.
#   oracle_stall    -- the contention oracle misses its latency budget for
#                      `duration` steps: the policy must fail soft to a
#                      contention-blind equal share (rung "stalled").
#   profile_poison  -- tenant `tenant` declares profile `profile` for
#                      `duration` steps (a wrong-but-plausible claim): the
#                      recalibrator must absorb the resulting misprediction
#                      without destabilizing placement.

SERVING_FAULT_KINDS = ("pool_spike", "oracle_stall", "profile_poison")


@dataclasses.dataclass(frozen=True)
class ServingFault:
    """One serving-layer fault firing at engine step `step` and lasting
    `duration` steps. `pages` sizes a pool_spike (0 = half the pool);
    `tenant`/`profile` target a profile_poison."""
    kind: str
    step: int
    duration: int = 16
    tenant: int = 0
    pages: int = 0
    profile: str = "heavy"

    def __post_init__(self):
        if self.kind not in SERVING_FAULT_KINDS:
            raise ValueError(f"serving fault kind must be one of "
                             f"{SERVING_FAULT_KINDS}, got {self.kind!r}")
        if self.step < 0:
            raise ValueError(f"fault step must be >= 0, got {self.step}")
        if self.duration < 1:
            raise ValueError(f"fault duration must be >= 1, "
                             f"got {self.duration}")
        if self.pages < 0:
            raise ValueError(f"fault pages must be >= 0, got {self.pages}")


@dataclasses.dataclass(frozen=True)
class ServingFaultPlan:
    """A deterministic, replayable overload schedule for the serving
    engine (carried on `EngineConfig.fault_plan`)."""
    seed: int = 0
    faults: Tuple[ServingFault, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "faults", tuple(self.faults))

    def at_step(self, step: int) -> Tuple[ServingFault, ...]:
        return tuple(f for f in self.faults if f.step == step)

    def validate(self, tenants: Tuple[int, ...]) -> None:
        for f in self.faults:
            if f.kind == "profile_poison" and f.tenant not in tenants:
                raise ValueError(
                    f"fault {f} poisons tenant {f.tenant}, not in the "
                    f"declared universe {tenants}")


def random_serving_plan(seed: int, n_steps: int,
                        tenants: Tuple[int, ...],
                        rate: float = 0.05) -> ServingFaultPlan:
    """Seeded random overload plan: each step past warmup draws a fault
    with probability `rate`; operands (kind, tenant, duration) come from
    one generator in step order — same seed, same plan, bit for bit."""
    rng = np.random.default_rng(seed)
    faults = []
    warmup = max(n_steps // 8, 4)
    for s in range(warmup, n_steps):
        if rng.random() >= rate:
            continue
        kind = SERVING_FAULT_KINDS[int(rng.integers(
            len(SERVING_FAULT_KINDS)))]
        faults.append(ServingFault(
            kind=kind, step=s,
            duration=int(rng.integers(8, 24)),
            tenant=int(tenants[int(rng.integers(len(tenants)))]),
            profile="heavy"))
    return ServingFaultPlan(seed=seed, faults=tuple(faults))


def _full_flush(st, on):
    """Flush every entry of a TLBState when `on` (traced bool scalar)."""
    return st._replace(
        tags=jnp.where(on, jnp.full_like(st.tags, -1), st.tags),
        asids=jnp.where(on, jnp.full_like(st.asids, -1), st.asids))


def apply_state_faults(cfg: SimConfig, state: "memsys.SimState",
                       ops: FaultOps) -> "memsys.SimState":
    """Apply one boundary's non-kill faults to the carried state.

    `ops` is a `FaultOps` sliced at a single segment (leading axis
    removed). Kill faults are NOT handled here — the runner merges
    `ops.kill` into the membership-change mask so kills share
    `memsys.apply_membership_change`'s full teardown path. Every write is
    mask-gated (`jnp.where` / out-of-bounds drop scatter): all-False
    operands return `state` bitwise unchanged, which keeps every plan —
    and no plan at all — on one compiled trace.
    """
    trans = state.trans
    trans = trans._replace(
        l1=_full_flush(trans.l1, ops.flush[0]),
        l2tlb=_full_flush(trans.l2tlb, ops.flush[1]),
        bypass_tlb=_full_flush(trans.bypass_tlb, ops.flush[2]))

    # tlb_corrupt: seeded overwrite of one shared-L2-TLB entry with a
    # plausible translation for a live ASID. First drop any existing
    # same-(vpn, asid) entry in the set (no duplicate-entry invariant
    # violation), then scatter the corrupt entry; inactive boundaries
    # route the write out of bounds.
    l2 = trans.l2tlb
    n_sets, n_ways = l2.tags.shape
    c_on = ops.corrupt
    c_set = jnp.where(c_on, ops.corrupt_set % n_sets, n_sets)
    c_asid = state.asid_of_app[ops.corrupt_app % cfg.n_apps]
    row_dup = (l2.tags[c_set % n_sets] == ops.corrupt_vpn) & \
        (l2.asids[c_set % n_sets] == c_asid) & c_on
    tags = l2.tags.at[c_set % n_sets].set(
        jnp.where(row_dup, -1, l2.tags[c_set % n_sets]))
    asids = l2.asids.at[c_set % n_sets].set(
        jnp.where(row_dup, -1, l2.asids[c_set % n_sets]))
    tags = tags.at[c_set, ops.corrupt_way % n_ways].set(
        ops.corrupt_vpn, mode="drop")
    asids = asids.at[c_set, ops.corrupt_way % n_ways].set(
        c_asid, mode="drop")
    lru = l2.lru.at[c_set, ops.corrupt_way % n_ways].set(
        state.t, mode="drop")
    trans = trans._replace(l2tlb=l2._replace(tags=tags, asids=asids,
                                             lru=lru))

    # walk_clobber: occupy one walk-table row with a bogus live-ASID walk
    wt = trans.walk.shape[0]
    k_on = ops.clobber
    k_row = jnp.where(k_on, ops.clobber_row % wt, wt)
    k_asid = state.asid_of_app[ops.clobber_app % cfg.n_apps]
    bogus = jnp.stack([ops.clobber_vpn, k_asid,
                       state.t + ops.clobber_delta,
                       jnp.ones((), jnp.int32)]).astype(jnp.int32)
    walk = trans.walk.at[k_row].set(bogus, mode="drop")
    trans = trans._replace(walk=walk)

    dram = state.data.dram
    dram = dram._replace(
        open_row=jnp.where(ops.drop_dram,
                           jnp.full_like(dram.open_row, -1), dram.open_row),
        queue_len=jnp.where(ops.drop_dram,
                            jnp.zeros_like(dram.queue_len), dram.queue_len))

    return state._replace(trans=trans,
                          data=state.data._replace(dram=dram))
