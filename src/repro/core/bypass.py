"""TLB-Request-Aware L2 Bypass (paper §5.3).

Memory requests carry a 3-bit page-walk-depth tag (0 = data, 1..6 = walk
level, 7 = deeper). Per-level hit/access counters at the shared L2 data
cache are compared with the data-request hit rate; a walk level may FILL
the L2 only while its hit rate >= the data hit rate. Root-ward levels have
high cross-thread reuse (Fig. 9) and keep caching; leaf levels bypass.

Decisions are epoch-based: an epoch's fills follow the PREVIOUS epoch's
measured rates, and every 4th epoch is a sampling epoch (fills enabled for
all levels) so a bypassed level's rate can recover if its locality changes
— without sampling, bypassing is a one-way door (a bypassed level never
hits again, so its measured rate can never climb back over the data rate).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

MAX_DEPTH = 8  # tag values 0..7
SAMPLE_EVERY = 4


class BypassState(NamedTuple):
    hits: jax.Array       # (MAX_DEPTH,) per-tag L2 hits this epoch (0 = data)
    accesses: jax.Array   # (MAX_DEPTH,)
    rate_q10: jax.Array   # (MAX_DEPTH,) int32 prev-epoch hit rate in 1/1024
    have_rates: jax.Array  # () bool — at least one epoch measured
    epoch_idx: jax.Array   # () int32


def init() -> BypassState:
    return BypassState(hits=jnp.zeros((MAX_DEPTH,), jnp.int32),
                       accesses=jnp.zeros((MAX_DEPTH,), jnp.int32),
                       rate_q10=jnp.zeros((MAX_DEPTH,), jnp.int32),
                       have_rates=jnp.array(False),
                       epoch_idx=jnp.zeros((), jnp.int32))


def record(state: BypassState, depth_tag, hit, active) -> BypassState:
    oh = jax.nn.one_hot(depth_tag, MAX_DEPTH, dtype=jnp.int32)
    m = active[:, None] * oh
    return state._replace(hits=state.hits + (m * hit[:, None]).sum(0),
                          accesses=state.accesses + m.sum(0))


def should_fill(state: BypassState, depth_tag) -> jax.Array:
    """(N,) bool: may this request fill the shared L2 data cache?"""
    sampling = (state.epoch_idx % SAMPLE_EVERY) == 0
    level_ok = (state.rate_q10 >= state.rate_q10[0]) | ~state.have_rates \
        | sampling
    level_ok = level_ok.at[0].set(True)   # data always fills
    return level_ok[depth_tag]


def epoch_update(state: BypassState) -> BypassState:
    """Latch this epoch's rates for next epoch's decisions; reset counters."""
    measured = state.accesses > 32
    rate = (state.hits * 1024) // jnp.maximum(state.accesses, 1)
    # unmeasured levels inherit the previous estimate
    rate = jnp.where(measured, rate, state.rate_q10)
    return BypassState(
        hits=jnp.zeros_like(state.hits),
        accesses=jnp.zeros_like(state.accesses),
        rate_q10=rate.astype(jnp.int32),
        have_rates=state.have_rates | measured[0],
        epoch_idx=state.epoch_idx + 1,
    )
