"""Production mesh construction.

A FUNCTION (not module-level constant) so importing never touches jax device
state. Single pod: (data=16, model=16) = 256 chips. Multi-pod: (pod=2,
data=16, model=16) = 512 chips.
"""
from __future__ import annotations

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, found {len(devices)} — "
            "run under dryrun.py (XLA_FLAGS=--xla_force_host_platform_device_count=512)")
    return jax.make_mesh(shape, axes, devices=devices[:need])


def make_host_mesh(model_axis: int = 1):
    """Tiny mesh over the real local devices (CPU tests / examples)."""
    import jax

    n = len(jax.devices())
    data = n // model_axis
    return jax.make_mesh((data, model_axis), ("data", "model"),
                         devices=jax.devices()[: data * model_axis])
