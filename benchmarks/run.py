"""Benchmark harness: one entry per paper table/figure + kernel micro-bench
+ roofline summary. Prints ``name,value,paper_value`` rows / JSON blocks.

  PYTHONPATH=src python -m benchmarks.run                 # paper repro suite
  PYTHONPATH=src python -m benchmarks.run --quick         # subset (CI)
  PYTHONPATH=src python -m benchmarks.run --kernels       # kernel micro-bench
  PYTHONPATH=src python -m benchmarks.run --roofline      # dry-run summary
  PYTHONPATH=src python -m benchmarks.run --perf          # steps/sec bench
  PYTHONPATH=src python -m benchmarks.run --list-designs  # design registry
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from benchmarks import paper_repro  # noqa: E402


def run_paper(which=None, force=False):
    rows = []
    for name, fn in paper_repro.ALL.items():
        if which and name not in which:
            continue
        t0 = time.time()
        try:
            res = fn(force=force)
            rows.append((name, res, time.time() - t0))
            print(f"# {name} ({time.time()-t0:.0f}s)")
            print(json.dumps(res, indent=2, default=float))
        except Exception as e:  # pragma: no cover
            print(f"# {name} FAILED: {e!r}")
    return rows


def run_kernels():
    """Micro-bench the Pallas kernels (interpret on CPU = correctness +
    relative shape scaling, not wall-clock MFU)."""
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.fused_tlb.ops import fused_tlb_access
    from repro.kernels.paged_attention.ops import paged_attention
    from repro.kernels.ssd_scan.ops import ssd_scan

    print("name,us_per_call,flops_est")
    rng = np.random.RandomState(0)
    B, S, H, KV, dh = 1, 512, 4, 2, 128
    q = jnp.asarray(rng.randn(B, S, H, dh), jnp.float32)
    k = jnp.asarray(rng.randn(B, S, KV, dh), jnp.float32)
    v = jnp.asarray(rng.randn(B, S, KV, dh), jnp.float32)
    f = lambda: flash_attention(q, k, v, block_q=128, block_k=128)  # noqa
    f().block_until_ready()
    t0 = time.time()
    for _ in range(3):
        f().block_until_ready()
    print(f"flash_attention_512,{(time.time()-t0)/3*1e6:.0f},"
          f"{4*B*H*S*S*dh/2:.3g}")

    qd = jnp.asarray(rng.randn(4, H, dh), jnp.float32)
    kp = jnp.asarray(rng.randn(32, 16, KV, dh), jnp.float32)
    vp = jnp.asarray(rng.randn(32, 16, KV, dh), jnp.float32)
    bt = jnp.asarray(rng.choice(32, (4, 8)), jnp.int32)
    sl = jnp.asarray([128, 64, 90, 16], jnp.int32)
    g = lambda: paged_attention(qd, kp, vp, bt, sl)  # noqa
    g().block_until_ready()
    t0 = time.time()
    for _ in range(3):
        g().block_until_ready()
    print(f"paged_attention_b4,{(time.time()-t0)/3*1e6:.0f},"
          f"{4*4*H*128*dh:.3g}")

    sets, ways, lanes = 64, 16, 48
    tags = jnp.asarray(rng.choice(1 << 12, (sets, ways)), jnp.int32)
    asids = jnp.asarray(rng.choice(4, (sets, ways)), jnp.int32)
    lru = jnp.asarray(rng.choice(1000, (sets, ways)), jnp.int32)
    vpn = jnp.asarray(rng.choice(1 << 12, lanes), jnp.int32)
    asid = jnp.asarray(rng.choice(4, lanes), jnp.int32)
    on = jnp.ones(lanes, jnp.int32)
    tl = lambda: fused_tlb_access(tags, asids, lru, vpn, asid, on, on,  # noqa
                                  1001, n_waves=6, interpret=True)[3]
    tl().block_until_ready()
    t0 = time.time()
    for _ in range(3):
        tl().block_until_ready()
    print(f"fused_tlb_{lanes}lane,{(time.time()-t0)/3*1e6:.0f},n/a")

    x = jnp.asarray(rng.randn(1, 256, 8, 32) * .3, jnp.float32)
    dt = jnp.asarray(np.abs(rng.randn(1, 256, 8)) * .1 + .02, jnp.float32)
    A = jnp.asarray(-np.abs(rng.randn(8)) * .5 - .1, jnp.float32)
    Bm = jnp.asarray(rng.randn(1, 256, 16) * .3, jnp.float32)
    Cm = jnp.asarray(rng.randn(1, 256, 16) * .3, jnp.float32)
    h = lambda: ssd_scan(x, dt, A, Bm, Cm, chunk=64)[0]  # noqa
    h().block_until_ready()
    t0 = time.time()
    for _ in range(3):
        h().block_until_ready()
    print(f"ssd_scan_256,{(time.time()-t0)/3*1e6:.0f},n/a")


def run_roofline_summary():
    """Summarize reports/dryrun into the §Roofline table (CSV)."""
    rep_dir = Path(__file__).resolve().parent.parent / "reports" / "dryrun"
    rows = sorted(rep_dir.glob("*.json"))
    print("cell,mesh,dominant,compute_s,memory_s,collective_s,"
          "roofline_frac,useful_ratio,hbm_gb")
    for f in rows:
        r = json.loads(f.read_text())
        if "error" in r:
            print(f"{f.stem},ERROR,,,,,,,")
            continue
        rf = r.get("roofline", {})
        print(f"{r['arch']}__{r['shape']},{r['mesh']},{rf.get('dominant')},"
              f"{rf.get('compute_s', 0):.3e},{rf.get('memory_s', 0):.3e},"
              f"{rf.get('collective_s', 0):.3e},"
              f"{rf.get('roofline_fraction', 0):.3f},"
              f"{rf.get('useful_flops_ratio', 0):.3f},"
              f"{r.get('hbm_per_device_bytes', 0)/1e9:.2f}")


def list_designs():
    """Print the design registry: every named point `benchmarks` can run."""
    from repro.core.design import get_design, list_designs as _names
    for name in _names():
        d = get_design(name)
        mechs = [m for m, on in (("tokens", d.tokens.enabled),
                                 ("bypass", d.bypass.enabled),
                                 ("dram", d.dram.enabled)) if on]
        print(f"{name:12s} translation={d.translation.kind:13s} "
              f"partition={d.partition.kind:6s} "
              f"mechanisms={'+'.join(mechs) or '-'}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--kernels", action="store_true")
    ap.add_argument("--roofline", action="store_true")
    ap.add_argument("--perf", action="store_true")
    ap.add_argument("--list-designs", action="store_true")
    ap.add_argument("--only", nargs="*", default=None)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-compile-cache", action="store_true",
                    help="disable the persistent JAX compilation cache "
                         "(default: cache compiles under .jax_cache/ so "
                         "re-runs skip recompiles; see README)")
    args = ap.parse_args()

    if not args.no_compile_cache:
        from benchmarks.perf import enable_compilation_cache
        enable_compilation_cache()

    if args.kernels:
        run_kernels()
        return
    if args.roofline:
        run_roofline_summary()
        return
    if args.perf:
        from benchmarks.perf import run_bench
        run_bench()
        return
    if args.list_designs:
        list_designs()
        return
    which = args.only
    if args.quick and not which:
        which = ["fig16", "tab3"]
    run_paper(which, force=args.force)


if __name__ == "__main__":
    main()
