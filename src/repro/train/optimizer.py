"""Optimizers (no external deps): AdamW with optional bf16 moments, and
Adafactor for memory-constrained giants. Moment trees shard exactly like the
params they track (elementwise updates preserve GSPMD sharding)."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"          # adamw | adafactor
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    bf16_moments: bool = False
    warmup_steps: int = 100
    # serialize per-leaf updates with optimization barriers: without this
    # XLA holds the fp32 update temps of EVERY stacked weight concurrently
    # (tens of GB for 398B-class models); with it, peak = one leaf's temps
    sequential_updates: bool = True


def lr_schedule(cfg: OptConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    return cfg.lr * warm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw_init(params, cfg: OptConfig):
    mdt = jnp.bfloat16 if cfg.bf16_moments else jnp.float32

    def zeros_like(p):
        return jnp.zeros(p.shape, mdt)

    return {
        "m": jax.tree_util.tree_map(zeros_like, params),
        "v": jax.tree_util.tree_map(zeros_like, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(params, grads, state, cfg: OptConfig):
    step = state["step"] + 1
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32) * scale
        mf = m.astype(jnp.float32) * b1 + gf * (1 - b1)
        vf = v.astype(jnp.float32) * b2 + jnp.square(gf) * (1 - b2)
        u = (mf / bc1) / (jnp.sqrt(vf / bc2) + cfg.eps)
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * u
        return p_new.astype(p.dtype), mf.astype(m.dtype), vf.astype(v.dtype)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = []
    token = None
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        if cfg.sequential_updates and token is not None:
            p, g, m, v, _ = jax.lax.optimization_barrier((p, g, m, v, token))
        res = upd(p, g, m, v)
        token = res[0]
        out.append(res)
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics


# ---------------------------------------------------------------------------
# Adafactor (factored second moment; rank>=2 leaves factored)
# ---------------------------------------------------------------------------

def adafactor_init(params, cfg: OptConfig):
    def factored(p):
        if p.ndim >= 2:
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return {
        "v": jax.tree_util.tree_map(factored, params,
                                    is_leaf=lambda x: hasattr(x, "ndim")),
        "step": jnp.zeros((), jnp.int32),
    }


def adafactor_update(params, grads, state, cfg: OptConfig):
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)
    decay = 1.0 - step.astype(jnp.float32) ** -0.8

    def upd(p, g, v):
        gf = g.astype(jnp.float32)
        g2 = jnp.square(gf) + 1e-30
        if p.ndim >= 2:
            vr = v["vr"] * decay + jnp.mean(g2, axis=-1) * (1 - decay)
            vc = v["vc"] * decay + jnp.mean(g2, axis=-2) * (1 - decay)
            denom = (vr[..., None] * vc[..., None, :]
                     / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True),
                                   1e-30)[..., None])
            u = gf * jax.lax.rsqrt(denom + 1e-30)
            nv = {"vr": vr, "vc": vc}
        else:
            nv = {"v": v["v"] * decay + g2 * (1 - decay)}
            u = gf * jax.lax.rsqrt(nv["v"] + 1e-30)
        # update clipping (RMS <= 1)
        rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
        u = u / jnp.maximum(1.0, rms)
        p_new = p.astype(jnp.float32) - lr * (u + cfg.weight_decay * p)
        return p_new.astype(p.dtype), nv

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_v = treedef.flatten_up_to(state["v"])
    out = []
    token = None
    for p, g, v in zip(flat_p, flat_g, flat_v):
        if cfg.sequential_updates and token is not None:
            p, g, _ = jax.lax.optimization_barrier((p, g, token))
        res = upd(p, g, v)
        token = res[0]
        out.append(res)
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    return new_p, {"v": new_v, "step": step}, {"lr": lr}


def init(params, cfg: OptConfig):
    return (adafactor_init if cfg.name == "adafactor" else adamw_init)(params, cfg)


def update(params, grads, state, cfg: OptConfig):
    fn = adafactor_update if cfg.name == "adafactor" else adamw_update
    return fn(params, grads, state, cfg)


def abstract_state(param_specs_tree, cfg: OptConfig, sharding_fn=None):
    """ShapeDtypeStruct tree of optimizer state matching abstract params.

    sharding_fn: Param -> NamedSharding (moments shard like their param).
    """
    from repro.models.params import Param, is_param, tree_map_params
    import dataclasses as dc

    def moment(p: Param, dtype):
        q = dc.replace(p, dtype=dtype)
        if sharding_fn is None:
            return jax.ShapeDtypeStruct(q.shape, q.dtype)
        return jax.ShapeDtypeStruct(q.shape, q.dtype, sharding=sharding_fn(q))

    mdt = jnp.bfloat16 if cfg.bf16_moments else jnp.float32
    if cfg.name == "adafactor":
        def fac(p: Param):
            if len(p.shape) >= 2:
                vr = dc.replace(p, shape=p.shape[:-1], axes=p.axes[:-1],
                                dtype=jnp.float32)
                vc = dc.replace(p, shape=p.shape[:-2] + p.shape[-1:],
                                axes=p.axes[:-2] + p.axes[-1:],
                                dtype=jnp.float32)
                return {"vr": moment(vr, jnp.float32),
                        "vc": moment(vc, jnp.float32)}
            return {"v": moment(dc.replace(p, dtype=jnp.float32), jnp.float32)}

        return {"v": tree_map_params(fac, param_specs_tree),
                "step": jax.ShapeDtypeStruct((), jnp.int32)}

    return {
        "m": tree_map_params(lambda p: moment(p, mdt), param_specs_tree),
        "v": tree_map_params(lambda p: moment(p, mdt), param_specs_tree),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }
