"""Quickstart: the three layers of the repo in ~60 seconds on CPU.

1. MASK policy objects (the paper's contribution) driving a toy TLB.
2. The memory-hierarchy simulator: GPU-MMU vs MASK on one workload pair.
3. A reduced LM: one training step + one decode step through the public API.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

# ----------------------------------------------------------------- 1. MASK
from repro.core import tlb as tlb_mod
from repro.core import tokens as tok_mod

print("== 1. MASK policies ==")
tlb = tlb_mod.init(n_entries=512, n_ways=16)      # the shared L2 TLB
toks = tok_mod.init(n_apps=2, warps_per_app=jnp.asarray([720, 720]))
vpn = jnp.asarray([11, 12, 13], jnp.int32)
asid = jnp.asarray([0, 0, 1], jnp.int32)
tlb = tlb_mod.fill(tlb, vpn, asid, jnp.ones(3, bool), 1)
tlb, hit = tlb_mod.probe(tlb, vpn, asid, jnp.ones(3, bool), 2)
print("probe hits after fill:", np.asarray(hit))
print("initial tokens (80% of warps):", np.asarray(toks.tokens))

# ------------------------------------------------------------ 2. simulator
print("\n== 2. simulator: GPU-MMU vs MASK on 3DS+BLK (short run) ==")
from repro.sim.runner import run_batch

for design in ("gpu-mmu", "mask"):
    (s,) = run_batch(design, [("3DS", "BLK")], cycles=15000)
    print(f"{design:8s} ipc={np.round(s['ipc'], 1)} "
          f"sharedTLB hit={np.round(s['l2_hit_rate'], 2)}")

# -------------------------------------------------------------- 3. tiny LM
print("\n== 3. reduced llama3: one train step + one decode step ==")
from repro.configs import ARCHS, reduced_model
from repro.configs.base import RunConfig, ShapeConfig
from repro.models import model as M
from repro.train import optimizer as opt_mod
from repro.train.step import build_train_step

cfg = reduced_model(ARCHS["llama3-8b"])
shape = ShapeConfig("demo", seq_len=32, global_batch=2, kind="train")
run = RunConfig(model=cfg, shape=shape, remat=False,
                attn_block_q=16, attn_block_k=16)
params = M.init_params(jax.random.PRNGKey(0), cfg)
ocfg = opt_mod.OptConfig(lr=1e-3, warmup_steps=1)
step = build_train_step(cfg, run, ocfg)
rng = np.random.RandomState(0)
batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (2, 32))),
         "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (2, 32)))}
params, opt_state, metrics = step(params, opt_mod.init(params, ocfg), batch)
print(f"train loss: {float(metrics['loss']):.3f}")

logits, caches = M.forward_prefill(
    cfg, run, params, {"tokens": batch["tokens"][:, :8]}, max_len=64)
tok = jnp.argmax(logits[:, -1], -1)[:, None]
logits, caches = M.forward_decode(cfg, run, params, {"tokens": tok}, caches)
print("decode logits shape:", logits.shape, "— done.")
