from repro.configs.base import ModelConfig, RunConfig, ShapeConfig  # noqa: F401
from repro.configs.registry import (  # noqa: F401
    ARCHS, all_cells, get_model, get_run_config, reduced_model,
)
from repro.configs.shapes import ALL_SHAPES, SHAPES_BY_NAME  # noqa: F401
