# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
#
# fused_tlb/ is the simulator's hot spot: the fused cross-wave shared
# L2$/PWC round (core/tlb.py::access_fused) as a Pallas kernel, selected
# via SimConfig.tlb_backend / REPRO_TLB_BACKEND (xla | pallas |
# pallas-interpret) and parity-pinned bit-for-bit against the XLA path.
# It replaces the retired seed tlb_probe/ kernel, whose single-round
# probe+fill contract predated the fused semantics.
