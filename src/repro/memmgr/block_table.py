"""Two-level block tables for the multi-tenant paged KV cache.

Logical layout per tenant: sequence -> logical pages -> physical page slots
in the shared HBM pool. The *root* level (per-tenant page directory) is tiny
and hot — it is pinned in the translation cache (the paper's 'levels near
the root hit' insight, §5.3); leaf rows stream.

Everything is functional: tables are int32 arrays carried in serving state.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

FREE = jnp.int32(-1)


class BlockTables(NamedTuple):
    # leaf: (max_seqs, pages_per_seq) physical page id or -1
    leaf: jax.Array
    # root: (max_tenants, seqs_per_tenant) -> seq slot id or -1
    root: jax.Array
    # per-physical-page owner ASID (protection domain check, §5.1)
    owner: jax.Array          # (n_pages,) int32 asid or -1
    free_head: jax.Array      # () int32 — count of allocated pages
    free_list: jax.Array      # (n_pages,) int32 permutation of page ids


def init(n_pages: int, max_seqs: int, pages_per_seq: int,
         max_tenants: int, seqs_per_tenant: int) -> BlockTables:
    return BlockTables(
        leaf=jnp.full((max_seqs, pages_per_seq), FREE, jnp.int32),
        root=jnp.full((max_tenants, seqs_per_tenant), FREE, jnp.int32),
        owner=jnp.full((n_pages,), FREE, jnp.int32),
        free_head=jnp.zeros((), jnp.int32),
        free_list=jnp.arange(n_pages, dtype=jnp.int32),
    )


def n_free(bt: BlockTables) -> jax.Array:
    return bt.free_list.shape[0] - bt.free_head


def alloc_pages(bt: BlockTables, seq_slot, start_page, count, asid
                ) -> Tuple[BlockTables, jax.Array]:
    """Allocate `count` physical pages for seq_slot's logical pages
    [start_page, start_page+count). Returns (bt', ok). Static max `count`
    callers loop; this is the jit-able single-shot used by the engine."""
    max_count = bt.leaf.shape[1]
    idx = jnp.arange(max_count)
    take = idx < count
    # an allocation past the seq's logical capacity must fail WHOLE:
    # a page granted but unmappable would hold an owner while no leaf
    # entry references it — free_seq could then never reclaim it
    ok = (count <= n_free(bt)) & (start_page + count <= max_count)

    phys = bt.free_list[(bt.free_head + idx) % bt.free_list.shape[0]]
    phys = jnp.where(take & ok, phys, FREE)
    logical = start_page + idx
    write = take & ok & (logical < max_count)
    # inactive lanes scatter into a trash slot (never into index 0 — a
    # stale read-back there would clobber an active lane's write)
    padded = jnp.concatenate(
        [bt.leaf[seq_slot], jnp.zeros((1,), jnp.int32)])
    padded = padded.at[jnp.where(write, logical, max_count)].set(
        jnp.where(write, phys, 0))
    leaf = bt.leaf.at[seq_slot].set(padded[:max_count])
    n_pages = bt.owner.shape[0]
    owner_p = jnp.concatenate([bt.owner, jnp.zeros((1,), jnp.int32)])
    owner_p = owner_p.at[jnp.where(phys >= 0, phys, n_pages)].set(
        jnp.where(phys >= 0, asid, 0))
    head = bt.free_head + jnp.where(ok, count, 0)
    return bt._replace(leaf=leaf, owner=owner_p[:n_pages], free_head=head), ok


def free_seq(bt: BlockTables, seq_slot) -> BlockTables:
    """Return a sequence's pages to the pool (lazy free-list append)."""
    row = bt.leaf[seq_slot]
    n = (row >= 0).sum()
    # compact the freed ids to the tail region of the ring
    order = jnp.argsort(jnp.where(row >= 0, 0, 1))
    freed = row[order]
    start = bt.free_head - n
    pos = (start + jnp.arange(row.shape[0])) % bt.free_list.shape[0]
    fl = bt.free_list.at[pos].set(
        jnp.where(jnp.arange(row.shape[0]) < n, freed, bt.free_list[pos]))
    n_pages = bt.owner.shape[0]
    owner_p = jnp.concatenate([bt.owner, jnp.zeros((1,), jnp.int32)])
    owner_p = owner_p.at[jnp.where(row >= 0, row, n_pages)].set(FREE)
    return bt._replace(
        leaf=bt.leaf.at[seq_slot].set(FREE),
        owner=owner_p[:n_pages], free_list=fl, free_head=start)


def translate(bt: BlockTables, seq_slot, logical_page, asid):
    """Logical page -> physical page with protection check.

    Returns (phys, fault) — fault=True on unmapped page or ASID mismatch
    (cross-address-space access attempt)."""
    phys = bt.leaf[seq_slot, logical_page]
    bad = (phys < 0) | (bt.owner[jnp.maximum(phys, 0)] != asid)
    return jnp.where(bad, 0, phys), bad
