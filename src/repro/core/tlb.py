"""Set-associative, ASID-tagged TLBs as pure-JAX state (batched probe/fill).

One structure covers the paper's three translation caches:

  * per-core L1 TLB  — 64-entry fully associative (n_sets=1), LRU
  * shared L2 TLB    — 512-entry 16-way, ASID-tagged, LRU
  * bypass cache     — 32-entry fully associative (MASK §5.2)

State is a NamedTuple of arrays so a bank of TLBs (one per core) is just a
leading axis + vmap — `init_bank` / `probe_bank` / `fill_bank` package that
pattern for the simulator's per-core L1 TLBs. Fills are batched; when
several requests map to the same set in one step, one fill wins per set
(ports/fill-bandwidth model — the paper's L2 TLB has 2 ports per memory
partition).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class TLBState(NamedTuple):
    tags: jax.Array      # (sets, ways) int32 vpn  (-1 invalid)
    asids: jax.Array     # (sets, ways) int32
    lru: jax.Array       # (sets, ways) int32 last-use time
    hits: jax.Array      # () int32 cumulative
    misses: jax.Array    # () int32


def init(n_entries: int, n_ways: int) -> TLBState:
    n_sets = max(n_entries // n_ways, 1)
    shape = (n_sets, n_ways)
    return TLBState(
        tags=jnp.full(shape, -1, jnp.int32),
        asids=jnp.full(shape, -1, jnp.int32),
        lru=jnp.zeros(shape, jnp.int32),
        hits=jnp.zeros((), jnp.int32),
        misses=jnp.zeros((), jnp.int32),
    )


def probe(state: TLBState, vpn, asid, active, time) -> Tuple[TLBState, jax.Array]:
    """Batched probe. vpn/asid/active: (N,). Returns (state', hit (N,) bool).

    LRU is updated for hits; hit/miss counters accumulate only active lanes.
    """
    n_sets, n_ways = state.tags.shape
    set_ix = jnp.where(n_sets > 1, vpn % n_sets, 0).astype(jnp.int32)
    t = state.tags[set_ix]                       # (N, ways)
    a = state.asids[set_ix]
    match = (t == vpn[:, None]) & (a == asid[:, None])
    hit = match.any(axis=1) & active
    way = jnp.argmax(match, axis=1)

    # LRU touch for hits only: non-hit lanes are routed out of bounds and
    # dropped, so they can never scatter a stale value over a hit's touch
    touch_set = jnp.where(hit, set_ix, n_sets)
    lru = state.lru.at[touch_set, way].set(time, mode="drop")
    hits = state.hits + hit.sum(dtype=jnp.int32)
    misses = state.misses + (active & ~hit).sum(dtype=jnp.int32)
    return state._replace(lru=lru, hits=hits, misses=misses), hit


def fill(state: TLBState, vpn, asid, do_fill, time) -> TLBState:
    """Batched fill with LRU victim selection. do_fill: (N,) bool.

    One fill per set per call (first lane wins) — models fill-port limits.
    """
    n_sets, n_ways = state.tags.shape
    set_ix = jnp.where(n_sets > 1, vpn % n_sets, 0).astype(jnp.int32)

    # first-wins per set: lane i is masked out if an earlier lane fills the
    # same set
    order = jnp.arange(vpn.shape[0])
    same_earlier = (set_ix[None, :] == set_ix[:, None]) & \
        (order[None, :] < order[:, None]) & do_fill[None, :]
    do_fill = do_fill & ~same_earlier.any(axis=1)

    victim = jnp.argmin(state.lru[set_ix], axis=1)       # (N,)
    # masked lanes are routed out of bounds and dropped — a plain masked
    # scatter would write the stale old value back and could clobber the
    # winning lane's fill on duplicate sets
    fill_set = jnp.where(do_fill, set_ix, n_sets)
    tags = state.tags.at[fill_set, victim].set(vpn, mode="drop")
    asids = state.asids.at[fill_set, victim].set(asid, mode="drop")
    lru = state.lru.at[fill_set, victim].set(time, mode="drop")
    return state._replace(tags=tags, asids=asids, lru=lru)


def init_bank(n_banks: int, n_entries: int, n_ways: int) -> TLBState:
    """A bank of identical TLBs: one TLBState with leading axis (n_banks,)."""
    single = init(n_entries, n_ways)
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (n_banks,) + x.shape), single)


def probe_bank(state: TLBState, vpn, asid, active, time
               ) -> Tuple[TLBState, jax.Array]:
    """Probe a bank of TLBs, one request per bank. vpn/asid/active: (B,)."""
    fn = jax.vmap(lambda s, v, a, act: probe(s, v[None], a[None], act[None],
                                             time))
    state, hit = fn(state, vpn, asid, active)
    return state, hit[:, 0]


def fill_bank(state: TLBState, vpn, asid, do_fill, time) -> TLBState:
    """Fill a bank of TLBs, one request per bank. vpn/asid/do_fill: (B,)."""
    fn = jax.vmap(lambda s, v, a, d: fill(s, v[None], a[None], d[None], time))
    return fn(state, vpn, asid, do_fill)


def flush_asid(state: TLBState, asid: int) -> TLBState:
    """TLB shootdown for one address space (paper §5.1)."""
    kill = state.asids == asid
    return state._replace(
        tags=jnp.where(kill, -1, state.tags),
        asids=jnp.where(kill, -1, state.asids))


def occupancy_by_asid(state: TLBState, n_asids: int) -> jax.Array:
    """(n_asids,) live-entry counts — used by fairness diagnostics.

    One-hot sum over every entry axis; invalid entries (asid -1) one-hot
    to all-zeros, so no explicit valid mask interplay is needed beyond
    the tag check. Also works on banked states (extra leading axes).
    """
    valid = state.tags >= 0
    oh = jax.nn.one_hot(state.asids, n_asids, dtype=jnp.int32)
    return (oh * valid[..., None]).sum(axis=tuple(range(oh.ndim - 1)))
