"""Logical-axis → mesh-axis mapping (DP / TP / FSDP / EP / SP).

Params carry *logical* axis names (repro.models.params.Param.axes); activations
are constrained with logical names at key points in the model. A ``Sharder``
binds those names to mesh axes for a given (mesh, RunConfig):

  TP   : heads / kv_heads / ffn / vocab / experts / ssm  -> 'model'
  DP   : batch                                           -> ('pod','data')
  FSDP : first large replicated weight axis              -> ('pod','data')
          (ZeRO-3: params+optimizer sharded; XLA all-gathers at use)
  SP   : decode KV length ('kvseq')                      -> 'model'
          (flash-decoding style: each model shard holds S/16 of the cache
           and computes partial attention; XLA inserts the tiny softmax
           combine collectives). long_500k (batch=1) additionally spreads
           kvseq over ('data','model') = 256-way.

Every mapping is divisibility-checked: a dim that does not divide evenly
falls back to replication (this is why vocab tables are padded to 128).
"""
from __future__ import annotations

from typing import Optional, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import RunConfig
from repro.models.params import Param

_TP_PARAM_AXES = {"heads", "ffn", "vocab", "experts", "ssm"}


class Sharder:
    def __init__(self, mesh: Mesh, run: RunConfig):
        self.mesh = mesh
        self.run = run
        self.multi_pod = "pod" in mesh.axis_names
        self.dp: Tuple[str, ...] = (("pod", "data") if self.multi_pod
                                    else ("data",))
        self.model_size = mesh.shape["model"]
        self.dp_size = int(np.prod([mesh.shape[a] for a in self.dp]))
        # long-context decode with batch < dp: spread KV over data too
        self.wide_kvseq = (run.seq_shard_decode
                           and run.shape.global_batch < self.dp_size)

    def _axis_size(self, entry) -> int:
        if entry is None:
            return 1
        if isinstance(entry, tuple):
            return int(np.prod([self.mesh.shape[a] for a in entry]))
        return self.mesh.shape[entry]

    def _fit(self, entry, size: Optional[int]):
        """Divisibility fallback: drop the mapping if it doesn't divide."""
        if size is None:
            return entry
        return entry if (self._axis_size(entry) and
                         size % self._axis_size(entry) == 0) else None

    # ----------------------------------------------------------- params
    def param_spec(self, p: Param) -> P:
        entries = [None] * len(p.shape)
        # pass 1: tensor parallelism (first fitting TP axis -> 'model')
        used_model = False
        for i, (ax, size) in enumerate(zip(p.axes, p.shape)):
            if ax in _TP_PARAM_AXES and not used_model:
                e = self._fit("model", size)
                if e is not None:
                    entries[i] = e
                    used_model = True
        # pass 2: data-axis placement under FSDP.
        #  * expert weights whose 'ffn' dim is still free get 2D sharding
        #    (experts->model, ffn->data): consumed in place, no ZeRO gather,
        #    the w_down contraction psums over data.
        #  * otherwise ZeRO-3 on the first large 'embed' dim (gathered at use).
        if self.run.fsdp and len(p.shape) >= 2:
            cand = None
            if len(p.shape) >= 3 and "experts" in p.axes:
                for i, (ax, size) in enumerate(zip(p.axes, p.shape)):
                    if (ax == "ffn" and entries[i] is None
                            and size % self.dp_size == 0):
                        cand = i
                        break
            if cand is None:
                for i, (ax, size) in enumerate(zip(p.axes, p.shape)):
                    if (ax == "embed" and entries[i] is None and size >= 1024
                            and size % self.dp_size == 0):
                        cand = i
                        break
            if cand is not None:
                entries[cand] = self.dp
        return P(*entries)

    def param_sharding(self, p: Param) -> NamedSharding:
        return NamedSharding(self.mesh, self.param_spec(p))

    # ------------------------------------------------------- activations
    def act_spec(self, axes, shape: Optional[Tuple[int, ...]] = None) -> P:
        spec = []
        used = set()
        relax = (self.run.decode_relax_batch and self.run.shape.is_decode
                 and "kvseq" not in axes)
        for i, ax in enumerate(axes):
            size = shape[i] if shape is not None else None
            if ax == "batch":
                entry = None if relax else self._fit(self.dp, size)
            elif ax == "kvseq":
                e = ("data", "model") if self.wide_kvseq else "model"
                entry = self._fit(e, size)
            elif ax in ("heads", "kv_heads", "ffn", "vocab", "experts", "ssm"):
                entry = self._fit("model", size)
            else:
                entry = None
            # a mesh axis may appear at most once per spec
            names = (entry if isinstance(entry, tuple)
                     else (entry,) if entry else ())
            if any(n in used for n in names):
                entry = None
            else:
                used.update(names)
            spec.append(entry)
        return P(*spec)

    def act_sharding(self, axes, shape=None) -> NamedSharding:
        return NamedSharding(self.mesh, self.act_spec(axes, shape))

    def constrain(self, x: jax.Array, axes) -> jax.Array:
        return jax.lax.with_sharding_constraint(
            x, self.act_sharding(axes, tuple(x.shape)))

    # ------------------------------------------------------------- misc
    def replicated(self) -> NamedSharding:
        return NamedSharding(self.mesh, P())
