"""phi-3-vision-4.2b [vlm] — phi3-mini backbone + CLIP frontend (stubbed).

32L d_model=3072 32H (MHA: kv=32) d_ff=8192 vocab=32064.
[hf:microsoft/Phi-3-vision-128k-instruct; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    rope_theta=10_000.0,
    n_patches=64,      # precomputed CLIP patch embeddings prepended (stub frontend)
)
